"""Continuous-batching serving with a factorized model (paper use case 2,
serving side) over the paged KV cache with chunked, prefix-aware prefill.

    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 16 --factorize --rank 0.5 --shared-prefix 16 \
        --kv-layout paged --block-size 8 --decode-kernel pallas \
        --chunk-size 8 --prefill-budget 8

    # speculative decoding: rank-0.5 factorized draft, dense verify,
    # bit-exact greedy output (asserted), acceptance rate printed
    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 8 --spec-k 4

    # SSE-style streaming: one `data:` line per token as it lands
    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 8 --stream

    # the HTTP front door: SSE streaming over POST /v1/generate,
    # client-disconnect/deadline cancellation, bounded admission queue
    # (429 when full), GET /metrics Prometheus exposition; drive it
    # with the closed-/open-loop client in `repro.launch.loadgen`
    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --http --port 8000 --max-pending 32 --request-timeout 30
    PYTHONPATH=src python -m repro.launch.loadgen --port 8000 \
        --mode open --rate 8 --n-requests 32 --cancel-frac 0.2

    # heterogeneous families: hymba (ring-buffer KV + SSM state) and
    # mamba2 (pure SSM) serve through the same engine via per-slot state
    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 8 --arch hymba-1.5b --chunk-size 8
    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 8 --arch mamba2-2.7b

    # sharded serving: the engine SPMD on a {data, model} mesh (params,
    # paged pool, slot state, activations all placed; greedy tokens
    # bit-identical to single-device) — 8 emulated CPU devices suffice
    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu \
      PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 8 --mesh 2,2

Wraps the production serve driver (``repro.launch.serve``), so every
engine knob threads straight through: ``--kv-layout`` / ``--block-size`` /
``--n-blocks`` pick the KV layout, ``--decode-kernel`` picks the paged
decode attention (``reference`` dense gather vs the fused ``pallas``
paged-attention kernel), ``--prefill-kernel`` picks the chunked-prefill
attention on either layout (``reference`` vs the flash ``pallas``
prefill-chunk kernel), ``--chunk-size`` / ``--buckets`` /
``--prefill-budget`` shape the admission pipeline, ``--shared-prefix`` /
``--no-prefix-reuse`` / ``--prefix-retain`` exercise the prefix cache,
and ``--long-frac`` / ``--long-prompt`` mix a heavy prompt tail into the
Poisson trace.  ``--factorize --rank R --solver svd`` serves the
``auto_fact``-factorized model and reports dense-vs-factorized greedy
agreement; ``--spec-k K`` runs speculative decoding (rank-``R``
factorized draft + dense multi-token verify, bit-exact greedy).
``--priority-mix`` / ``--no-preemption`` / ``--aging-every`` /
``--slo-ttft`` drive the scheduling policy: priority-class admission
(FIFO within a class, aging-bounded starvation across classes),
preemption of lower-priority running decodes with prefix-cache-backed
resume (bit-identical greedy streams), and SLO-aware prefill-budget
adaptation — see ``src/repro/serve/README.md`` §Scheduling policy.
``--http`` skips the offline trace entirely and serves the engine over
HTTP (``--host`` / ``--port`` / ``--max-pending`` / ``--request-timeout``
— per-request bodies may carry ``"priority"`` and ``"timeout_s"``; see
``src/repro/serve/README.md`` §The HTTP front door).
``--mesh dp,tp`` (or ``$REPRO_MESH``) runs the engine SPMD on a
``{data, model}`` mesh — see ``src/repro/dist/README.md`` and
``src/repro/serve/README.md`` §Sharded serving.

**The admission pipeline** (see ``src/repro/serve/README.md``): a prompt
is prefilled in ``chunk_size``-token chunks, each right-padded to one of
2-3 bucket widths so the chunk jit compiles a bounded number of times,
and at most ``prefill_chunk_budget`` padded tokens of prefill run per
engine step — decode keeps advancing between the chunks of a long
prompt, so one long prompt no longer freezes every running request, and
a short prompt's TTFT no longer hides behind a long neighbour's prefill.
When requests share a prompt prefix, the paged layout serves it from
refcounted pool blocks AND skips recomputing it: prefill starts at the
longest cached block-chain (recomputing at most the final token), and
freed prefix blocks stay parked on an LRU so hits survive idle periods.

Greedy outputs are bit-identical to the dense per-slot layout, to the
monolithic (single-chunk) prefill, and to the one-shot ``generate``
baseline — enforced by ``tests/test_chunked_prefill.py`` (and by
``tests/test_hetero_serving.py`` for the hymba/mamba per-slot state
kinds, where the paged knobs degrade gracefully: ring lanes and SSM
state cannot be paged or prefix-cached).

Prints tokens/s, p50/p95 per-request latency, TTFT, HBM-resident KV
bytes, the admission-path profile (tokens computed vs skipped, per-step
stall), and greedy-token agreement between dense and factorized weights.

Programmatic use::

    from repro.serve import ContinuousEngine
    eng = ContinuousEngine(model, cfg, batch=8, max_len=256,
                           max_prompt_len=64, block_size=16,
                           chunk_size=32, prefill_chunk_budget=32,
                           decode_kernel="pallas")
    eng.submit(prompt_ids, max_new_tokens=32)                  # greedy
    eng.submit(other_ids, max_new_tokens=16, temperature=0.8,
               stop_ids=(eos_id,))
    for uid, token, done in eng.stream():      # tokens as they land
        print(uid, token, done.finish_reason if done else "")
    print(eng.kv_stats())       # resident KV bytes, prefix-cache hits
    print(eng.prefill_stats())  # chunks run, tokens computed vs skipped
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    raise SystemExit(serve_main())
