"""Continuous-batching serving with a factorized model (paper use case 2,
serving side).

    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 16 --fact-rank 0.5

Wraps the production serve driver (``repro.launch.serve``): a Poisson trace
of variable-length prompts is replayed through ``ContinuousEngine`` —
requests join recyclable decode slots mid-flight under one jitted
prefill/decode pair — for the dense model and its SVD-factorized copy.
Prints tokens/s, p50/p95 per-request latency, and greedy-token agreement
between the two.

Programmatic use::

    from repro.serve import ContinuousEngine
    eng = ContinuousEngine(model, cfg, batch=8, max_len=256,
                           max_prompt_len=64)
    eng.submit(prompt_ids, max_new_tokens=32)                  # greedy
    eng.submit(other_ids, max_new_tokens=16, temperature=0.8,
               stop_ids=(eos_id,))
    for completion in eng.run():
        print(completion.uid, completion.finish_reason, completion.tokens)
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    raise SystemExit(serve_main())
