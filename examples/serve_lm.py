"""Continuous-batching serving with a factorized model (paper use case 2,
serving side) over the paged KV cache.

    PYTHONPATH=src python examples/serve_lm.py --reduced --batch 4 \
        --n-requests 16 --fact-rank 0.5 --shared-prefix 16 \
        --kv-layout paged --block-size 8 --decode-kernel pallas

Wraps the production serve driver (``repro.launch.serve``), so every
engine knob threads straight through: ``--kv-layout`` / ``--block-size`` /
``--n-blocks`` pick the KV layout, ``--decode-kernel`` picks the paged
decode attention (``reference`` dense gather vs the fused ``pallas``
paged-attention kernel), ``--shared-prefix`` exercises the prefix cache.
A Poisson trace of variable-length prompts is replayed through
``ContinuousEngine`` — requests join recyclable decode slots mid-flight
under one jitted prefill/decode pair — for the dense model and its
SVD-factorized copy.

The KV cache is **paged** by default: instead of each slot pinning a dense
``max_len`` lane, all slots share one pool of ``block_size``-token KV
blocks (``(n_layers, n_blocks, block_size, kv_heads, head_dim)``), and a
per-slot block table of shape ``(batch, ceil(max_len / block_size))`` maps
logical position ``p`` to pool row ``table[slot, p // block_size] *
block_size + p % block_size``.  Requests reserve only the blocks they can
actually use, so HBM-resident KV bytes track live tokens.  Requests that
share a system prompt (``--shared-prefix``) reuse the same physical
prefill blocks: full prompt blocks are keyed by a sha256 hash-chain over
their tokens and refcounted, and a shared block is immutable — decode
always extends into a freshly allocated block, never a shared one.
Greedy outputs are bit-identical to the dense per-slot layout and to the
one-shot ``generate`` baseline.

Prints tokens/s, p50/p95 per-request latency, HBM-resident KV bytes, and
greedy-token agreement between dense and factorized weights.

Programmatic use::

    from repro.serve import ContinuousEngine
    eng = ContinuousEngine(model, cfg, batch=8, max_len=256,
                           max_prompt_len=64, block_size=16,
                           decode_kernel="pallas")  # fused paged attention
    eng.submit(prompt_ids, max_new_tokens=32)                  # greedy
    eng.submit(other_ids, max_new_tokens=16, temperature=0.8,
               stop_ids=(eos_id,))
    for completion in eng.run():
        print(completion.uid, completion.finish_reason, completion.tokens)
    print(eng.kv_stats())   # peak resident KV bytes, prefix-cache hits
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    raise SystemExit(serve_main())
