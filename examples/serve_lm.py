"""Batched serving with a factorized model (paper use case 2, serving side).

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --gen 32 --fact-rank 0.5

Wraps the production serve driver: dense vs SVD-factorized tokens/s plus
greedy-token agreement between the two.
"""

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    raise SystemExit(serve_main())
