"""Post-training factorization (paper use case 2) end to end:

  1. train a dense model on the synthetic Markov-LM task,
  2. factorize it with each solver at a sweep of rank ratios,
  3. report eval loss + parameter compression per point.

    PYTHONPATH=src python examples/factorize_pretrained.py [--steps 200]
"""

import argparse

import jax

from repro import auto_fact
from repro.configs import get_config
from repro.models import build_model
from repro.nn import param_count


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    args = p.parse_args()

    import sys
    sys.path.insert(0, "benchmarks")
    from common import eval_loss, train_model  # reuse the bench harness

    cfg = get_config("paper-tiny")
    key = jax.random.PRNGKey(0)
    model = build_model(key, cfg)
    model, final_loss, _ = train_model(model, cfg, steps=args.steps)
    base_eval, _ = eval_loss(model, cfg)
    base_params = param_count(model)
    print(f"dense: eval {base_eval:.3f}  params {base_params/1e6:.2f}M")

    for solver in ("svd", "snmf", "random"):
        for ratio in (0.75, 0.5, 0.25):
            fact = auto_fact(model, ratio, solver=solver, num_iter=50,
                             key=key, exclude=["embed", "lm_head"])
            ev, _ = eval_loss(fact, cfg)
            print(f"{solver:6s}@{ratio:4.2f}: eval {ev:.3f} "
                  f"(Δ {ev - base_eval:+.3f})  params "
                  f"{param_count(fact)/1e6:.2f}M")


if __name__ == "__main__":
    main()
