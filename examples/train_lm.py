"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
Greenformer factorization-by-design, checkpointing, and resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--fact-rank 0.25]

This is deliberately the same code path as the production launcher
(repro/launch/train.py); on CPU a ~100M model is slow, so the default config
here is ~10M — pass --big for the ~100M variant if you have the patience.
"""

import argparse

from repro.launch.train import main as train_main


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--fact-rank", type=float, default=0.25)
    p.add_argument("--big", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    argv = ["--arch", "paper-tiny", "--steps", str(args.steps),
            "--batch", "16", "--seq", "128",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100"]
    if args.fact_rank:
        argv += ["--fact-rank", str(args.fact_rank), "--solver", "random"]
    raise SystemExit(train_main(argv))


if __name__ == "__main__":
    main()
