"""Greenformer-JAX quickstart — the paper's one-line API.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import auto_fact
from repro.configs import get_config
from repro.models import build_model
from repro.nn import param_count

key = jax.random.PRNGKey(0)

# 1. build any model in the framework (a small dense LM here)
cfg = get_config("paper-tiny")
model = build_model(key, cfg)
print(f"dense model: {param_count(model)/1e6:.2f}M params")

# 2. ONE LINE: factorize every linear/conv layer with the SVD solver.
#    rank may be an int (absolute) or a float (ratio of each layer's r_max).
fact_model, report = auto_fact(
    model, rank=0.25, solver="svd", num_iter=50,
    exclude=["embed", "lm_head"],  # the paper's submodule filtering
    return_report=True)
print(report.summary())

# 3. the factorized model is a drop-in replacement
tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
dense_logits, _ = model(tokens)
fact_logits, _ = fact_model(tokens)
print(f"output shape: {fact_logits.shape} (same as dense: "
      f"{dense_logits.shape == fact_logits.shape})")
print(f"factorized params: {param_count(fact_model)/1e6:.2f}M "
      f"({param_count(model)/param_count(fact_model):.2f}x smaller incl. "
      "embeddings)")

# 4. it trains / differentiates like any pytree module
grads = jax.grad(
    lambda m: jnp.mean(m(tokens)[0].astype(jnp.float32) ** 2))(fact_model)
print("grad of a factor:", grads.blocks.attn.q_proj.A.shape)
