"""Global runtime configuration for sharded serving.

Alpa keeps one process-wide ``global_config`` object (``global_env.py``)
so every knob that shapes the distributed runtime lives in a single,
inspectable place instead of threading through a dozen call sites.  We
adopt the same pattern here: :data:`global_config` is the one source of
truth for the serving mesh spec and its companions, seeded from the
environment at import time and overridable programmatically (tests) or
via CLI flags (``repro.launch.serve --mesh dp,tp``).

The serving mesh is a 2-D ``{data, model}`` mesh:

- ``data``  — the decode-slot batch axis.  Slots are sharded across it;
  each data shard decodes its slice of the batch.
- ``model`` — the tensor-parallel axis.  Attention/MLP weights and the
  KV head dim of the cache are sharded across it (Megatron layout, see
  ``repro.dist.sharding``).

No accelerators required: with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the CPU backend
exposes 8 host devices and every sharded path here runs (slowly but
bit-exactly) on a laptop or CI runner.  That flag must be set *before*
jax first initialises its backends — export it in the environment or
re-exec, do not set it after ``import jax`` has run any computation.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

__all__ = [
    "RuntimeConfig",
    "global_config",
    "parse_mesh_spec",
    "make_serve_mesh",
    "HOST_DEVICES_RECIPE",
]

HOST_DEVICES_RECIPE = (
    "export XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(before the first jax import) to emulate 8 devices on a CPU host"
)


def _env_bool(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() in ("1", "true", "yes", "on")


class RuntimeConfig:
    """Process-wide knobs for the distributed serving runtime.

    Seeded from the environment once at import; mutate the singleton
    :data:`global_config` to override (CLI flags do exactly that).
    """

    def __init__(self) -> None:
        # "dp,tp" — e.g. "2,2".  Empty string = single-device serving
        # (no mesh is built, the engine takes the unsharded path).
        self.mesh_spec: str = os.environ.get("REPRO_MESH", "")
        # Shard long activations over "model" inside prefill (sequence
        # parallelism).  Off by default: decode steps are seq-len 1.
        self.seq_parallel: bool = _env_bool("REPRO_SEQ_PARALLEL", False)
        # Weight-shard replicated params over the data axes (ZeRO-3
        # style).  Serving default is off: params are read-only and
        # gather latency lands on every decode step.
        self.fsdp_params: bool = _env_bool("REPRO_FSDP", False)

    def describe(self) -> dict:
        """Loggable snapshot of every knob (alpa prints the same)."""
        return {
            "mesh_spec": self.mesh_spec,
            "seq_parallel": self.seq_parallel,
            "fsdp_params": self.fsdp_params,
        }


global_config = RuntimeConfig()


def parse_mesh_spec(spec: Optional[str]) -> Optional[Tuple[int, int]]:
    """``"dp,tp"`` -> ``(dp, tp)``; ``None``/``""`` -> ``None``.

    Accepts a bare ``"dp"`` as shorthand for ``(dp, 1)``.  Raises
    ``ValueError`` on anything non-positive or non-integer so a typo'd
    ``--mesh`` fails loudly instead of silently serving single-device.
    """
    if spec is None:
        return None
    spec = spec.strip()
    if not spec:
        return None
    parts = [p.strip() for p in spec.split(",")]
    if len(parts) > 2:
        raise ValueError(
            f"mesh spec {spec!r}: expected 'dp,tp' (at most two axes)"
        )
    try:
        dims = [int(p) for p in parts]
    except ValueError:
        raise ValueError(
            f"mesh spec {spec!r}: axes must be integers, e.g. '2,2'"
        ) from None
    if len(dims) == 1:
        dims.append(1)
    dp, tp = dims
    if dp < 1 or tp < 1:
        raise ValueError(f"mesh spec {spec!r}: axes must be >= 1")
    return dp, tp


def make_serve_mesh(spec: Optional[str] = None, *, devices=None):
    """Build the ``{data, model}`` serving :class:`jax.sharding.Mesh`.

    ``spec`` defaults to :data:`global_config`'s ``mesh_spec``; an empty
    spec returns ``None`` (single-device serving, no mesh).  The mesh
    takes the *first* ``dp*tp`` devices, so a 2x2 mesh works on an
    8-device host without claiming all of them (``jax.make_mesh`` by
    contrast insists on using every device).
    """
    if spec is None:
        spec = global_config.mesh_spec
    dims = parse_mesh_spec(spec)
    if dims is None:
        return None
    import numpy as np

    import jax

    dp, tp = dims
    if devices is None:
        devices = jax.devices()
    need = dp * tp
    if need > len(devices):
        raise ValueError(
            f"mesh {dp}x{tp} needs {need} devices but only "
            f"{len(devices)} are visible; {HOST_DEVICES_RECIPE}"
        )
    arr = np.asarray(devices[:need]).reshape(dp, tp)
    return jax.sharding.Mesh(arr, ("data", "model"))
