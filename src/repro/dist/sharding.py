"""Sharding rules: Megatron tensor parallelism + expert parallelism + LED
factor boundary specs + ZeRO/FSDP fallbacks.

``spec_for_param`` maps a dotted parameter path + shape to a
``PartitionSpec`` on a ``{data[, pod], model}`` mesh:

* column-parallel projections (q/k/v, up/gate, mamba in_proj, lm_head)
  shard their OUTPUT dim on "model"; their biases shard with the output;
* row-parallel projections (o_proj, down_proj, mamba out_proj) shard their
  INPUT dim on "model"; their biases are replicated (added post-reduce);
* LED factors shard at the low-rank boundary: a column-parallel layer keeps
  ``A`` replicated and shards ``B``'s output dim, a row-parallel layer
  shards ``A``'s input dim and keeps ``B`` replicated — the rank-r
  intermediate is never partitioned;
* stacked experts shard the expert axis on "model" (expert parallelism);
* the embedding table is vocab-parallel;
* any dim that does not divide its mesh axes is replicated instead
  (e.g. hymba's vocab 32001 on a 16-way TP mesh);
* ``fsdp=True`` additionally shards the first eligible remaining dim of
  LARGE params over the data axes (ZeRO-3 style).

``constrain_acts`` is a no-op outside an ``activation_mesh`` context, so
models call it unconditionally and single-device tests/benches never touch
device state.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from contextvars import ContextVar
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Minimum element count before FSDP bothers sharding a param over data.
FSDP_MIN_SIZE = 1 << 20

_COLUMN = {"q_proj", "k_proj", "v_proj", "up_proj", "gate_proj", "in_proj",
           "lm_head"}
_ROW = {"o_proj", "down_proj", "out_proj"}


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _data_entry(axes: Sequence[str]):
    return axes[0] if len(axes) == 1 else tuple(axes)


def batch_spec(mesh) -> P:
    """Batch-dim spec over every data-parallel mesh axis."""
    return P(_data_entry(_data_axes(mesh)))


def spec_for_param(path: str, shape: Tuple[int, ...], mesh,
                   fsdp: bool = False) -> P:
    """PartitionSpec for one parameter (see module docstring for rules)."""
    tp = mesh.shape.get("model", 1)
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    nd = len(shape)
    spec: list = [None] * nd
    parts = path.strip(".").split(".")
    leaf = parts[-1]
    owner = parts[-2] if len(parts) > 1 else ""

    if ".experts." in f".{path}." and nd >= 3:
        e_ax = nd - 3  # (..., E, in, out) / (..., E, in, r)
        if tp > 1 and shape[e_ax] % tp == 0:
            spec[e_ax] = "model"
    elif owner == "embed" and leaf == "weight":
        if tp > 1 and shape[0] % tp == 0:  # vocab-parallel table
            spec[0] = "model"
    elif owner in _COLUMN:
        if leaf in ("weight", "B", "bias") and tp > 1 and shape[-1] % tp == 0:
            spec[-1] = "model"  # output dim; A stays replicated
    elif owner in _ROW:
        if leaf in ("weight", "A") and nd >= 2 and tp > 1 \
                and shape[-2] % tp == 0:
            spec[-2] = "model"  # input dim; bias/B stay replicated
    # everything else (norms, routers, ssm params, pos embeddings): replicated

    if fsdp and dp > 1 and math.prod(shape) >= FSDP_MIN_SIZE:
        for i in range(nd):
            if spec[i] is None and shape[i] % dp == 0:
                spec[i] = _data_entry(data_axes)
                break
    return P(*spec)


def model_shardings(model, mesh, *, fsdp: bool = False):
    """NamedSharding tree mirroring ``model`` (arrays or SDS stand-ins)."""

    def _path_str(key_path) -> str:
        out = []
        for k in key_path:
            if hasattr(k, "name"):
                out.append(str(k.name))
            elif hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            else:
                out.append(str(k).strip(".[]'\""))
        return ".".join(out)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, spec_for_param(_path_str(kp), leaf.shape, mesh, fsdp=fsdp)),
        model)


def data_sharding(mesh, shape: Tuple[int, ...]) -> NamedSharding:
    """Shard dim 0 (batch) over the data axes; replicate the rest."""
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    spec: list = [None] * len(shape)
    if shape and dp > 1 and shape[0] % dp == 0:
        spec[0] = _data_entry(data_axes)
    return NamedSharding(mesh, P(*spec))


def cache_specs(cache, mesh):
    """PartitionSpec tree for a decode/prefill cache (see cache_shardings).

    Two layouts are distinguished by structure:

    * **paged** (a NamedTuple with a ``table`` field): the block pool k/v
      are (layers, n_blocks, block_size, kv_heads, head_dim) — the pool is
      GLOBAL over data (every data shard holds the full pool; the host-side
      allocator hands out block ids with no notion of placement) and its
      kv-head dim shards over "model".  The block table (batch, max_table)
      and write frontier (layers, batch) shard their batch dim over data.
    * **dense / ring / ssm** (everything else): per-slot lanes are
      (layers, batch, ...) so dim 1 shards over data, and any trailing
      (..., kv_heads, head_dim) lane shards its head dim over "model".

    Any non-divisible dim — e.g. GQA kv_heads=3 on a 2-way model axis —
    falls back to replication for that dim.
    """
    tp = mesh.shape.get("model", 1)
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    data = _data_entry(data_axes) if data_axes else None

    fields = getattr(cache, "_fields", None)
    if fields is not None and "table" in fields:
        def pool(leaf) -> P:
            s: list = [None] * len(leaf.shape)
            if len(leaf.shape) >= 2 and tp > 1 and leaf.shape[-2] % tp == 0:
                s[-2] = "model"
            return P(*s)

        def batch_dim(leaf, dim: int) -> P:
            s: list = [None] * len(leaf.shape)
            if dp > 1 and leaf.shape[dim] % dp == 0:
                s[dim] = data
            return P(*s)

        return type(cache)(
            k=pool(cache.k),
            v=pool(cache.v),
            table=batch_dim(cache.table, 0),
            length=batch_dim(cache.length, len(cache.length.shape) - 1),
        )

    def spec(leaf) -> P:
        shape = leaf.shape
        s: list = [None] * len(shape)
        if len(shape) >= 2 and dp > 1 and shape[1] % dp == 0:
            s[1] = data
        if len(shape) >= 4 and tp > 1 and shape[-2] % tp == 0:
            s[-2] = "model"
        return P(*s)

    return jax.tree_util.tree_map(spec, cache)


def cache_shardings(cache, mesh):
    """Decode/prefill cache shardings: batch over data, heads over model.

    NamedSharding tree over :func:`cache_specs` — see there for the
    paged-vs-dense layout rules.  KV lanes are
    (layers, batch, slots, kv_heads, head_dim); SSM/conv states are
    (layers, batch, ...).  Any non-divisible dim is replicated."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), cache_specs(cache, mesh),
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------

# (mesh, seq_parallel) inside an activation_mesh scope.  A ContextVar, not
# a module global: BackgroundServer traces engine steps off the main
# thread, and a module global set on one thread would leak the mesh into
# (or hide it from) traces running concurrently on another.  Each thread
# starts with a fresh context, so scopes are strictly per-thread/per-task.
_ACTIVE: ContextVar[Optional[tuple]] = ContextVar(
    "repro_activation_mesh", default=None)


@contextmanager
def activation_mesh(mesh, seq_parallel: bool = False):
    """Enable activation sharding constraints for traces under this scope."""
    token = _ACTIVE.set((mesh, seq_parallel))
    try:
        yield mesh
    finally:
        _ACTIVE.reset(token)


def active_activation_mesh() -> Optional[tuple]:
    """The ``(mesh, seq_parallel)`` of the innermost :func:`activation_mesh`
    scope on THIS thread/task — exactly what :func:`constrain_acts` will
    read — or ``None`` outside any scope."""
    return _ACTIVE.get()


def constrain_acts(x: jax.Array) -> jax.Array:
    """Constrain (batch, seq, d_model) activations between blocks.

    Batch shards over the data axes; with sequence parallelism the seq dim
    additionally shards over "model".  Outside an :func:`activation_mesh`
    scope this is the identity (returns ``x`` itself)."""
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, seq_parallel = active
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    tp = mesh.shape.get("model", 1)
    spec: list = [None] * x.ndim
    if x.ndim >= 1 and dp > 1 and x.shape[0] % dp == 0:
        spec[0] = _data_entry(data_axes)
    if seq_parallel and x.ndim >= 2 and tp > 1 and x.shape[1] % tp == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


__all__ = ["batch_spec", "spec_for_param", "model_shardings", "data_sharding",
           "cache_specs", "cache_shardings", "activation_mesh",
           "active_activation_mesh", "constrain_acts", "FSDP_MIN_SIZE"]
