"""Sharding rules: Megatron tensor parallelism + expert parallelism + LED
factor boundary specs + ZeRO/FSDP fallbacks.

``spec_for_param`` maps a dotted parameter path + shape to a
``PartitionSpec`` on a ``{data[, pod], model}`` mesh:

* column-parallel projections (q/k/v, up/gate, mamba in_proj, lm_head)
  shard their OUTPUT dim on "model"; their biases shard with the output;
* row-parallel projections (o_proj, down_proj, mamba out_proj) shard their
  INPUT dim on "model"; their biases are replicated (added post-reduce);
* LED factors shard at the low-rank boundary: a column-parallel layer keeps
  ``A`` replicated and shards ``B``'s output dim, a row-parallel layer
  shards ``A``'s input dim and keeps ``B`` replicated — the rank-r
  intermediate is never partitioned;
* stacked experts shard the expert axis on "model" (expert parallelism);
* the embedding table is vocab-parallel;
* any dim that does not divide its mesh axes is replicated instead
  (e.g. hymba's vocab 32001 on a 16-way TP mesh);
* ``fsdp=True`` additionally shards the first eligible remaining dim of
  LARGE params over the data axes (ZeRO-3 style).

``constrain_acts`` is a no-op outside an ``activation_mesh`` context, so
models call it unconditionally and single-device tests/benches never touch
device state.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Minimum element count before FSDP bothers sharding a param over data.
FSDP_MIN_SIZE = 1 << 20

_COLUMN = {"q_proj", "k_proj", "v_proj", "up_proj", "gate_proj", "in_proj",
           "lm_head"}
_ROW = {"o_proj", "down_proj", "out_proj"}


def _data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _data_entry(axes: Sequence[str]):
    return axes[0] if len(axes) == 1 else tuple(axes)


def batch_spec(mesh) -> P:
    """Batch-dim spec over every data-parallel mesh axis."""
    return P(_data_entry(_data_axes(mesh)))


def spec_for_param(path: str, shape: Tuple[int, ...], mesh,
                   fsdp: bool = False) -> P:
    """PartitionSpec for one parameter (see module docstring for rules)."""
    tp = mesh.shape.get("model", 1)
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    nd = len(shape)
    spec: list = [None] * nd
    parts = path.strip(".").split(".")
    leaf = parts[-1]
    owner = parts[-2] if len(parts) > 1 else ""

    if ".experts." in f".{path}." and nd >= 3:
        e_ax = nd - 3  # (..., E, in, out) / (..., E, in, r)
        if tp > 1 and shape[e_ax] % tp == 0:
            spec[e_ax] = "model"
    elif owner == "embed" and leaf == "weight":
        if tp > 1 and shape[0] % tp == 0:  # vocab-parallel table
            spec[0] = "model"
    elif owner in _COLUMN:
        if leaf in ("weight", "B", "bias") and tp > 1 and shape[-1] % tp == 0:
            spec[-1] = "model"  # output dim; A stays replicated
    elif owner in _ROW:
        if leaf in ("weight", "A") and nd >= 2 and tp > 1 \
                and shape[-2] % tp == 0:
            spec[-2] = "model"  # input dim; bias/B stay replicated
    # everything else (norms, routers, ssm params, pos embeddings): replicated

    if fsdp and dp > 1 and math.prod(shape) >= FSDP_MIN_SIZE:
        for i in range(nd):
            if spec[i] is None and shape[i] % dp == 0:
                spec[i] = _data_entry(data_axes)
                break
    return P(*spec)


def model_shardings(model, mesh, *, fsdp: bool = False):
    """NamedSharding tree mirroring ``model`` (arrays or SDS stand-ins)."""

    def _path_str(key_path) -> str:
        out = []
        for k in key_path:
            if hasattr(k, "name"):
                out.append(str(k.name))
            elif hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            else:
                out.append(str(k).strip(".[]'\""))
        return ".".join(out)

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, spec_for_param(_path_str(kp), leaf.shape, mesh, fsdp=fsdp)),
        model)


def data_sharding(mesh, shape: Tuple[int, ...]) -> NamedSharding:
    """Shard dim 0 (batch) over the data axes; replicate the rest."""
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    spec: list = [None] * len(shape)
    if shape and dp > 1 and shape[0] % dp == 0:
        spec[0] = _data_entry(data_axes)
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cache, mesh):
    """Decode/prefill cache shardings: batch over data, heads over model.

    KV lanes are (layers, batch, slots, kv_heads, head_dim); SSM/conv states
    are (layers, batch, ...).  Any non-divisible dim is replicated."""
    tp = mesh.shape.get("model", 1)
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1

    def spec(leaf):
        shape = leaf.shape
        s: list = [None] * len(shape)
        if len(shape) >= 2 and dp > 1 and shape[1] % dp == 0:
            s[1] = _data_entry(data_axes)
        if len(shape) >= 4 and tp > 1 and shape[-2] % tp == 0:
            s[-2] = "model"
        return NamedSharding(mesh, P(*s))

    return jax.tree_util.tree_map(spec, cache)


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------

_ACTIVE: Optional[tuple] = None  # (mesh, seq_parallel) inside activation_mesh


@contextmanager
def activation_mesh(mesh, seq_parallel: bool = False):
    """Enable activation sharding constraints for traces under this scope."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = (mesh, seq_parallel)
    try:
        yield mesh
    finally:
        _ACTIVE = prev


def constrain_acts(x: jax.Array) -> jax.Array:
    """Constrain (batch, seq, d_model) activations between blocks.

    Batch shards over the data axes; with sequence parallelism the seq dim
    additionally shards over "model".  Outside an :func:`activation_mesh`
    scope this is the identity (returns ``x`` itself)."""
    if _ACTIVE is None:
        return x
    mesh, seq_parallel = _ACTIVE
    data_axes = _data_axes(mesh)
    dp = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    tp = mesh.shape.get("model", 1)
    spec: list = [None] * x.ndim
    if x.ndim >= 1 and dp > 1 and x.shape[0] % dp == 0:
        spec[0] = _data_entry(data_axes)
    if seq_parallel and x.ndim >= 2 and tp > 1 and x.shape[1] % tp == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


__all__ = ["batch_spec", "spec_for_param", "model_shardings", "data_sharding",
           "cache_shardings", "activation_mesh", "constrain_acts",
           "FSDP_MIN_SIZE"]
