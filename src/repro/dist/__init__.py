from repro.dist.runtime import (RuntimeConfig, global_config, make_serve_mesh,
                                parse_mesh_spec)
from repro.dist.sharding import (activation_mesh, batch_spec, cache_shardings,
                                 cache_specs, constrain_acts, data_sharding,
                                 model_shardings, spec_for_param)

__all__ = ["activation_mesh", "batch_spec", "cache_shardings", "cache_specs",
           "constrain_acts", "data_sharding", "model_shardings",
           "spec_for_param", "RuntimeConfig", "global_config",
           "make_serve_mesh", "parse_mesh_spec"]
