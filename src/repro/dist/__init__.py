from repro.dist.sharding import (activation_mesh, batch_spec, cache_shardings,
                                 constrain_acts, data_sharding,
                                 model_shardings, spec_for_param)

__all__ = ["activation_mesh", "batch_spec", "cache_shardings",
           "constrain_acts", "data_sharding", "model_shardings",
           "spec_for_param"]
