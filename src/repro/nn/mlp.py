"""Feed-forward blocks: SwiGLU (llama family) and GeLU (whisper/classic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.linear import Linear
from repro.nn.module import Module, static_field


class SwiGLU(Module):
    gate_proj: Linear
    up_proj: Linear
    down_proj: Linear

    @staticmethod
    def create(key, dim: int, hidden: int, *, dtype=jnp.float32,
               stack_dims: tuple = ()) -> "SwiGLU":
        kg, ku, kd = jax.random.split(key, 3)
        return SwiGLU(
            gate_proj=Linear.create(kg, dim, hidden, dtype=dtype, stack_dims=stack_dims),
            up_proj=Linear.create(ku, dim, hidden, dtype=dtype, stack_dims=stack_dims),
            down_proj=Linear.create(kd, hidden, dim, dtype=dtype, stack_dims=stack_dims),
        )

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.down_proj(jax.nn.silu(self.gate_proj(x)) * self.up_proj(x))


class GeluMLP(Module):
    up_proj: Linear
    down_proj: Linear

    @staticmethod
    def create(key, dim: int, hidden: int, *, use_bias: bool = True,
               dtype=jnp.float32) -> "GeluMLP":
        ku, kd = jax.random.split(key)
        return GeluMLP(
            up_proj=Linear.create(ku, dim, hidden, use_bias=use_bias, dtype=dtype),
            down_proj=Linear.create(kd, hidden, dim, use_bias=use_bias, dtype=dtype),
        )

    def __call__(self, x: jax.Array) -> jax.Array:
        return self.down_proj(jax.nn.gelu(self.up_proj(x)))
