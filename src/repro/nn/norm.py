"""Normalization layers."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, static_field


class RMSNorm(Module):
    scale: jax.Array
    eps: float = static_field(default=1e-6)

    @staticmethod
    def create(dim: int, *, eps: float = 1e-6, dtype=jnp.float32) -> "RMSNorm":
        return RMSNorm(scale=jnp.ones((dim,), dtype), eps=eps)

    def __call__(self, x: jax.Array) -> jax.Array:
        orig_dtype = x.dtype
        x = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + self.eps)
        return (x * self.scale.astype(jnp.float32)).astype(orig_dtype)


class LayerNorm(Module):
    scale: jax.Array
    bias: Optional[jax.Array]
    eps: float = static_field(default=1e-5)

    @staticmethod
    def create(dim: int, *, eps: float = 1e-5, use_bias: bool = True,
               dtype=jnp.float32) -> "LayerNorm":
        bias = jnp.zeros((dim,), dtype) if use_bias else None
        return LayerNorm(scale=jnp.ones((dim,), dtype), bias=bias, eps=eps)

    def __call__(self, x: jax.Array) -> jax.Array:
        orig_dtype = x.dtype
        x = x.astype(jnp.float32)
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        x = (x - mean) * jax.lax.rsqrt(var + self.eps)
        x = x * self.scale.astype(jnp.float32)
        if self.bias is not None:
            x = x + self.bias.astype(jnp.float32)
        return x.astype(orig_dtype)
