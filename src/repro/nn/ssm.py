"""Mamba-2 (SSD — state-space duality) mixer.

Implements the chunked SSD algorithm (Dao & Gu, 2024) in pure JAX:
  * training / prefill: chunk-parallel form — quadratic attention *within*
    chunks, linear state recurrence *across* chunks (a ``jax.lax`` scan-free
    cumulative formulation over the chunk axis via associative decay products).
  * decode: O(1) recurrent state update per token.

Shapes follow the reference implementation: ``d_inner = expand · d_model``,
``n_heads = d_inner / head_dim``, scalar decay ``A`` per head, ``B``/``C``
shared across heads per group (``n_groups`` groups), state size ``N``.

The in/out projections are ``Linear`` modules — the factorization target for
Greenformer on this architecture (the SSD scan itself is weight-free apart
from the scalar decays; see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.nn.linear import Linear
from repro.nn.module import Module, static_field
from repro.nn.norm import RMSNorm


class SSMState(NamedTuple):
    conv: jax.Array  # (batch, conv_width - 1, conv_dim) rolling conv buffer
    ssm: jax.Array  # (batch, heads, head_dim, state) recurrent state


class SSMCache(NamedTuple):
    """Per-slot serving state for continuous batching (``repro.serve``).

    The same rolling conv buffer + recurrent state as :class:`SSMState`,
    layer-stacked and carrying an explicit per-slot position counter so
    the engine can drive slots at independent positions (the counter is
    bookkeeping only — the recurrence itself is position-free, which is
    why decode memory is O(1) per slot).  Slot recycling needs no reset
    pass: the first prefill chunk of a new request (``offset == 0``)
    zeros the slot's conv/ssm lanes in-graph before scanning in."""

    conv: jax.Array  # (n_layers, batch, conv_width - 1, conv_dim)
    ssm: jax.Array  # (n_layers, batch, heads, head_dim, state)
    length: jax.Array  # (n_layers, batch) int32 — absolute position


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k] (−inf j>i)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{j+1..i} for i >= j
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


class Mamba2Mixer(Module):
    in_proj: Linear  # dim -> 2*d_inner + 2*groups*state + heads
    out_proj: Linear  # d_inner -> dim
    conv_w: jax.Array  # (conv_width, conv_dim) depthwise causal conv
    conv_b: jax.Array  # (conv_dim,)
    A_log: jax.Array  # (heads,)
    D: jax.Array  # (heads,)
    dt_bias: jax.Array  # (heads,)
    gate_norm: RMSNorm
    d_inner: int = static_field(default=0)
    n_heads: int = static_field(default=0)
    head_dim: int = static_field(default=64)
    n_groups: int = static_field(default=1)
    d_state: int = static_field(default=128)
    conv_width: int = static_field(default=4)
    chunk: int = static_field(default=128)

    @staticmethod
    def create(key, dim: int, *, expand: int = 2, head_dim: int = 64,
               d_state: int = 128, n_groups: int = 1, conv_width: int = 4,
               chunk: int = 128, dtype=jnp.float32) -> "Mamba2Mixer":
        d_inner = expand * dim
        n_heads = d_inner // head_dim
        conv_dim = d_inner + 2 * n_groups * d_state
        d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
        ki, ko, kc, ka = jax.random.split(key, 4)
        return Mamba2Mixer(
            in_proj=Linear.create(ki, dim, d_in_proj, dtype=dtype),
            out_proj=Linear.create(ko, d_inner, dim, dtype=dtype),
            conv_w=0.1 * jax.random.normal(kc, (conv_width, conv_dim), dtype),
            conv_b=jnp.zeros((conv_dim,), dtype),
            A_log=jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(dtype)),
            D=jnp.ones((n_heads,), dtype),
            dt_bias=jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01, dtype))),
            gate_norm=RMSNorm.create(d_inner, dtype=dtype),
            d_inner=d_inner, n_heads=n_heads, head_dim=head_dim,
            n_groups=n_groups, d_state=d_state, conv_width=conv_width,
            chunk=chunk,
        )

    # -- projection plumbing -------------------------------------------------

    def _split(self, zxbcdt):
        di, g, n, h = self.d_inner, self.n_groups, self.d_state, self.n_heads
        z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * n], axis=-1)
        return z, xbc, dt

    def _conv(self, xbc):
        """Causal depthwise conv along seq. xbc: (b, l, conv_dim)."""
        w = self.conv_w.astype(xbc.dtype)
        pad = self.conv_width - 1
        xp = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))
        out = sum(
            xp[:, i:i + xbc.shape[1], :] * w[i] for i in range(self.conv_width)
        )
        return jax.nn.silu(out + self.conv_b.astype(xbc.dtype))

    def _split_xbc(self, xbc):
        di, g, n, h, p = (self.d_inner, self.n_groups, self.d_state,
                          self.n_heads, self.head_dim)
        x, B, C = jnp.split(xbc, [di, di + g * n], axis=-1)
        b, l = x.shape[:2]
        x = x.reshape(b, l, h, p)
        B = B.reshape(b, l, g, n)
        C = C.reshape(b, l, g, n)
        return x, B, C

    # -- chunked SSD (training / prefill) ------------------------------------

    def _ssd(self, x, dt, B, C, initial_state=None):
        """Chunked SSD. x: (b,l,h,p); dt: (b,l,h); B/C: (b,l,g,n).

        ``initial_state`` (b,h,p,n) seeds the inter-chunk recurrence —
        the chunked scan-in path for serving feeds a prompt span at a
        time, carrying the state between spans.

        Returns y: (b,l,h,p) and the final state (b,h,p,n).
        """
        b, l_orig, h, p = x.shape
        g, n = self.n_groups, self.d_state
        q = min(self.chunk, l_orig) if l_orig % self.chunk else self.chunk
        pad = (-l_orig) % q
        if pad:
            # pad with "no-op" steps: x=0 (no contribution) and raw dt=-30 so
            # softplus(dt)≈0 => decay exp(0)=1 => the final state is exact.
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                         constant_values=-30.0)
            B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
            C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l_orig + pad
        nc = l // q
        A = -jnp.exp(self.A_log.astype(jnp.float32))  # (h,)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + self.dt_bias)  # (b,l,h)
        a = dt * A  # (b,l,h) log-decay per step
        rep = h // g

        # reshape into chunks
        xc = x.reshape(b, nc, q, h, p)
        ac = a.reshape(b, nc, q, h)
        dtc = dt.reshape(b, nc, q, h)
        Bc = B.reshape(b, nc, q, g, n)
        Cc = C.reshape(b, nc, q, g, n)

        # --- intra-chunk (quadratic) ---
        L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (b,nc,h,q,q)
        scores = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)  # (b,nc,g,q,q)
        scores = jnp.repeat(scores, rep, axis=2)  # (b,nc,h,q,q)
        M = scores * L * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
        y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M.astype(x.dtype), xc)

        # --- chunk states ---
        a_cum = jnp.cumsum(ac, axis=2)  # (b,nc,q,h)
        a_tot = a_cum[:, :, -1, :]  # (b,nc,h)
        decay_to_end = jnp.exp(a_tot[:, :, None, :] - a_cum)  # (b,nc,q,h)
        # S_c = sum_k decay_to_end * dt * B_k ⊗ x_k  -> (b,nc,h,p,n)
        wB = (Bc[:, :, :, :, None, :]  # (b,nc,q,g,1,n)
              .repeat(rep, axis=4).reshape(b, nc, q, h, n))
        states = jnp.einsum(
            "bcqh,bcqhp,bcqhn->bchpn",
            (decay_to_end * dtc).astype(x.dtype), xc, wB.astype(x.dtype))

        # --- inter-chunk recurrence over chunk states (scan) ---
        def step(carry, inp):
            s_prev = carry
            s_c, atot = inp
            s_new = s_prev * jnp.exp(atot)[:, :, None, None].astype(s_prev.dtype) + s_c
            return s_new, s_prev  # emit state *entering* the chunk

        s0 = (jnp.zeros((b, h, p, n), x.dtype) if initial_state is None
              else initial_state.astype(x.dtype))
        final, s_in = jax.lax.scan(
            step, s0, (states.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2)))
        s_in = s_in.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

        # --- inter-chunk contribution ---
        decay_from_start = jnp.exp(a_cum)  # (b,nc,q,h)
        wC = (Cc[:, :, :, :, None, :].repeat(rep, axis=4).reshape(b, nc, q, h, n))
        y_inter = jnp.einsum(
            "bcqhn,bchpn,bcqh->bcqhp", wC.astype(x.dtype), s_in,
            decay_from_start.astype(x.dtype))

        y = (y_intra + y_inter).reshape(b, l, h, p)
        y = y + x * self.D.astype(x.dtype)[None, None, :, None]
        return y[:, :l_orig], final

    # -- public paths ---------------------------------------------------------

    def __call__(self, u: jax.Array) -> jax.Array:
        y, _ = self.forward_with_state(u)
        return y

    def forward_with_state(self, u: jax.Array):
        z, xbc, dt = self._split(self.in_proj(u))
        xbc = self._conv(xbc)
        x, B, C = self._split_xbc(xbc)
        y, state = self._ssd(x, dt, B, C)
        y = y.reshape(u.shape[0], u.shape[1], self.d_inner)
        y = self.gate_norm(y) * jax.nn.silu(z)
        return self.out_proj(y), state

    def init_state(self, batch: int, dtype=jnp.float32) -> SSMState:
        conv_dim = self.d_inner + 2 * self.n_groups * self.d_state
        return SSMState(
            conv=jnp.zeros((batch, self.conv_width - 1, conv_dim), dtype),
            ssm=jnp.zeros((batch, self.n_heads, self.head_dim, self.d_state),
                          dtype),
        )

    def prefill_chunk(self, u: jax.Array, state: SSMState, *,
                      n_valid: jax.Array):
        """Scan one padded prompt chunk into a carried state.

        ``u``: (1, W, dim) — the first ``n_valid`` rows are real tokens,
        the rest right-padding.  The depthwise conv reads its left context
        from ``state.conv`` (instead of zero padding), padding rows are
        routed to exact no-ops before the SSD scan (``x = 0`` and raw
        ``dt = -30`` => softplus ≈ 1e-13 => decay rounds to exactly 1.0 in
        fp32, so the carried state is unaffected bit-for-bit), and the new
        conv tail is sliced at the REAL frontier ``n_valid`` — feeding a
        prompt in any chunking yields the same carried state as one
        monolithic prefill up to fp summation order.

        Returns ``(chunk outputs (1, W, dim), updated SSMState)``."""
        b, W, _ = u.shape
        z, xbc, dt = self._split(self.in_proj(u))
        buf = jnp.concatenate([state.conv.astype(xbc.dtype), xbc], axis=1)
        w = self.conv_w.astype(xbc.dtype)
        conv = sum(buf[:, i:i + W, :] * w[i] for i in range(self.conv_width))
        xbc_c = jax.nn.silu(conv + self.conv_b.astype(xbc.dtype))
        x, B, C = self._split_xbc(xbc_c)
        live = jnp.arange(W) < n_valid
        x = jnp.where(live[None, :, None, None], x, 0.0)
        dt = jnp.where(live[None, :, None], dt, -30.0)
        y, final = self._ssd(x, dt, B, C, initial_state=state.ssm)
        y = y.reshape(b, W, self.d_inner)
        y = self.gate_norm(y) * jax.nn.silu(z)
        tail = jax.lax.dynamic_slice_in_dim(buf, n_valid,
                                            self.conv_width - 1, axis=1)
        new_state = SSMState(conv=tail.astype(state.conv.dtype),
                             ssm=final.astype(state.ssm.dtype))
        return self.out_proj(y), new_state

    def decode(self, u: jax.Array, state: SSMState):
        """One-token recurrent step. u: (b, 1, dim)."""
        b = u.shape[0]
        z, xbc, dt = self._split(self.in_proj(u))
        # rolling conv buffer
        window = jnp.concatenate([state.conv, xbc], axis=1)  # (b, w, conv_dim)
        w = self.conv_w.astype(u.dtype)
        conv_out = jnp.einsum("bwc,wc->bc", window, w) + self.conv_b.astype(u.dtype)
        xbc_t = jax.nn.silu(conv_out)[:, None, :]
        x, B, C = self._split_xbc(xbc_t)  # x: (b,1,h,p)
        A = -jnp.exp(self.A_log.astype(jnp.float32))
        dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + self.dt_bias)  # (b,h)
        decay = jnp.exp(dt_t * A)  # (b,h)
        rep = self.n_heads // self.n_groups
        Bh = jnp.repeat(B[:, 0], rep, axis=1)  # (b,h,n)
        Ch = jnp.repeat(C[:, 0], rep, axis=1)
        dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt_t.astype(u.dtype),
                         Bh.astype(u.dtype), x[:, 0])
        ssm = state.ssm * decay[:, :, None, None].astype(state.ssm.dtype) + dBx
        y = jnp.einsum("bhpn,bhn->bhp", ssm, Ch.astype(u.dtype))
        y = y + x[:, 0] * self.D.astype(u.dtype)[None, :, None]
        y = y.reshape(b, 1, self.d_inner)
        y = self.gate_norm(y) * jax.nn.silu(z)
        new_state = SSMState(conv=window[:, 1:], ssm=ssm)
        return self.out_proj(y), new_state
