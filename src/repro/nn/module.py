"""Pytree-registered module system (equinox-style, dependency-free).

A ``Module`` is a frozen dataclass automatically registered as a JAX pytree.
Fields are pytree *children* unless declared with :func:`static_field`, in
which case they are part of the treedef (hashable aux data).  This gives the
PyTorch-like "walk the module tree and swap layers" ergonomics that
Greenformer's ``auto_fact`` needs, while remaining fully jit/pjit/scan
compatible.

Design notes
------------
* Modules are immutable; functional updates go through ``dataclasses.replace``
  or :func:`update`.
* ``flatten_with_keys`` is used so sharding rules and ``auto_fact`` filters can
  pattern-match on dotted parameter paths (e.g. ``"blocks.attn.q_proj.weight"``).
* Containers (list/tuple/dict) of sub-modules are supported transparently as
  ordinary pytree nodes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, Tuple

import jax
import jax.numpy as jnp


def static_field(**kwargs) -> Any:
    """A dataclass field stored as static (non-traced) pytree aux data."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata["static"] = True
    return dataclasses.field(metadata=metadata, **kwargs)


def _data_fields(cls) -> list:
    return [f for f in dataclasses.fields(cls) if not f.metadata.get("static", False)]


def _static_fields(cls) -> list:
    return [f for f in dataclasses.fields(cls) if f.metadata.get("static", False)]


class Module:
    """Base class.  Subclasses are turned into frozen dataclasses + pytrees."""

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        dataclasses.dataclass(frozen=True, repr=False)(cls)

        def flatten_with_keys(obj):
            children = tuple(
                (jax.tree_util.GetAttrKey(f.name), getattr(obj, f.name))
                for f in _data_fields(cls)
            )
            aux = tuple(getattr(obj, f.name) for f in _static_fields(cls))
            return children, aux

        def flatten(obj):
            children = tuple(getattr(obj, f.name) for f in _data_fields(cls))
            aux = tuple(getattr(obj, f.name) for f in _static_fields(cls))
            return children, aux

        def unflatten(aux, children):
            obj = object.__new__(cls)
            for f, v in zip(_data_fields(cls), children):
                object.__setattr__(obj, f.name, v)
            for f, v in zip(_static_fields(cls), aux):
                object.__setattr__(obj, f.name, v)
            return obj

        jax.tree_util.register_pytree_with_keys(
            cls, flatten_with_keys, unflatten, flatten_func=flatten
        )

    # -- ergonomics ---------------------------------------------------------

    def replace(self, **changes) -> "Module":
        return dataclasses.replace(self, **changes)

    def __repr__(self) -> str:  # compact, avoids dumping full arrays
        parts = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, (jnp.ndarray, jax.Array)):
                parts.append(f"{f.name}={v.dtype}{list(v.shape)}")
            else:
                parts.append(f"{f.name}={v!r}")
        return f"{type(self).__name__}({', '.join(parts)})"


# ---------------------------------------------------------------------------
# Tree surgery: the traversal primitive behind auto_fact and sharding rules.
# ---------------------------------------------------------------------------


def iter_modules(root: Any, path: str = "") -> Iterator[Tuple[str, Module]]:
    """Depth-first iteration over every ``Module`` in ``root`` with dotted paths."""
    if isinstance(root, Module):
        yield path, root
        for f in _data_fields(type(root)):
            sub = getattr(root, f.name)
            child_path = f"{path}.{f.name}" if path else f.name
            yield from iter_modules(sub, child_path)
    elif isinstance(root, (list, tuple)):
        for i, sub in enumerate(root):
            yield from iter_modules(sub, f"{path}.{i}" if path else str(i))
    elif isinstance(root, dict):
        for k, sub in root.items():
            yield from iter_modules(sub, f"{path}.{k}" if path else str(k))


def map_modules(
    root: Any,
    fn: Callable[[str, Module], Any],
    path: str = "",
) -> Any:
    """Rebuild a module tree, letting ``fn(path, module)`` substitute nodes.

    ``fn`` is called on every ``Module`` node (pre-order).  If it returns a
    value that is not the module itself, that value replaces the node and
    recursion stops there; otherwise recursion continues into children.
    """
    if isinstance(root, Module):
        replacement = fn(path, root)
        if replacement is not root:
            return replacement
        changes = {}
        for f in _data_fields(type(root)):
            sub = getattr(root, f.name)
            child_path = f"{path}.{f.name}" if path else f.name
            new_sub = map_modules(sub, fn, child_path)
            if new_sub is not sub:
                changes[f.name] = new_sub
        return dataclasses.replace(root, **changes) if changes else root
    if isinstance(root, (list, tuple)):
        new = [
            map_modules(sub, fn, f"{path}.{i}" if path else str(i))
            for i, sub in enumerate(root)
        ]
        if all(a is b for a, b in zip(new, root)):
            return root
        return type(root)(new)
    if isinstance(root, dict):
        new = {
            k: map_modules(sub, fn, f"{path}.{k}" if path else str(k))
            for k, sub in root.items()
        }
        if all(new[k] is root[k] for k in root):
            return root
        return new
    return root


def named_parameters(root: Any) -> Iterator[Tuple[str, jax.Array]]:
    """Yield ``(dotted_path, array)`` for every array leaf."""
    leaves = jax.tree_util.tree_flatten_with_path(root)[0]
    for key_path, leaf in leaves:
        if leaf is None:
            continue
        name = ".".join(_key_str(k) for k in key_path)
        yield name, leaf


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.GetAttrKey):
        return k.name
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    return str(k)


def param_count(root: Any) -> int:
    return sum(
        leaf.size
        for leaf in jax.tree_util.tree_leaves(root)
        if hasattr(leaf, "size")
    )


def tree_slice(root: Any, i) -> Any:
    """Index the leading axis of every array leaf (for scan-over-layers)."""
    return jax.tree_util.tree_map(lambda x: x[i], root)
