"""Hymba-style hybrid block: parallel attention heads + SSM heads.

Each block runs an attention path and a Mamba-2 SSD path *in parallel* on the
same (normalized) input; the two outputs are per-path RMS-normalized and
averaged (the fusion used by Hymba, arXiv:2411.13676).  Most layers use
sliding-window attention; every ``global_every``-th layer is global.

Simplifications vs. the paper (recorded in DESIGN.md): no meta tokens, no
cross-layer KV sharing — neither changes the compute/communication shape the
roofline measures.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn.attention import Attention, KVCache
from repro.nn.module import Module
from repro.nn.norm import RMSNorm
from repro.nn.ssm import Mamba2Mixer, SSMState


class HybridState(NamedTuple):
    kv: KVCache
    ssm: SSMState


class HybridCache(NamedTuple):
    """Per-slot serving state for continuous batching: the attention
    path's ring-buffer (or dense) KV lanes plus the SSM path's conv/ssm
    state, layer-stacked, sharing one per-slot position counter.

    When the config uses sliding-window attention, ``k``/``v`` hold
    exactly ``window`` slots per lane (``slot(p) = p % window`` — decode
    memory O(window) per slot regardless of context length); otherwise a
    dense ``max_len`` lane.  ``length`` drives both the ring write lane
    and the SSM bookkeeping."""

    k: jax.Array  # (n_layers, batch, slots, kv_heads, head_dim)
    v: jax.Array  # (n_layers, batch, slots, kv_heads, head_dim)
    conv: jax.Array  # (n_layers, batch, conv_width - 1, conv_dim)
    ssm: jax.Array  # (n_layers, batch, heads, head_dim, state)
    length: jax.Array  # (n_layers, batch) int32 — absolute position


class HybridMixer(Module):
    attn: Attention
    ssm: Mamba2Mixer
    attn_norm: RMSNorm
    ssm_norm: RMSNorm

    @staticmethod
    def create(key, dim: int, num_heads: int, num_kv_heads: int, *,
               head_dim: Optional[int] = None, window: int = 0,
               ssm_state: int = 16, ssm_head_dim: int = 64, chunk: int = 0,
               dtype=jnp.float32) -> "HybridMixer":
        ka, ks = jax.random.split(key)
        return HybridMixer(
            attn=Attention.create(ka, dim, num_heads, num_kv_heads,
                                  head_dim=head_dim, window=window,
                                  chunk=chunk, dtype=dtype),
            ssm=Mamba2Mixer.create(ks, dim, d_state=ssm_state,
                                   head_dim=ssm_head_dim, dtype=dtype),
            attn_norm=RMSNorm.create(dim, dtype=dtype),
            ssm_norm=RMSNorm.create(dim, dtype=dtype),
        )

    def __call__(self, x: jax.Array) -> jax.Array:
        a = self.attn(x)
        s = self.ssm(x)
        return 0.5 * (self.attn_norm(a) + self.ssm_norm(s))

    def prefill(self, x: jax.Array, state: HybridState):
        a, kv = self.attn.prefill(x, state.kv)
        # prefill the SSM path with its full forward, capturing the state
        z, xbc, dt = self.ssm._split(self.ssm.in_proj(x))
        xbc_c = self.ssm._conv(xbc)
        xi, B, C = self.ssm._split_xbc(xbc_c)
        y, ssm_final = self.ssm._ssd(dt=dt, x=xi, B=B, C=C)
        y = y.reshape(x.shape[0], x.shape[1], self.ssm.d_inner)
        y = self.ssm.gate_norm(y) * jax.nn.silu(z)
        s = self.ssm.out_proj(y)
        w = self.ssm.conv_width - 1
        conv_tail = xbc[:, -w:, :] if x.shape[1] >= w else jnp.pad(
            xbc, ((0, 0), (w - x.shape[1], 0), (0, 0)))
        new_state = HybridState(
            kv=kv, ssm=SSMState(conv=conv_tail, ssm=ssm_final))
        return 0.5 * (self.attn_norm(a) + self.ssm_norm(s)), new_state

    def prefill_chunk(self, x: jax.Array, state: HybridState, *,
                      slot: jax.Array, offset: jax.Array,
                      n_valid: jax.Array):
        """Consume one prompt chunk for ONE slot of a batched serving
        state: the attention path scatters into the slot's (ring or
        dense) KV lane via :meth:`Attention.prefill_chunk`, the SSM path
        scans the chunk into the slot's carried conv/ssm state.  The
        first chunk of a request (``offset == 0``) zeros the slot's SSM
        lanes in-graph — the per-slot state reset that makes slot
        recycling safe (the KV ring needs no reset: its masks exclude
        lanes this request never wrote)."""
        a, kv = self.attn.prefill_chunk(x, state.kv, slot=slot,
                                        offset=offset, n_valid=n_valid)
        fresh = offset == 0
        conv0 = jnp.where(fresh, 0.0, state.ssm.conv[slot][None])
        ssm0 = jnp.where(fresh, 0.0, state.ssm.ssm[slot][None])
        s, st = self.ssm.prefill_chunk(x, SSMState(conv0, ssm0),
                                       n_valid=n_valid)
        new_ssm = SSMState(
            conv=state.ssm.conv.at[slot].set(
                st.conv[0].astype(state.ssm.conv.dtype)),
            ssm=state.ssm.ssm.at[slot].set(
                st.ssm[0].astype(state.ssm.ssm.dtype)))
        out = 0.5 * (self.attn_norm(a) + self.ssm_norm(s))
        return out, HybridState(kv=kv, ssm=new_ssm)

    def decode(self, x: jax.Array, state: HybridState):
        a, kv = self.attn.decode(x, state.kv)
        s, ssm = self.ssm.decode(x, state.ssm)
        return 0.5 * (self.attn_norm(a) + self.ssm_norm(s)), HybridState(kv, ssm)
