"""Grouped-query attention with RoPE, sliding windows, and KV caching.

One module covers every assigned attention variant:
  * MHA (kv_heads == heads), GQA (kv_heads < heads), MQA (kv_heads == 1)
  * optional QKV bias (qwen2.5)
  * optional sliding-window mask (hymba local layers)
  * optional cross-attention (whisper decoder): keys/values from ``context``
  * KV-cache decode path (one new token against a pre-filled cache)

The projections are plain ``Linear`` modules, so Greenformer's ``auto_fact``
factorizes them into LED layers transparently.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain_acts
from repro.nn.linear import Linear
from repro.nn.module import Module, static_field
from repro.nn.rotary import apply_rope

# mask fill value — must stay equal to repro.kernels.ref.NEG_INF (the pallas
# kernels and their oracles) for the paged-decode bit-identity contract; kept
# as a local literal because nn only imports repro.kernels lazily (pallas
# must not load for training-only use)
NEG_INF = -1e30


class UnsupportedCacheError(ValueError):
    """A model/config's cache family cannot back the requested cache layout
    or serving mode.

    Lives beside the cache types so the model layer can raise it without
    depending on ``repro.serve`` (which re-exports it).  Subclasses
    ``ValueError`` for backwards compatibility with callers that caught the
    old unstructured errors.  ``roadmap_item`` names the ROADMAP entry that
    would lift the limitation."""

    def __init__(self, message: str, *, roadmap_item: Optional[str] = None):
        if roadmap_item:
            message = f"{message} [ROADMAP: {roadmap_item}]"
        super().__init__(message)
        self.roadmap_item = roadmap_item


class KVCache(NamedTuple):
    k: jax.Array  # (batch, max_len, kv_heads, head_dim)
    v: jax.Array  # (batch, max_len, kv_heads, head_dim)
    length: jax.Array  # () int32 — number of valid positions; or (batch,)
    # int32 in per-slot mode (continuous batching: each row advances
    # independently, see ``repro.serve``).

    @staticmethod
    def zeros(batch: int, max_len: int, kv_heads: int, head_dim: int,
              dtype=jnp.bfloat16, per_slot: bool = False) -> "KVCache":
        return KVCache(
            k=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
            v=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
            length=jnp.zeros((batch,) if per_slot else (), jnp.int32),
        )


class PagedKVCache(NamedTuple):
    """Paged (block-table) KV layout for continuous batching.

    Instead of each slot reserving a dense ``max_len`` lane, all slots
    share one pool of fixed-size blocks; a per-slot block table maps
    logical position ``p`` to pool row ``table[slot, p // bs] * bs +
    p % bs``.  HBM spent on KV is proportional to live tokens, not to
    ``batch * max_len``.  Block ownership, refcounts, and prefix sharing
    live host-side in :mod:`repro.serve.paging`; table entries equal to
    ``n_blocks`` (one past the last block) are the unmapped sentinel —
    scatters there drop, gathers clip into lanes the position mask
    already excludes.
    """

    k: jax.Array  # (n_blocks, block_size, kv_heads, head_dim)
    v: jax.Array  # (n_blocks, block_size, kv_heads, head_dim)
    table: jax.Array  # (batch, max_blocks_per_seq) int32 pool block ids
    length: jax.Array  # (batch,) int32 — valid positions per slot

    # constructed by ``TransformerLM.init_paged_cache`` (which stacks a
    # leading n_layers dim onto k/v/length); no bare ``zeros`` here so the
    # two shape contracts cannot drift apart


class Attention(Module):
    q_proj: Linear
    k_proj: Linear
    v_proj: Linear
    o_proj: Linear
    num_heads: int = static_field(default=8)
    num_kv_heads: int = static_field(default=8)
    head_dim: int = static_field(default=64)
    rope: bool = static_field(default=True)
    rope_theta: float = static_field(default=10000.0)
    window: int = static_field(default=0)  # 0 = global; >0 = sliding window
    causal: bool = static_field(default=True)
    chunk: int = static_field(default=0)  # >0: flash-style blockwise attention

    @staticmethod
    def create(key, dim: int, num_heads: int, num_kv_heads: int, *,
               head_dim: Optional[int] = None, qkv_bias: bool = False,
               rope: bool = True, rope_theta: float = 10000.0, window: int = 0,
               causal: bool = True, chunk: int = 0,
               dtype=jnp.float32) -> "Attention":
        head_dim = head_dim or dim // num_heads
        kq, kk, kv, ko = jax.random.split(key, 4)
        return Attention(
            q_proj=Linear.create(kq, dim, num_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
            k_proj=Linear.create(kk, dim, num_kv_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
            v_proj=Linear.create(kv, dim, num_kv_heads * head_dim, use_bias=qkv_bias, dtype=dtype),
            o_proj=Linear.create(ko, num_heads * head_dim, dim, use_bias=False, dtype=dtype),
            num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
            rope=rope, rope_theta=rope_theta, window=window, causal=causal,
            chunk=chunk,
        )

    # -- helpers ------------------------------------------------------------

    def _qkv(self, x, context=None, positions=None, kv_positions=None):
        b, s, _ = x.shape
        ctx = x if context is None else context
        q = self.q_proj(x).reshape(b, s, self.num_heads, self.head_dim)
        k = self.k_proj(ctx).reshape(b, ctx.shape[1], self.num_kv_heads, self.head_dim)
        v = self.v_proj(ctx).reshape(b, ctx.shape[1], self.num_kv_heads, self.head_dim)
        if self.rope:
            if positions is None:
                positions = jnp.arange(s)[None, :]
            if kv_positions is None:
                kv_positions = jnp.arange(ctx.shape[1])[None, :]
            q = apply_rope(q, positions, theta=self.rope_theta)
            k = apply_rope(k, kv_positions, theta=self.rope_theta)
        return q, k, v

    def _attend(self, q, k, v, mask):
        """q: (b, sq, h, d); k/v: (b, sk, kvh, d); mask: (b, 1, sq, sk) bool."""
        group = self.num_heads // self.num_kv_heads
        b, sq, h, d = q.shape
        sk = k.shape[1]
        q = q.reshape(b, sq, self.num_kv_heads, group, d)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
        logits = logits / jnp.sqrt(d).astype(jnp.float32)
        if mask is not None:
            logits = jnp.where(mask[:, :, None, :, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return out.reshape(b, sq, h * d)

    def _attend_chunked(self, q, k, v):
        """Flash-style blockwise attention: O(chunk²) temporaries instead of
        O(S²).  Online-softmax accumulation over KV blocks, lax.map over Q
        blocks.  Respects causal + sliding-window masks via block position
        offsets.  Self-attention full-sequence path only (training/prefill)."""
        c = self.chunk
        b, sq, h, d = q.shape
        sk = k.shape[1]
        pad_q, pad_k = (-sq) % c, (-sk) % c
        qpad = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        kpad = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        vpad = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        nq, nk = (sq + pad_q) // c, (sk + pad_k) // c
        group = self.num_heads // self.num_kv_heads
        kvh = self.num_kv_heads
        qb = qpad.reshape(b, nq, c, kvh, group, d).astype(jnp.float32)
        kb = kpad.reshape(b, nk, c, kvh, d).astype(jnp.float32)
        vb = vpad.reshape(b, nk, c, kvh, d).astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(d)
        kpos_in = jnp.arange(c)
        qpos_in = jnp.arange(c)

        def q_block(qi):
            qblk = qb[:, qi]  # (b, c, kvh, g, d)

            def kv_step(carry, ki):
                m, l, acc = carry
                kblk, vblk = kb[:, ki], vb[:, ki]
                logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk) * scale
                qpos = qi * c + qpos_in
                kpos = ki * c + kpos_in
                valid = kpos[None, :] < sk
                if self.causal:
                    valid = valid & (kpos[None, :] <= qpos[:, None])
                if self.window > 0:
                    valid = valid & (kpos[None, :] > qpos[:, None] - self.window)
                logits = jnp.where(valid[None, None, None, :, :], logits,
                                   NEG_INF)
                m_new = jnp.maximum(m, logits.max(-1))
                p = jnp.exp(logits - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(-1)
                acc_new = (acc * corr[..., None]
                           + jnp.einsum("bkgqs,bskd->bkgqd", p, vblk))
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((b, kvh, group, c), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, kvh, group, c), jnp.float32)
            a0 = jnp.zeros((b, kvh, group, c, d), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            # (b, kvh, g, c, d) -> (b, c, kvh*g*d)
            return out.transpose(0, 3, 1, 2, 4).reshape(b, c, h * d)

        blocks = jax.lax.map(q_block, jnp.arange(nq))  # (nq, b, c, h*d)
        out = blocks.transpose(1, 0, 2, 3).reshape(b, nq * c, h * d)
        return out[:, :sq].astype(q.dtype)

    def _causal_mask(self, sq, sk, q_offset=0):
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = kpos[None, :] <= qpos[:, None] if self.causal else jnp.ones((sq, sk), bool)
        if self.window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - self.window)
        return mask[None, None, :, :]  # (1, 1, sq, sk) -> broadcasts over (b, kvh)

    # -- forward paths ------------------------------------------------------

    def __call__(self, x: jax.Array, *, context: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None) -> jax.Array:
        """Full-sequence forward (training / prefill without cache)."""
        q, k, v = self._qkv(x, context=context, positions=positions)
        if context is None and self.chunk > 0 and x.shape[1] > self.chunk:
            out = self._attend_chunked(q, k, v)
            return self.o_proj(out)
        if context is None:
            mask = self._causal_mask(x.shape[1], x.shape[1])
        else:
            mask = None  # cross-attention: attend to the whole context
        out = self._attend(q, k, v, mask)
        return self.o_proj(out)

    def project_kv(self, context: jax.Array):
        """Precompute cross-attention K/V from an encoder context."""
        b, t, _ = context.shape
        k = self.k_proj(context).reshape(b, t, self.num_kv_heads, self.head_dim)
        v = self.v_proj(context).reshape(b, t, self.num_kv_heads, self.head_dim)
        return k, v

    def attend_kv(self, x: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
        """Cross-attend ``x`` against precomputed K/V (no mask, no rope)."""
        b, s, _ = x.shape
        q = self.q_proj(x).reshape(b, s, self.num_heads, self.head_dim)
        return self.o_proj(self._attend(q, k, v, None))

    def _is_ring(self, cache: KVCache) -> bool:
        """Ring-buffer mode: a sliding-window layer whose cache holds exactly
        ``window`` slots — slot(p) = p % window.  O(window) decode memory
        regardless of context length (the long_500k path)."""
        return self.window > 0 and cache.k.shape[1] == self.window

    def prefill(self, x: jax.Array, cache: KVCache) -> tuple[jax.Array, KVCache]:
        """Process a prompt, fill the cache, return outputs + updated cache."""
        b, s, _ = x.shape
        q, k, v = self._qkv(x)
        if self.chunk > 0 and s > self.chunk:
            out = self._attend_chunked(q, k, v)
        else:
            out = self._attend(q, k, v, self._causal_mask(s, s))
        k, v = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
        if self._is_ring(cache):
            w = self.window
            keep = min(s, w)
            slots = (jnp.arange(s - keep, s)) % w
            new_k = cache.k.at[:, slots].set(k[:, s - keep:])
            new_v = cache.v.at[:, slots].set(v[:, s - keep:])
        else:
            new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, axis=1)
            new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, axis=1)
        return (constrain_acts(self.o_proj(out)),
                KVCache(new_k, new_v, jnp.asarray(s, jnp.int32)))

    def prefill_chunk(self, x: jax.Array, cache, *, slot: jax.Array,
                      offset: jax.Array, n_valid: jax.Array,
                      dst: Optional[jax.Array] = None,
                      prefill_kernel: str = "reference"):
        """Consume one prompt chunk for ONE slot of a batched serving cache.

        ``x``: (1, W, dim) — a bucket-padded span of the slot's prompt whose
        first ``n_valid`` rows are real tokens starting at absolute position
        ``offset`` (RoPE positions, causal mask, and cache writes are all
        offset-relative, so a prompt can be fed in any chunking and produce
        the same K/V rows and the same last-token logits as one monolithic
        prefill).  The chunk attends against everything already resident in
        the slot's lane — earlier chunks of this prompt AND, for the paged
        layout, shared prefix blocks written by an earlier request — which
        is what lets prefix-aware admission *start* after the cached prefix
        instead of recomputing it.

        Dense per-slot :class:`KVCache`: chunk K/V rows are scattered
        straight into the slot's lane at ``offset + i`` (padding rows are
        routed out of range and dropped), and attention gathers the full
        lane under a ``kpos <= qpos`` mask.

        Ring-buffer :class:`KVCache` (sliding-window layer whose lane
        holds exactly ``window`` slots): the chunk attends against the
        concatenation of the slot's resident ring lanes and its own fresh
        K/V — ring lane ``i`` holds absolute position ``offset - 1 -
        ((offset - 1 - i) mod window)`` (the newest position below
        ``offset`` on that lane; negative means this request never wrote
        it, which also masks out stale lanes from a recycled slot without
        any reset), and both halves carry offset-relative causal +
        sliding-window masks, so any chunking is wraparound-safe.  Only
        the newest ``min(n_valid, window)`` chunk rows are scattered back
        (``slot(p) = p % window``); older rows of an over-wide chunk and
        padding rows route to the out-of-range lane and drop.

        :class:`PagedKVCache`: ``dst`` gives the flat pool row for each of
        the W chunk positions — the engine points padding AND cached-prefix
        positions at the out-of-range sentinel row, so ``mode='drop'``
        leaves shared blocks untouched (a prefix hit is never rewritten,
        even with identical bytes) — and attention gathers the slot's
        logical lane through its block table.

        ``prefill_kernel`` selects the chunk attention implementation for
        the paged and dense layouts: ``"reference"`` is the dense gather +
        masked softmax above; ``"pallas"`` is the flash-style
        :func:`repro.kernels.chunk_attention` kernel — prefix blocks
        stream through VMEM inside an online-softmax loop and the
        gathered lane view is never materialized.  Valid rows match the
        reference to float tolerance (padding rows carry no contract —
        the engine never reads them); ring-buffer lanes refuse the
        kernel (their wraparound gather has no paged-pool analogue).

        Returns ``(chunk outputs (1, W, dim), updated cache)`` with the
        slot's length advanced to ``offset + n_valid``.
        """
        if prefill_kernel not in ("reference", "pallas"):
            raise ValueError(f"unknown prefill_kernel {prefill_kernel!r}")
        w = x.shape[1]
        qpos = offset + jnp.arange(w)  # (W,) absolute positions
        q, k, v = self._qkv(x, positions=qpos[None, :],
                            kv_positions=qpos[None, :])
        if isinstance(cache, PagedKVCache):
            if self.window > 0:
                raise NotImplementedError(
                    "paged chunked prefill supports global attention only; "
                    "sliding-window layers use the ring-buffer KVCache path")
            nb, bs, kvh, hd = cache.k.shape
            max_table = cache.table.shape[1]
            pool_k = cache.k.reshape(nb * bs, kvh, hd)
            pool_v = cache.v.reshape(nb * bs, kvh, hd)
            pool_k = pool_k.at[dst].set(k[0].astype(pool_k.dtype),
                                        mode="drop")
            pool_v = pool_v.at[dst].set(v[0].astype(pool_v.dtype),
                                        mode="drop")
            if prefill_kernel == "pallas":
                from repro.kernels.chunk_attention import chunk_attention

                out = chunk_attention(
                    q[0], pool_k.reshape(cache.k.shape),
                    pool_v.reshape(cache.v.shape), cache.table[slot],
                    k[0].astype(pool_k.dtype), v[0].astype(pool_v.dtype),
                    offset, n_valid).reshape(1, w, -1)
            else:
                kpos = jnp.arange(max_table * bs)
                rows = cache.table[slot, kpos // bs] * bs + kpos % bs
                gk = pool_k[rows][None].astype(x.dtype)  # (1, S, kvh, hd)
                gv = pool_v[rows][None].astype(x.dtype)
                valid = kpos[None, :] <= qpos[:, None]  # (W, S)
                out = self._attend(q, gk, gv, valid[None, None])
            length = cache.length.at[slot].set(offset + n_valid)
            new_cache = PagedKVCache(pool_k.reshape(cache.k.shape),
                                     pool_v.reshape(cache.v.shape),
                                     cache.table, length)
        elif self._is_ring(cache):
            if prefill_kernel == "pallas":
                raise NotImplementedError(
                    "prefill_kernel='pallas' streams a position-addressable "
                    "KV prefix (paged pool or dense lane); ring-buffer "
                    "(sliding-window) lanes wrap around and use the "
                    "reference path")
            ring = self.window
            i = jnp.arange(ring)
            # lane i holds the newest absolute position < offset congruent
            # to i mod ring; negative => never written by THIS request
            # (covers both a cold lane and stale bytes left by the slot's
            # previous occupant — no reset pass needed)
            p_lane = (offset - 1) - jnp.mod((offset - 1) - i, ring)
            ring_k = cache.k[slot][None].astype(x.dtype)  # (1, ring, kvh, hd)
            ring_v = cache.v[slot][None].astype(x.dtype)
            ring_valid = ((p_lane[None, :] >= 0)
                          & (p_lane[None, :] > qpos[:, None] - ring))
            j = jnp.arange(w)
            self_valid = ((j[None, :] <= j[:, None])         # causal in-chunk
                          & (j[None, :] < n_valid)           # padding
                          & (qpos[None, :] > qpos[:, None] - ring))
            mask = jnp.concatenate([ring_valid, self_valid], axis=1)
            gk = jnp.concatenate([ring_k, k.astype(x.dtype)], axis=1)
            gv = jnp.concatenate([ring_v, v.astype(x.dtype)], axis=1)
            out = self._attend(q, gk, gv, mask[None, None])
            # scatter the newest min(n_valid, ring) rows to slot(p) = p %
            # ring; rows a wider-than-window chunk already superseded and
            # padding rows route to the out-of-range lane and drop (the
            # survivors hit pairwise-distinct lanes: ring consecutive
            # positions)
            live = (j < n_valid) & (j >= n_valid - ring)
            lanes = jnp.where(live, (offset + j) % ring, ring)
            new_k = cache.k.at[slot, lanes].set(k[0].astype(cache.k.dtype),
                                                mode="drop")
            new_v = cache.v.at[slot, lanes].set(v[0].astype(cache.v.dtype),
                                                mode="drop")
            length = cache.length.at[slot].set(offset + n_valid)
            new_cache = KVCache(new_k, new_v, length)
        else:
            max_len = cache.k.shape[1]
            wpos = jnp.where(jnp.arange(w) < n_valid, qpos, max_len)
            new_k = cache.k.at[slot, wpos].set(k[0].astype(cache.k.dtype),
                                               mode="drop")
            new_v = cache.v.at[slot, wpos].set(v[0].astype(cache.v.dtype),
                                               mode="drop")
            if prefill_kernel == "pallas":
                from repro.kernels.chunk_attention import (
                    chunk_attention_dense)

                out = chunk_attention_dense(
                    q[0], new_k[slot], new_v[slot],
                    k[0].astype(cache.k.dtype), v[0].astype(cache.v.dtype),
                    offset, n_valid).reshape(1, w, -1)
            else:
                kpos = jnp.arange(max_len)
                valid = kpos[None, :] <= qpos[:, None]  # (W, max_len)
                out = self._attend(q, new_k[slot][None].astype(x.dtype),
                                   new_v[slot][None].astype(x.dtype),
                                   valid[None, None])
            length = cache.length.at[slot].set(offset + n_valid)
            new_cache = KVCache(new_k, new_v, length)
        return constrain_acts(self.o_proj(out)), new_cache

    def decode(self, x: jax.Array, cache, *,
               decode_kernel: str = "reference") -> tuple[jax.Array, "KVCache"]:
        """Decode step for ``s`` new tokens per row. x: (batch, s, dim).

        ``s == 1`` is the ordinary autoregressive step.  ``s > 1`` is the
        multi-token step speculative verification uses: all ``s`` K/V rows
        are written first, then every query attends under a ``kpos <=
        qpos`` mask, so token ``j`` sees exactly the rows a sequential
        ``s``-step decode would have seen (intra-chunk causality) and the
        logits match the sequential ones bit-for-bit given the same cache
        contents.  Rows past the accepted prefix are overwritten by the
        next step before any query can attend them (length only advances
        by the accepted count).

        With a :class:`KVCache`, ``cache.length`` is either a scalar
        (lock-step batch: every row sits at the same position) or a
        ``(batch,)`` vector (per-slot mode for continuous batching: each row
        advances independently, with its own RoPE position, cache write
        offset, and validity mask).  With a :class:`PagedKVCache`, K/V rows
        are scattered to / gathered from the shared block pool through each
        slot's block table; ``decode_kernel`` selects the paged attention
        implementation (``"reference"`` = dense gather + masked softmax,
        ``"pallas"`` = the fused block-streaming kernel, single-token steps
        only — multi-token steps fall back to the reference gather) and is
        ignored for dense caches."""
        if isinstance(cache, PagedKVCache):
            return self._decode_paged(x, cache, kernel=decode_kernel)
        b, s, _ = x.shape
        pos = cache.length
        per_slot = pos.ndim == 1
        if self._is_ring(cache):
            if s != 1:
                raise NotImplementedError(
                    "multi-token decode targets the kv/paged layouts; "
                    "ring-buffer (sliding-window) caches decode one token "
                    "at a time")
            positions = (pos[:, None].astype(jnp.int32) if per_slot
                         else jnp.full((b, 1), pos, dtype=jnp.int32))
            q, k, v = self._qkv(x, positions=positions, kv_positions=positions)
            k, v = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
            w = self.window
            slot = pos % w
            i = jnp.arange(w)
            if per_slot:
                rows = jnp.arange(b)
                new_k = cache.k.at[rows, slot].set(k[:, 0])
                new_v = cache.v.at[rows, slot].set(v[:, 0])
                kpos = pos[:, None] - jnp.mod(pos[:, None] - i[None, :], w)
                valid = kpos >= 0  # (b, w)
                mask = valid[:, None, None, :]
            else:
                new_k = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
                new_v = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
                # slot i holds absolute position pos - ((pos - i) mod w); valid
                # once non-negative.  Window recency holds by construction.
                kpos = pos - jnp.mod(pos - i, w)
                valid = kpos >= 0
                mask = valid[None, None, None, :]
            out = self._attend(q, new_k.astype(x.dtype),
                               new_v.astype(x.dtype), mask)
            return (constrain_acts(self.o_proj(out)),
                    KVCache(new_k, new_v, pos + 1))
        kpos = jnp.arange(cache.k.shape[1])
        if per_slot:
            qpos = pos[:, None] + jnp.arange(s)[None, :]  # (b, s)
            q, k, v = self._qkv(x, positions=qpos.astype(jnp.int32),
                                kv_positions=qpos.astype(jnp.int32))
            k, v = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
            # mode='drop': a row parked at pos == max_len (slot frozen by
            # cache_full eviction, or mid-chunked-prefill with its write
            # frontier owned by prefill_chunk) must write NOWHERE — the
            # default clip would smear stale K/V into the last lane row
            rows = jnp.arange(b)
            new_k = cache.k.at[rows[:, None], qpos].set(k, mode="drop")
            new_v = cache.v.at[rows[:, None], qpos].set(v, mode="drop")
            valid = kpos[None, None, :] <= qpos[:, :, None]  # (b, s, S)
            if self.window > 0:
                valid = valid & (kpos[None, None, :]
                                 > qpos[:, :, None] - self.window)
            mask = valid[:, None]  # (b, 1, s, S)
        else:
            qpos = pos + jnp.arange(s)  # (s,)
            positions = jnp.broadcast_to(qpos[None, :], (b, s)).astype(jnp.int32)
            q, k, v = self._qkv(x, positions=positions, kv_positions=positions)
            k, v = k.astype(cache.k.dtype), v.astype(cache.v.dtype)
            new_k = jax.lax.dynamic_update_slice(cache.k, k, (0, pos, 0, 0))
            new_v = jax.lax.dynamic_update_slice(cache.v, v, (0, pos, 0, 0))
            valid = kpos[None, :] <= qpos[:, None]  # (s, S)
            if self.window > 0:
                valid = valid & (kpos[None, :] > qpos[:, None] - self.window)
            mask = valid[None, None]  # (1, 1, s, S)
        out = self._attend(q, new_k.astype(x.dtype), new_v.astype(x.dtype), mask)
        return constrain_acts(self.o_proj(out)), KVCache(new_k, new_v, pos + s)

    def _decode_paged(self, x: jax.Array, cache: PagedKVCache,
                      kernel: str = "reference"
                      ) -> tuple[jax.Array, PagedKVCache]:
        """Decode ``s`` tokens per slot against the shared block pool.

        Each new K/V row is scattered to ``table[b, p // bs] * bs +
        p % bs`` for ``p = pos .. pos + s - 1`` (``mode='drop'``: slots
        whose table entry is the unmapped sentinel — finished, never
        admitted, or positions past the slot's block reservation — write
        nowhere, so a frozen slot can never clobber a block recycled to
        another request).  ``kernel="reference"`` (the dense-gather
        baseline) then gathers every mapped pool row back into logical
        order and masks ``kpos > qpos`` per query; gathers through
        sentinel entries clip into masked lanes, and exactly-NEG_INF
        masking makes their contribution a hard zero, keeping outputs
        bit-identical to the dense per-slot layout.  ``kernel="pallas"``
        replaces the gather + attention with the fused
        :func:`repro.kernels.paged_attention` kernel — blocks stream
        through VMEM inside a flash-style online-softmax loop and the
        dense ``(batch, max_len, kvh, hd)`` view is never materialized
        (sentinel and ``kpos > pos`` masking move in-kernel).  The kernel
        is single-query; multi-token steps (``s > 1``, the speculative
        verify pass) fall back to the reference gather."""
        if self.window > 0:
            raise NotImplementedError(
                "paged decode supports global attention only; sliding-window "
                "layers use the ring-buffer KVCache path")
        if kernel not in ("reference", "pallas"):
            raise ValueError(f"unknown paged decode kernel {kernel!r}")
        b, s, _ = x.shape
        pos = cache.length  # (b,)
        qpos = pos[:, None] + jnp.arange(s)[None, :]  # (b, s)
        positions = qpos.astype(jnp.int32)
        q, k, v = self._qkv(x, positions=positions, kv_positions=positions)
        nb, bs, kvh, hd = cache.k.shape
        max_table = cache.table.shape[1]
        pool_k = cache.k.reshape(nb * bs, kvh, hd)
        pool_v = cache.v.reshape(nb * bs, kvh, hd)
        # a slot frozen at pos == max_table*bs (cache_full eviction) would
        # index one past the table; clamp the lookup and route its write to
        # the sentinel row explicitly — take_along_axis's out-of-bounds fill
        # (INT32_MIN) times bs wraps around int32 to a VALID row otherwise
        blk = jnp.take_along_axis(
            cache.table, jnp.minimum(qpos // bs, max_table - 1), axis=1)
        row_new = jnp.where(qpos < max_table * bs, blk * bs + qpos % bs,
                            nb * bs)  # (b, s) flat pool rows for these tokens
        pool_k = pool_k.at[row_new].set(k.astype(pool_k.dtype), mode="drop")
        pool_v = pool_v.at[row_new].set(v.astype(pool_v.dtype), mode="drop")
        new_k = pool_k.reshape(nb, bs, kvh, hd)
        new_v = pool_v.reshape(nb, bs, kvh, hd)
        if kernel == "pallas" and s == 1:
            from repro.kernels.paged_attention import paged_attention

            out = paged_attention(q[:, 0], new_k, new_v, cache.table, pos)
            out = out.reshape(b, 1, self.num_heads * self.head_dim)
        else:
            kpos = jnp.arange(max_table * bs)
            rows = cache.table[:, kpos // bs] * bs + (kpos % bs)[None, :]
            gk = pool_k[rows].astype(x.dtype)  # (b, max_table*bs, kvh, hd)
            gv = pool_v[rows].astype(x.dtype)
            valid = kpos[None, None, :] <= qpos[:, :, None]  # (b, s, S)
            out = self._attend(q, gk, gv, valid[:, None])
        return (constrain_acts(self.o_proj(out)),
                PagedKVCache(new_k, new_v, cache.table, pos + s))
