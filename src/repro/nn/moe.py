"""Mixture-of-Experts feed-forward with token-choice top-k routing.

Design (TPU-native, GSPMD-friendly):
  * Routing and dispatch happen **per sequence row** ("groups" in GShard
    terminology): each row of ``S`` tokens is routed independently with a
    per-row capacity ``C = ceil(S·k/E · capacity_factor)``.  This bounds the
    sort to ``S·k`` elements, keeps every shape static, and lets the batch
    axis stay sharded on ``data`` while the expert axis shards on ``model``
    (expert parallelism); GSPMD inserts the dispatch all-to-all.
  * Dispatch/combine use sort + scatter/gather (O(T·k·d) memory), NOT the
    one-hot einsum (O(T²) FLOPs at large T) — this keeps the roofline honest.
  * Expert FFNs are weight-stacked SwiGLUs, so ``auto_fact`` factorizes all
    experts at once (batched SVD over the expert axis).
  * Shared experts (deepseek/kimi style) are a plain SwiGLU applied to every
    token, added to the routed output.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn.linear import LED, Linear
from repro.nn.mlp import SwiGLU
from repro.nn.module import Module, static_field


def _expert_matmul(proj, x: jax.Array) -> jax.Array:
    """x: (b, E, cap, d_in) × expert-stacked Linear/LED -> (b, E, cap, d_out).

    LED experts (Greenformer-factorized) contract through the rank
    bottleneck — two small einsums instead of one dense one."""
    if isinstance(proj, LED):
        t = jnp.einsum("becd,edr->becr", x, proj.A.astype(x.dtype))
        return jnp.einsum("becr,erf->becf", t, proj.B.astype(x.dtype))
    return jnp.einsum("becd,edf->becf", x, proj.weight.astype(x.dtype))


class MoEOutput(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array


class MoE(Module):
    router: Linear  # (dim, n_experts)
    experts: SwiGLU  # weight-stacked: (..., E, dim, ff)
    shared: Optional[SwiGLU]
    n_experts: int = static_field(default=8)
    top_k: int = static_field(default=2)
    capacity_factor: float = static_field(default=1.25)

    @staticmethod
    def create(key, dim: int, ff: int, n_experts: int, top_k: int, *,
               n_shared: int = 0, capacity_factor: float = 1.25,
               dtype=jnp.float32) -> "MoE":
        kr, ke, ks = jax.random.split(key, 3)
        experts = SwiGLU.create(ke, dim, ff, dtype=dtype, stack_dims=(n_experts,))
        shared = SwiGLU.create(ks, dim, ff * n_shared, dtype=dtype) if n_shared else None
        return MoE(
            router=Linear.create(kr, dim, n_experts, dtype=dtype),
            experts=experts, shared=shared,
            n_experts=n_experts, top_k=top_k, capacity_factor=capacity_factor,
        )

    def _capacity(self, seq_len: int) -> int:
        cap = int(seq_len * self.top_k * self.capacity_factor / self.n_experts) + 1
        return min(max(cap, self.top_k), seq_len)

    def __call__(self, x: jax.Array) -> MoEOutput:
        """x: (batch, seq, dim) -> (batch, seq, dim), aux load-balance loss."""
        b, s, d = x.shape
        e, k = self.n_experts, self.top_k
        cap = self._capacity(s)

        logits = self.router(x.astype(jnp.float32))  # (b, s, e)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)  # (b, s, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # ---- per-row sort-based dispatch -------------------------------
        flat_e = top_e.reshape(b, s * k)  # expert id per slot
        order = jnp.argsort(flat_e, axis=-1)  # (b, s*k)
        sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
        counts = jax.vmap(lambda ids: jnp.bincount(ids, length=e))(flat_e)  # (b, e)
        seg_start = jnp.cumsum(counts, axis=-1) - counts  # (b, e)
        pos = jnp.arange(s * k)[None, :] - jnp.take_along_axis(seg_start, sorted_e, -1)
        keep = pos < cap
        dest = jnp.where(keep, sorted_e * cap + pos, e * cap)  # OOB => dropped
        src_tok = order // k  # token index for each sorted slot

        x_slot = jnp.take_along_axis(
            x, src_tok[..., None], axis=1, mode="clip")  # (b, s*k, d)
        buf = jnp.zeros((b, e * cap, d), x.dtype)
        buf = jax.vmap(lambda bf, dst, xs: bf.at[dst].set(xs, mode="drop"))(
            buf, dest, x_slot)
        buf = buf.reshape(b, e, cap, d)

        # ---- expert computation (weights stacked on leading E axis) ----
        h = _expert_matmul(self.experts.gate_proj, buf)
        u = _expert_matmul(self.experts.up_proj, buf)
        y_e = _expert_matmul(self.experts.down_proj, jax.nn.silu(h) * u)
        y_e = y_e.reshape(b, e * cap, d)

        # ---- combine ----------------------------------------------------
        y_slot = jnp.take_along_axis(
            y_e, jnp.minimum(dest, e * cap - 1)[..., None], axis=1)
        prob_slot = jnp.take_along_axis(top_p.reshape(b, s * k), order, axis=-1)
        w = jnp.where(keep, prob_slot, 0.0).astype(x.dtype)
        y = jnp.zeros_like(x)
        y = jax.vmap(lambda yy, tok, val: yy.at[tok].add(val))(
            y, src_tok, y_slot * w[..., None])

        if self.shared is not None:
            y = y + self.shared(x)

        # ---- load-balance aux loss (Switch-style) -----------------------
        frac_tokens = counts.astype(jnp.float32) / (s * k)  # (b, e)
        frac_probs = probs.mean(axis=1)  # (b, e)
        aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
        return MoEOutput(y=y, aux_loss=aux)
