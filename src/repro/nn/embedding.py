"""Token embedding (optionally tied as the output head)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.module import Module


class Embedding(Module):
    weight: jax.Array  # (vocab, dim)

    @staticmethod
    def create(key, vocab_size: int, dim: int, *, dtype=jnp.float32) -> "Embedding":
        return Embedding(weight=initializers.normal(key, (vocab_size, dim), dtype))

    def __call__(self, tokens: jax.Array) -> jax.Array:
        return jnp.take(self.weight, tokens, axis=0)

    def attend(self, x: jax.Array) -> jax.Array:
        """Tied output head: logits = x @ E^T."""
        return x @ self.weight.T
