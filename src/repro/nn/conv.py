"""Convolution layers and their factorized CED counterparts.

Weight layouts (chosen to match the paper's description):
  * ``Conv1D``: ``W ∈ R^{Cin × Cout × S}``; inputs are ``(batch, length, Cin)``.
  * ``Conv2D``: ``W ∈ R^{Cin × Cout × Kh × Kw}``; inputs ``(batch, H, W, Cin)``.

CED (Convolution Encoder-Decoder) factorizes the rearranged matrix
``W' ∈ R^{Cin·S × Cout}`` into ``A'B'`` and reshapes back into two convs:
a spatial conv to ``r`` channels (``A ∈ R^{Cin × r × S}``) followed by a
pointwise conv (``B ∈ R^{r × Cout × 1}``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.module import Module, static_field


def _conv1d(x, w_oik, stride, padding):
    # x: (B, L, Cin); w_oik: (Cout, Cin, S)
    return jax.lax.conv_general_dilated(
        x, w_oik, window_strides=(stride,), padding=padding,
        dimension_numbers=("NWC", "OIW", "NWC"))


def _conv2d(x, w_oihw, stride, padding):
    return jax.lax.conv_general_dilated(
        x, w_oihw, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))


class Conv1D(Module):
    weight: jax.Array  # (Cin, Cout, S)
    bias: Optional[jax.Array]
    stride: int = static_field(default=1)
    padding: str = static_field(default="SAME")

    @staticmethod
    def create(key, c_in: int, c_out: int, kernel_size: int, *, stride: int = 1,
               padding: str = "SAME", use_bias: bool = True,
               dtype=jnp.float32) -> "Conv1D":
        w = initializers.he_normal(key, (c_in, c_out, kernel_size), dtype,
                                   fan_in_axes=(0, 2))
        b = jnp.zeros((c_out,), dtype) if use_bias else None
        return Conv1D(weight=w, bias=b, stride=stride, padding=padding)

    def __call__(self, x: jax.Array) -> jax.Array:
        w = jnp.transpose(self.weight, (1, 0, 2))  # -> (Cout, Cin, S)
        y = _conv1d(x, w, self.stride, self.padding)
        if self.bias is not None:
            y = y + self.bias
        return y


class Conv2D(Module):
    weight: jax.Array  # (Cin, Cout, Kh, Kw)
    bias: Optional[jax.Array]
    stride: tuple = static_field(default=(1, 1))
    padding: str = static_field(default="SAME")

    @staticmethod
    def create(key, c_in: int, c_out: int, kernel_size, *, stride=(1, 1),
               padding: str = "SAME", use_bias: bool = True,
               dtype=jnp.float32) -> "Conv2D":
        kh, kw = kernel_size if isinstance(kernel_size, tuple) else (kernel_size,) * 2
        w = initializers.he_normal(key, (c_in, c_out, kh, kw), dtype,
                                   fan_in_axes=(0, 2, 3))
        b = jnp.zeros((c_out,), dtype) if use_bias else None
        return Conv2D(weight=w, bias=b, stride=tuple(stride), padding=padding)

    def __call__(self, x: jax.Array) -> jax.Array:
        w = jnp.transpose(self.weight, (1, 0, 2, 3))  # -> (Cout, Cin, Kh, Kw)
        y = _conv2d(x, w, self.stride, self.padding)
        if self.bias is not None:
            y = y + self.bias
        return y


class CED1D(Module):
    """Factorized Conv1D: spatial conv to rank channels + pointwise conv."""

    A: jax.Array  # (Cin, r, S)
    B: jax.Array  # (r, Cout, 1)
    bias: Optional[jax.Array]
    stride: int = static_field(default=1)
    padding: str = static_field(default="SAME")

    @property
    def rank(self) -> int:
        return self.A.shape[1]

    @staticmethod
    def create(key, c_in: int, c_out: int, kernel_size: int, rank: int, *,
               stride: int = 1, padding: str = "SAME", use_bias: bool = True,
               dtype=jnp.float32) -> "CED1D":
        ka, kb = jax.random.split(key)
        A = initializers.he_normal(ka, (c_in, rank, kernel_size), dtype,
                                   fan_in_axes=(0, 2))
        B = initializers.he_normal(kb, (rank, c_out, 1), dtype, fan_in_axes=(0, 2))
        b = jnp.zeros((c_out,), dtype) if use_bias else None
        return CED1D(A=A, B=B, bias=b, stride=stride, padding=padding)

    def __call__(self, x: jax.Array) -> jax.Array:
        wa = jnp.transpose(self.A, (1, 0, 2))  # (r, Cin, S)
        t = _conv1d(x, wa, self.stride, self.padding)
        wb = jnp.transpose(self.B, (1, 0, 2))  # (Cout, r, 1)
        y = _conv1d(t, wb, 1, "SAME")
        if self.bias is not None:
            y = y + self.bias
        return y

    def materialize(self) -> Conv1D:
        """Collapse to a dense Conv1D (pointwise ∘ spatial == one conv)."""
        c_in, r, s = self.A.shape
        # W'[Cin*S, Cout] = A'[Cin*S, r] @ B'[r, Cout]; undo the rearrangement.
        a_mat = jnp.transpose(self.A, (0, 2, 1)).reshape(c_in * s, r)
        w_mat = a_mat @ self.B[:, :, 0]
        w = w_mat.reshape(c_in, s, -1).transpose(0, 2, 1)  # (Cin, Cout, S)
        return Conv1D(weight=w, bias=self.bias, stride=self.stride,
                      padding=self.padding)


class CED2D(Module):
    """Factorized Conv2D: spatial conv to rank channels + 1x1 conv."""

    A: jax.Array  # (Cin, r, Kh, Kw)
    B: jax.Array  # (r, Cout, 1, 1)
    bias: Optional[jax.Array]
    stride: tuple = static_field(default=(1, 1))
    padding: str = static_field(default="SAME")

    @property
    def rank(self) -> int:
        return self.A.shape[1]

    @staticmethod
    def create(key, c_in: int, c_out: int, kernel_size, rank: int, *,
               stride=(1, 1), padding: str = "SAME", use_bias: bool = True,
               dtype=jnp.float32) -> "CED2D":
        kh, kw = kernel_size if isinstance(kernel_size, tuple) else (kernel_size,) * 2
        ka, kb = jax.random.split(key)
        A = initializers.he_normal(ka, (c_in, rank, kh, kw), dtype,
                                   fan_in_axes=(0, 2, 3))
        B = initializers.he_normal(kb, (rank, c_out, 1, 1), dtype,
                                   fan_in_axes=(0, 2, 3))
        b = jnp.zeros((c_out,), dtype) if use_bias else None
        return CED2D(A=A, B=B, bias=b, stride=tuple(stride), padding=padding)

    def __call__(self, x: jax.Array) -> jax.Array:
        wa = jnp.transpose(self.A, (1, 0, 2, 3))
        t = _conv2d(x, wa, self.stride, self.padding)
        wb = jnp.transpose(self.B, (1, 0, 2, 3))
        y = _conv2d(t, wb, (1, 1), "SAME")
        if self.bias is not None:
            y = y + self.bias
        return y

    def materialize(self) -> Conv2D:
        c_in, r, kh, kw = self.A.shape
        a_mat = jnp.transpose(self.A, (0, 2, 3, 1)).reshape(c_in * kh * kw, r)
        w_mat = a_mat @ self.B[:, :, 0, 0]
        w = w_mat.reshape(c_in, kh, kw, -1).transpose(0, 3, 1, 2)
        return Conv2D(weight=w, bias=self.bias, stride=self.stride,
                      padding=self.padding)
