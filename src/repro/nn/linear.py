"""Dense and Low-rank (LED) linear layers.

``Linear`` stores ``weight`` of shape ``(in_features, out_features)`` —
``y = x @ W + b`` — optionally with leading stack axes (layer-stacked weights
for scan-over-layers, or expert-stacked weights for MoE); ``__call__`` always
consumes the *last two* axes.

``LED`` (Linear Encoder-Decoder) is the paper's factorized replacement:
``y = (x @ A) @ B + b`` with ``A: (in, r)`` and ``B: (r, out)``.  When
``fuse='pallas'`` the forward uses the fused Pallas TPU kernel from
``repro.kernels`` that keeps the rank-``r`` intermediate in VMEM;
``fuse='auto'`` picks the kernel on TPU and the plain jnp matmuls
elsewhere (off-TPU the kernel only runs interpreted — correct but slow,
so 'auto' never selects it there).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import init as initializers
from repro.nn.module import Module, static_field


class Linear(Module):
    weight: jax.Array  # (..., in_features, out_features)
    bias: Optional[jax.Array]  # (..., out_features) or None

    @property
    def in_features(self) -> int:
        return self.weight.shape[-2]

    @property
    def out_features(self) -> int:
        return self.weight.shape[-1]

    @staticmethod
    def create(key, in_features: int, out_features: int, *, use_bias: bool = False,
               dtype=jnp.float32, stack_dims: tuple = ()) -> "Linear":
        wkey, _ = jax.random.split(key)
        weight = initializers.lecun_normal(
            wkey, (*stack_dims, in_features, out_features), dtype)
        bias = jnp.zeros((*stack_dims, out_features), dtype) if use_bias else None
        return Linear(weight=weight, bias=bias)

    def __call__(self, x: jax.Array) -> jax.Array:
        y = x @ self.weight
        if self.bias is not None:
            y = y + self.bias
        return y


class LED(Module):
    """Linear Encoder-Decoder layer: ``y = (x @ A) @ B + bias``."""

    A: jax.Array  # (..., in_features, rank)  -- the "encoder"
    B: jax.Array  # (..., rank, out_features) -- the "decoder"
    bias: Optional[jax.Array]
    fuse: str = static_field(default="auto")  # 'auto' | 'jnp' | 'pallas'

    @property
    def in_features(self) -> int:
        return self.A.shape[-2]

    @property
    def out_features(self) -> int:
        return self.B.shape[-1]

    @property
    def rank(self) -> int:
        return self.A.shape[-1]

    @staticmethod
    def create(key, in_features: int, out_features: int, rank: int, *,
               use_bias: bool = False, dtype=jnp.float32,
               stack_dims: tuple = ()) -> "LED":
        ka, kb = jax.random.split(key)
        A = initializers.lecun_normal(ka, (*stack_dims, in_features, rank), dtype)
        B = initializers.lecun_normal(kb, (*stack_dims, rank, out_features), dtype)
        bias = jnp.zeros((*stack_dims, out_features), dtype) if use_bias else None
        return LED(A=A, B=B, bias=bias)

    def __call__(self, x: jax.Array) -> jax.Array:
        if self.fuse == "pallas" or (self.fuse == "auto"
                                     and jax.default_backend() == "tpu"):
            from repro.kernels.ops import led_matmul_trainable

            y = led_matmul_trainable(x, self.A, self.B)
        else:
            y = (x @ self.A) @ self.B
        if self.bias is not None:
            y = y + self.bias
        return y

    def materialize(self) -> Linear:
        """Collapse back to a dense layer (for testing / export)."""
        return Linear(weight=self.A @ self.B, bias=self.bias)
