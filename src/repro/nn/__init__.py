from repro.nn.module import (Module, static_field, iter_modules, map_modules,
                             named_parameters, param_count, tree_slice)
from repro.nn.linear import Linear, LED
from repro.nn.conv import Conv1D, Conv2D, CED1D, CED2D
from repro.nn.norm import RMSNorm, LayerNorm
from repro.nn.embedding import Embedding
from repro.nn.rotary import apply_rope
from repro.nn.attention import (Attention, KVCache, PagedKVCache,
                                UnsupportedCacheError)
from repro.nn.mlp import SwiGLU, GeluMLP
from repro.nn.moe import MoE, MoEOutput
from repro.nn.ssm import Mamba2Mixer, SSMCache, SSMState
from repro.nn.hybrid import HybridCache, HybridMixer, HybridState

__all__ = [
    "Module", "static_field", "iter_modules", "map_modules",
    "named_parameters", "param_count", "tree_slice",
    "Linear", "LED", "Conv1D", "Conv2D", "CED1D", "CED2D",
    "RMSNorm", "LayerNorm", "Embedding", "apply_rope",
    "Attention", "KVCache", "PagedKVCache", "UnsupportedCacheError",
    "SwiGLU", "GeluMLP", "MoE", "MoEOutput",
    "Mamba2Mixer", "SSMCache", "SSMState",
    "HybridCache", "HybridMixer", "HybridState",
]
