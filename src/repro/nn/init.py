"""Parameter initializers (pure functions of a PRNG key)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal(key, shape, dtype=jnp.float32, stddev=0.02):
    return stddev * jax.random.normal(key, shape, dtype)


def lecun_normal(key, shape, dtype=jnp.float32, fan_in_axes=(-2,)):
    fan_in = int(np.prod([shape[a] for a in fan_in_axes]))
    return jax.random.normal(key, shape, dtype) / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(dtype)


def he_normal(key, shape, dtype=jnp.float32, fan_in_axes=(-2,)):
    fan_in = int(np.prod([shape[a] for a in fan_in_axes]))
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(2.0 / max(fan_in, 1)).astype(dtype)


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def uniform(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.uniform(key, shape, dtype, -scale, scale)
