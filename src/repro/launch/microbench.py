"""Kernel microbenchmark harness: per-step decode + per-chunk prefill
timings with compilation separated from steady state, in the style of
maxtext's decode microbenchmark.

    PYTHONPATH=src python -m repro.launch.microbench --smoke \
        --history BENCH_history.jsonl

Every emitted **cell** is one JSON object stamped with explicit
provenance — ``compiled_backend`` (the backend the timing actually
compiled for, or ``null`` when the Pallas kernels ran in interpret
mode) and ``interpret_mode`` — so a 5x "slowdown" measured in
interpret mode on a CPU runner can never again masquerade as a real
perf number.  Cells append to ``BENCH_history.jsonl`` (one line each,
append-only) and ``benchmarks/check_regression.py`` gates the
trajectory against ``benchmarks/thresholds.json``: timing metrics are
compared only against prior cells with *matching* provenance, warn-only
off-TPU; correctness/count metrics hard-fail anywhere.

Four metric families, swept over (batch, seq, block_size, heads):

* ``decode_step_ms`` — one jitted model decode step against a fully
  resident paged cache, ``reference`` (dense gather) vs ``pallas``
  (fused :func:`repro.kernels.paged_attention`).
* ``prefill_chunk_ms`` — one jitted model prefill chunk mid-prompt,
  ``reference`` vs ``pallas`` (flash
  :func:`repro.kernels.chunk_attention`).
* ``kernel_us`` — the raw kernel calls (no model around them):
  ``paged_attention`` / ``chunk_attention``, each vs its jnp oracle.
* ``parity_max_abs_err`` — kernel-vs-oracle max abs error for both
  kernels (the correctness cells the regression gate hard-fails on).

``--sharded`` swaps the kernel matrix for the dp x tp serve grid
(:data:`SHARDED_GRID`): the reference decode / prefill-chunk steps with
params, paged pool, and activations placed on a ``{data, model}`` mesh
(variants ``sharded_dp{dp}tp{tp}``).  It re-execs itself under
``--xla_force_host_platform_device_count=8`` when fewer than 4 devices
are visible, so the grid runs anywhere.

Timing methodology: the first call (trace + compile + first run) is
recorded as ``compile_ms``, never mixed into steady state; ``warmup``
discarded iterations follow; then ``iters`` timed iterations with
``jax.block_until_ready`` per iteration give mean/p50/min.
``--profile-dir`` activates ``jax.profiler`` tracing around the timed
region of every variant (one trace subdir per cell key).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import subprocess
import sys
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

SCHEMA = 1
SUITE = "microbench_kernels"


# ---------------------------------------------------------------------------
# provenance + cell plumbing
# ---------------------------------------------------------------------------


def provenance() -> dict:
    """The stamp every emitted cell carries.

    ``interpret_mode`` is the repo-wide Pallas policy
    (:func:`repro.kernels.ops.default_interpret`): True off-TPU or under
    ``REPRO_PALLAS_INTERPRET=1``.  ``compiled_backend`` is the backend a
    kernel timing actually compiled for — ``None`` in interpret mode,
    because an interpreted timing measures the Pallas interpreter, not
    any hardware.  Two cells are comparable only when both fields (and
    the backend) match; see :func:`comparable`.
    """
    interp = _default_interpret()
    backend = jax.default_backend()
    dev = jax.devices()[0]
    return {
        "backend": backend,
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "compiled_backend": None if interp else backend,
        "interpret_mode": interp,
        "jax_version": jax.__version__,
    }


def _default_interpret() -> bool:
    from repro.kernels.ops import default_interpret

    return default_interpret()


def comparable(a: dict, b: dict) -> bool:
    """May two provenance stamps' timings be compared?  Same backend, same
    interpret mode, same compiled target — an interpret-mode CPU number
    vs a compiled TPU number is not a regression, it's a category error."""
    keys = ("backend", "interpret_mode", "compiled_backend")
    return all(a.get(k) == b.get(k) for k in keys)


def make_cell(metric: str, variant: str, axes: dict, stats: dict,
              prov: Optional[dict] = None, *, smoke: bool = False) -> dict:
    return {
        "schema": SCHEMA,
        "suite": SUITE,
        "metric": metric,
        "variant": variant,
        "axes": dict(axes),
        "stats": dict(stats),
        "provenance": dict(prov if prov is not None else provenance()),
        "smoke": smoke,
        "unix_time": time.time(),
    }


def cell_key(cell: dict) -> str:
    """Stable identity of a tracked series: metric/variant plus the sorted
    sweep axes.  ``check_regression`` groups history lines by this key
    (and by provenance) before comparing."""
    axes = "_".join(f"{k}{v}" for k, v in sorted(cell["axes"].items()))
    return f"{cell['metric']}/{cell['variant']}" + (f"@{axes}" if axes
                                                   else "")


def append_history(path: str, cells: Iterable[dict]) -> int:
    n = 0
    with open(path, "a") as fh:
        for cell in cells:
            fh.write(json.dumps(cell, sort_keys=True) + "\n")
            n += 1
    return n


@contextlib.contextmanager
def maybe_profile(trace_dir: Optional[str]):
    """Profiler-activation hook: wrap a timed region in a
    ``jax.profiler`` trace when a directory is given, no-op otherwise."""
    if not trace_dir:
        yield
        return
    jax.profiler.start_trace(trace_dir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


# ---------------------------------------------------------------------------
# timing core
# ---------------------------------------------------------------------------


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3,
            profile_dir: Optional[str] = None) -> dict:
    """Time ``fn(*args)`` with compile/warmup separated from steady state.

    The first call (trace + compile + run) lands in ``compile_ms`` and
    never pollutes the steady-state stats; ``warmup`` further calls are
    discarded; then ``iters`` calls are timed individually with
    ``jax.block_until_ready`` each, giving mean/p50/min over real
    end-to-end step latencies.
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_ms = (time.perf_counter() - t0) * 1e3
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    with maybe_profile(profile_dir):
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples.append((time.perf_counter() - t0) * 1e3)
    arr = np.asarray(samples)
    return {
        "mean_ms": float(arr.mean()),
        "p50_ms": float(np.percentile(arr, 50)),
        "min_ms": float(arr.min()),
        "std_ms": float(arr.std()),
        "compile_ms": compile_ms,
        "iters": iters,
        "warmup": warmup,
    }


# ---------------------------------------------------------------------------
# synthetic layouts (kernel-level cells need no model)
# ---------------------------------------------------------------------------


def _synthetic_paged(rng, *, batch, seq, block_size, heads, kvh, head_dim,
                     slack_blocks: int = 2):
    """A well-formed paged layout with every slot resident at ``seq``
    tokens: pool, per-slot tables (distinct blocks, sentinel tail), and
    per-slot positions."""
    n_table = -(-seq // block_size)
    n_blocks = batch * n_table + slack_blocks
    kp = jnp.asarray(rng.standard_normal(
        (n_blocks, block_size, kvh, head_dim)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal(
        (n_blocks, block_size, kvh, head_dim)), jnp.float32)
    table = np.full((batch, n_table), n_blocks, np.int32)
    perm = rng.permutation(batch * n_table)
    table[:, :] = perm.reshape(batch, n_table)
    pos = np.full((batch,), seq - 1, np.int32)
    q = jnp.asarray(rng.standard_normal((batch, heads, head_dim)),
                    jnp.float32)
    return q, kp, vp, jnp.asarray(table), jnp.asarray(pos)


def _synthetic_chunk(rng, *, seq, block_size, width, heads, kvh, head_dim):
    """One slot's mid-prompt chunk: resident prefix of ``seq - width``
    tokens behind a mapped table, plus ``width`` fresh chunk rows."""
    offset = max(seq - width, 0)
    n_table = -(-seq // block_size)
    n_blocks = n_table + 2
    kp = jnp.asarray(rng.standard_normal(
        (n_blocks, block_size, kvh, head_dim)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal(
        (n_blocks, block_size, kvh, head_dim)), jnp.float32)
    table = jnp.asarray(rng.permutation(n_blocks)[:n_table], jnp.int32)
    q = jnp.asarray(rng.standard_normal((width, heads, head_dim)),
                    jnp.float32)
    kc = jnp.asarray(rng.standard_normal((width, kvh, head_dim)),
                     jnp.float32)
    vc = jnp.asarray(rng.standard_normal((width, kvh, head_dim)),
                     jnp.float32)
    return (q, kp, vp, table, kc, vc, jnp.int32(offset), jnp.int32(width))


# ---------------------------------------------------------------------------
# the benchmarked paths
# ---------------------------------------------------------------------------


def bench_kernel_cells(point: dict, *, iters: int, warmup: int,
                       prov: dict, smoke: bool,
                       profile_dir: Optional[str] = None) -> list[dict]:
    """Raw-kernel cells for one sweep point: ``kernel_us`` timings for
    paged_attention / chunk_attention (kernel + oracle each) plus the
    ``parity_max_abs_err`` correctness cells."""
    from repro.kernels import (chunk_attention, chunk_attention_ref,
                               paged_attention, paged_attention_ref)

    rng = np.random.default_rng(0)
    axes = dict(point)
    kvh = max(1, point["heads"] // 2)
    dims = dict(batch=point["batch"], seq=point["seq"],
                block_size=point["block_size"], heads=point["heads"],
                kvh=kvh, head_dim=16)
    cells = []

    def prof(name):
        return f"{profile_dir}/{name}" if profile_dir else None

    # --- paged_attention (decode) ---
    q, kp, vp, table, pos = _synthetic_paged(rng, **dims)
    fused = jax.jit(paged_attention)
    oracle = jax.jit(paged_attention_ref)
    out_k = fused(q, kp, vp, table, pos)
    out_r = oracle(q, kp, vp, table, pos)
    err = float(jnp.abs(out_k - out_r).max())
    cells.append(make_cell("parity_max_abs_err", "paged_attention", axes,
                           {"value": err}, prov, smoke=smoke))
    for variant, fn in (("pallas", fused), ("ref", oracle)):
        stats = time_fn(fn, q, kp, vp, table, pos, iters=iters,
                        warmup=warmup,
                        profile_dir=prof(f"paged_attention_{variant}"))
        stats["us_per_call"] = stats["mean_ms"] * 1e3
        cells.append(make_cell("kernel_us", f"paged_attention_{variant}",
                               axes, stats, prov, smoke=smoke))

    # --- chunk_attention (prefill chunk) ---
    width = min(point["seq"], max(point["block_size"], 8))
    case = _synthetic_chunk(rng, seq=point["seq"],
                            block_size=point["block_size"], width=width,
                            heads=point["heads"], kvh=kvh, head_dim=16)
    flash = jax.jit(chunk_attention)
    coracle = jax.jit(chunk_attention_ref)
    out_k = flash(*case)
    out_r = coracle(*case)
    err = float(jnp.abs(out_k - out_r).max())  # every row valid here
    cells.append(make_cell("parity_max_abs_err", "chunk_attention", axes,
                           {"value": err}, prov, smoke=smoke))
    for variant, fn in (("pallas", flash), ("ref", coracle)):
        stats = time_fn(fn, *case, iters=iters, warmup=warmup,
                        profile_dir=prof(f"chunk_attention_{variant}"))
        stats["us_per_call"] = stats["mean_ms"] * 1e3
        cells.append(make_cell("kernel_us", f"chunk_attention_{variant}",
                               axes, stats, prov, smoke=smoke))
    return cells


def _bench_model(point: dict):
    """A tiny model matched to the sweep point's head count."""
    from repro.configs import get_config
    from repro.models import build_model

    heads = point["heads"]
    kvh = max(1, heads // 2)
    cfg = get_config("paper-tiny").reduced().replace(
        n_heads=heads, n_kv_heads=kvh, head_dim=16, d_model=16 * heads)
    return build_model(jax.random.PRNGKey(0), cfg), cfg


def bench_decode_step_cells(point: dict, *, iters: int, warmup: int,
                            prov: dict, smoke: bool,
                            profile_dir: Optional[str] = None
                            ) -> list[dict]:
    """``decode_step_ms`` cells: one jitted model decode step (all slots
    live at ``seq`` tokens) for the reference gather vs the fused Pallas
    kernel — scheduler/admission overhead excluded by construction."""
    model, cfg = _bench_model(point)
    batch, seq, bs = point["batch"], point["seq"], point["block_size"]
    max_len = seq + 8
    n_table = -(-max_len // bs)
    cache0 = model.init_paged_cache(batch, max_len, cfg,
                                    n_blocks=batch * n_table + 1,
                                    block_size=bs, dtype=jnp.float32)
    table = np.asarray(
        np.random.default_rng(0).permutation(batch * n_table)
    ).reshape(batch, n_table).astype(np.int32)
    cache = cache0._replace(
        table=jnp.asarray(table),
        length=jnp.broadcast_to(jnp.int32(seq), cache0.length.shape))
    tok = jnp.zeros((batch, 1), jnp.int32)
    cells = []
    for variant in ("reference", "pallas"):
        fn = jax.jit(
            lambda t, c, k=variant: model.decode(t, c, decode_kernel=k)[0])
        stats = time_fn(fn, tok, cache, iters=iters, warmup=warmup,
                        profile_dir=(f"{profile_dir}/decode_{variant}"
                                     if profile_dir else None))
        cells.append(make_cell("decode_step_ms", variant, dict(point),
                               stats, prov, smoke=smoke))
    return cells


def bench_prefill_chunk_cells(point: dict, *, iters: int, warmup: int,
                              prov: dict, smoke: bool,
                              profile_dir: Optional[str] = None
                              ) -> list[dict]:
    """``prefill_chunk_ms`` cells: one jitted model prefill chunk
    mid-prompt (resident prefix of ``seq - W`` tokens, chunk width ``W``
    = ``block_size``), reference gather vs flash Pallas kernel."""
    model, cfg = _bench_model(point)
    batch, seq, bs = point["batch"], point["seq"], point["block_size"]
    w = min(seq // 2 or 1, bs)
    offset = seq - w
    max_len = seq + 8
    n_table = -(-max_len // bs)
    cache0 = model.init_paged_cache(batch, max_len, cfg,
                                    n_blocks=batch * n_table + 1,
                                    block_size=bs, dtype=jnp.float32)
    table = np.asarray(
        np.random.default_rng(0).permutation(batch * n_table)
    ).reshape(batch, n_table).astype(np.int32)
    cache = cache0._replace(
        table=jnp.asarray(table),
        length=jnp.broadcast_to(jnp.int32(offset), cache0.length.shape))
    toks = jnp.zeros((1, w), jnp.int32)
    qpos = offset + np.arange(w)
    dst = jnp.asarray(table[0][qpos // bs] * bs + qpos % bs)
    cells = []
    for variant in ("reference", "pallas"):
        fn = jax.jit(lambda t, c, k=variant: model.prefill_chunk(
            t, c, slot=jnp.int32(0), offset=jnp.int32(offset),
            n_valid=jnp.int32(w), dst=dst, need_logits=True,
            prefill_kernel=k)[0])
        stats = time_fn(fn, toks, cache, iters=iters, warmup=warmup,
                        profile_dir=(f"{profile_dir}/prefill_{variant}"
                                     if profile_dir else None))
        stats["chunk_width"] = w
        cells.append(make_cell("prefill_chunk_ms", variant, dict(point),
                               stats, prov, smoke=smoke))
    return cells


def bench_sharded_step_cells(point: dict, *, iters: int, warmup: int,
                             prov: dict, smoke: bool) -> list[dict]:
    """``decode_step_ms`` / ``prefill_chunk_ms`` cells under a
    ``{data, model}`` mesh: the SAME jitted reference step with params
    placed via :func:`repro.dist.sharding.model_shardings`, the paged
    pool/table via ``cache_shardings``, and activations constrained
    through an ``activation_mesh`` scope at trace time — variants
    ``sharded_dp{dp}tp{tp}`` over the serve grid.  Pallas variants are
    deliberately absent: the kernels are single-shard and the engine
    refuses them under tp>1."""
    from repro.dist.runtime import make_serve_mesh
    from repro.dist.sharding import (activation_mesh, cache_shardings,
                                     model_shardings)

    model0, cfg = _bench_model(point)
    batch, seq, bs = point["batch"], point["seq"], point["block_size"]
    w = min(seq // 2 or 1, bs)
    offset = seq - w
    max_len = seq + 8
    n_table = -(-max_len // bs)
    cache0 = model0.init_paged_cache(batch, max_len, cfg,
                                     n_blocks=batch * n_table + 1,
                                     block_size=bs, dtype=jnp.float32)
    table = np.asarray(
        np.random.default_rng(0).permutation(batch * n_table)
    ).reshape(batch, n_table).astype(np.int32)
    tok = jnp.zeros((batch, 1), jnp.int32)
    toks = jnp.zeros((1, w), jnp.int32)
    qpos = offset + np.arange(w)
    dst = jnp.asarray(table[0][qpos // bs] * bs + qpos % bs)
    cells = []
    for dp, tp in SHARDED_GRID:
        if dp * tp > len(jax.devices()):
            continue  # run_sharded_sweep re-execs with 8 forced devices
        mesh = make_serve_mesh(f"{dp},{tp}")
        variant = f"sharded_dp{dp}tp{tp}"
        if mesh is None:  # 1x1: the unsharded reference path
            model, dcache = model0, cache0
        else:
            model = jax.device_put(model0, model_shardings(model0, mesh))
            dcache = jax.device_put(cache0, cache_shardings(cache0, mesh))
        dcache = dcache._replace(
            table=jnp.asarray(table),
            length=jnp.broadcast_to(jnp.int32(seq), cache0.length.shape))

        def dec(t, c, model=model, mesh=mesh):
            with activation_mesh(mesh) if mesh is not None \
                    else contextlib.nullcontext():
                return model.decode(t, c)[0]

        stats = time_fn(jax.jit(dec), tok, dcache, iters=iters,
                        warmup=warmup)
        cells.append(make_cell("decode_step_ms", variant, dict(point),
                               stats, prov, smoke=smoke))

        pcache = dcache._replace(
            length=jnp.broadcast_to(jnp.int32(offset), cache0.length.shape))

        def pre(t, c, model=model, mesh=mesh):
            with activation_mesh(mesh) if mesh is not None \
                    else contextlib.nullcontext():
                return model.prefill_chunk(
                    t, c, slot=jnp.int32(0), offset=jnp.int32(offset),
                    n_valid=jnp.int32(w), dst=dst, need_logits=True)[0]

        stats = time_fn(jax.jit(pre), toks, pcache, iters=iters,
                        warmup=warmup)
        stats["chunk_width"] = w
        cells.append(make_cell("prefill_chunk_ms", variant, dict(point),
                               stats, prov, smoke=smoke))
    return cells


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

# the dp x tp serve grid every sharded bench walks (CPU-emulable with
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
SHARDED_GRID = [(1, 1), (2, 1), (1, 2), (2, 2)]

SMOKE_SWEEP = [
    {"batch": 2, "seq": 32, "block_size": 8, "heads": 4},
    {"batch": 4, "seq": 64, "block_size": 16, "heads": 4},
]

FULL_SWEEP = [
    {"batch": b, "seq": s, "block_size": bs, "heads": h}
    for b in (2, 8)
    for s in (64, 256)
    for bs in (8, 16)
    for h in (4, 8)
]


def run_sweep(*, smoke: bool = True, iters: int = 10, warmup: int = 2,
              profile_dir: Optional[str] = None,
              sweep: Optional[list[dict]] = None) -> list[dict]:
    """Run the full microbench matrix; returns the emitted cells (one per
    metric/variant/sweep-point, plus one ``cells_emitted`` count cell the
    regression gate hard-fails on if a benchmarked path disappears)."""
    prov = provenance()
    points = sweep if sweep is not None else (SMOKE_SWEEP if smoke
                                              else FULL_SWEEP)
    cells: list[dict] = []
    for point in points:
        cells.extend(bench_kernel_cells(
            point, iters=iters, warmup=warmup, prov=prov, smoke=smoke,
            profile_dir=profile_dir))
        cells.extend(bench_decode_step_cells(
            point, iters=iters, warmup=warmup, prov=prov, smoke=smoke,
            profile_dir=profile_dir))
        cells.extend(bench_prefill_chunk_cells(
            point, iters=iters, warmup=warmup, prov=prov, smoke=smoke,
            profile_dir=profile_dir))
    paths = sorted({f"{c['metric']}/{c['variant']}" for c in cells})
    cells.append(make_cell("cells_emitted", "total", {},
                           {"value": len(cells), "paths": paths}, prov,
                           smoke=smoke))
    return cells


def run_sharded_sweep(*, smoke: bool = True, iters: int = 10,
                      warmup: int = 2) -> list[dict]:
    """The sharded microbench matrix (``--sharded``): reference decode +
    prefill-chunk steps at every dp x tp point of :data:`SHARDED_GRID`,
    plus its own ``cells_emitted/sharded`` count cell so the regression
    gate hard-fails if a mesh point silently drops out of the sweep."""
    prov = provenance()
    points = SMOKE_SWEEP[:1] if smoke else SMOKE_SWEEP
    cells: list[dict] = []
    for point in points:
        cells.extend(bench_sharded_step_cells(
            point, iters=iters, warmup=warmup, prov=prov, smoke=smoke))
    paths = sorted({f"{c['metric']}/{c['variant']}" for c in cells})
    cells.append(make_cell("cells_emitted", "sharded", {},
                           {"value": len(cells), "paths": paths}, prov,
                           smoke=smoke))
    return cells


def format_cell(cell: dict) -> str:
    s = cell["stats"]
    if "mean_ms" in s:
        body = (f"{s['mean_ms']:9.3f} ms  (p50 {s['p50_ms']:.3f}, min "
                f"{s['min_ms']:.3f}, compile {s['compile_ms']:.0f})")
    else:
        body = f"{s['value']}"
    p = cell["provenance"]
    tag = (p["compiled_backend"] or
           f"{p['backend']}+interpret")
    return f"{cell_key(cell):66s} {body}  [{tag}]"


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--smoke", action="store_true",
                   help="small sweep + few iters (the CI cell)")
    p.add_argument("--iters", type=int, default=0,
                   help="steady-state iterations (0 = 10 smoke / 30 full)")
    p.add_argument("--warmup", type=int, default=2)
    p.add_argument("--history", default="",
                   help="append every cell to this JSONL perf trajectory")
    p.add_argument("--json", default="",
                   help="write this run's cells as one JSON array")
    p.add_argument("--profile-dir", default="",
                   help="activate jax.profiler around every timed region, "
                        "one trace per cell under this directory")
    p.add_argument("--sharded", action="store_true",
                   help="run the dp x tp sharded step sweep instead of the "
                        "kernel matrix (re-execs itself under 8 forced CPU "
                        "host devices when fewer than 4 are visible)")
    args = p.parse_args(argv)
    iters = args.iters or (10 if args.smoke else 30)
    if args.sharded and len(jax.devices()) < 4:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env.setdefault("JAX_PLATFORMS", "cpu")
        print("# <4 devices visible; re-exec with "
              "--xla_force_host_platform_device_count=8")
        code = ("from repro.launch.microbench import main; import sys; "
                "sys.exit(main(sys.argv[1:]))")
        return subprocess.run(
            [sys.executable, "-c", code] + list(argv or sys.argv[1:]),
            env=env).returncode
    if args.sharded:
        cells = run_sharded_sweep(smoke=args.smoke, iters=iters,
                                  warmup=args.warmup)
    else:
        cells = run_sweep(smoke=args.smoke, iters=iters, warmup=args.warmup,
                          profile_dir=args.profile_dir or None)
    for cell in cells:
        print(format_cell(cell))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(cells, fh, indent=1, sort_keys=True)
        print(f"# wrote {len(cells)} cells to {args.json}")
    if args.history:
        n = append_history(args.history, cells)
        print(f"# appended {n} cells to {args.history}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
