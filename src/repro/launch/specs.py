"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns the kwargs for the step function that the
dry-run lowers — weak-type-correct, shardable, zero allocation.  Shapes
follow the assignment: train/prefill take the full sequence; decode shapes
lower ONE new token against a pre-filled KV/SSM cache of ``seq_len``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig

WHISPER_ENC_FRAMES = 1500  # 30 s of audio after the (stubbed) conv frontend


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    """eval_shape the model's init_cache — zero allocation."""
    from repro.models import build_model

    def mk():
        model = build_model(jax.random.PRNGKey(0), cfg)
        kwargs = ({"enc_len": WHISPER_ENC_FRAMES}
                  if cfg.family == "encdec" else {})
        return model.init_cache(batch, max_len, cfg, dtype=dtype, **kwargs)

    return jax.eval_shape(mk)


def model_specs(cfg: ArchConfig, *, remat: bool = False):
    """ShapeDtypeStruct pytree of the model itself (no allocation)."""
    from repro.models import build_model

    return jax.eval_shape(
        lambda: build_model(jax.random.PRNGKey(0), cfg, remat=remat))


def input_specs(cfg: ArchConfig, shape_name: str,
                cache_dtype: str = "bfloat16") -> dict:
    shape: ShapeConfig = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    cdt = jnp.dtype(cache_dtype)

    if shape.kind == "train":
        batch = {"tokens": _sds((b, s), tok), "labels": _sds((b, s), tok)}
        if cfg.family == "encdec":
            batch["frames"] = _sds((b, WHISPER_ENC_FRAMES, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        return {"batch": batch}

    if shape.kind == "prefill":
        out = {"tokens": _sds((b, s), tok),
               "cache": _cache_specs(cfg, b, s, dtype=cdt)}
        if cfg.family == "encdec":
            out["frames"] = _sds((b, WHISPER_ENC_FRAMES, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
        return out

    if shape.kind == "decode":
        return {"token": _sds((b, 1), tok),
                "cache": _cache_specs(cfg, b, s, dtype=cdt)}

    raise ValueError(shape.kind)
