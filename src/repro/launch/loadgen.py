"""Load generator for the HTTP serving front door (``repro.serve.http``).

    # boot the server in one shell...
    PYTHONPATH=src python -m repro.launch.serve --reduced --http --port 8000

    # ...and drive it from another
    PYTHONPATH=src python -m repro.launch.loadgen --port 8000 \
        --mode open --rate 8 --n-requests 32 --cancel-frac 0.2 \
        --json loadgen_summary.json --strict

Pure stdlib + numpy — no jax, no model: the client speaks the server's
own SSE protocol over raw asyncio sockets, so it measures the full
serving stack (HTTP parse, admission queue, pump, stream writes), not a
shortcut around it.

Two driving modes:

* ``--mode closed`` — **closed loop**: ``--concurrency`` workers each
  keep exactly one request in flight, next request submitted when the
  previous finishes.  Measures per-request latency under a fixed
  concurrency; backpressure never triggers by construction (offered load
  follows service rate).
* ``--mode open`` — **open loop**: requests arrive by a Poisson process
  at ``--rate`` per second regardless of completions — the arrival
  pattern real traffic has.  Under overload the admission queue fills
  and the server answers 429 (counted, not retried); ``--cancel-frac``
  makes that fraction of clients disconnect after their first token,
  exercising the cancellation path under load.

Per-request results carry ``status``, ``tokens``, ``finish_reason``,
``ttft_s``, ``latency_s``, and ``cancelled_by_client``; ``summarize``
reduces them to the throughput/latency summary the benchmark stores and
CI uploads.  ``--priority-mix w0,w1,...`` assigns each request a
priority class sampled from those weights (class 0 = most urgent) and
the summary grows a per-class TTFT breakdown — the mixed-priority
traffic that exercises the engine's priority admission + decode
preemption.  ``--strict`` exits non-zero when the run looks broken
(unreachable server, unscrapeable ``/metrics``, a request with no
terminal outcome, zero client cancels despite ``--cancel-frac``, a
non-zero ``repro_serve_preempt_violations_total`` — a lower-priority
request preempted a higher one — or KV blocks still in use after the
engine drains, i.e. a block leak).
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time
from typing import List, Optional, Tuple

import numpy as np


# -- protocol client ---------------------------------------------------------


async def fetch(host: str, port: int, path: str,
                timeout_s: float = 10.0) -> Tuple[int, bytes]:
    """One GET; returns (status, body).  Raises on connect failure."""
    reader, writer = await asyncio.wait_for(
        asyncio.open_connection(host, port), timeout_s)
    try:
        writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n"
                     f"Connection: close\r\n\r\n".encode())
        await writer.drain()
        status_line = await asyncio.wait_for(reader.readline(), timeout_s)
        status = int(status_line.split()[1])
        n_body = None
        while True:
            h = await asyncio.wait_for(reader.readline(), timeout_s)
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin1").partition(":")
            if k.strip().lower() == "content-length":
                n_body = int(v)
        body = (await reader.readexactly(n_body) if n_body is not None
                else await reader.read())
        return status, body
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


async def sse_generate(host: str, port: int, payload: dict, *,
                       cancel_after_tokens: Optional[int] = None,
                       timeout_s: float = 60.0) -> dict:
    """POST one request to ``/v1/generate`` and consume its SSE stream.

    ``cancel_after_tokens=N`` disconnects abruptly after the N-th token —
    the client-abandons-mid-stream behaviour the server must translate
    into an engine cancel.  Never raises for protocol-level failures: the
    result dict records what happened (``status`` 0 = connect failure)."""
    res = {"status": 0, "tokens": [], "finish_reason": None,
           "ttft_s": None, "latency_s": None,
           "cancelled_by_client": False, "error": None}
    t0 = time.monotonic()
    body = json.dumps(payload).encode()
    try:
        reader, writer = await asyncio.wait_for(
            asyncio.open_connection(host, port), timeout_s)
    except (OSError, asyncio.TimeoutError) as exc:
        res["error"] = f"connect: {exc!r}"
        return res
    try:
        writer.write(
            f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n".encode() + body)
        await writer.drain()
        status_line = await asyncio.wait_for(reader.readline(), timeout_s)
        res["status"] = int(status_line.split()[1])
        n_body = None
        while True:  # headers
            h = await asyncio.wait_for(reader.readline(), timeout_s)
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin1").partition(":")
            if k.strip().lower() == "content-length":
                n_body = int(v)
        if res["status"] != 200:
            raw = (await reader.readexactly(n_body) if n_body is not None
                   else await reader.read())
            res["error"] = raw.decode("utf-8", "replace")
            return res
        event = "message"
        while True:  # SSE event stream
            line = await asyncio.wait_for(reader.readline(), timeout_s)
            if not line:  # server closed without a done event
                res["error"] = res["error"] or "stream closed early"
                break
            line = line.strip()
            if not line:
                event = "message"
                continue
            if line.startswith(b"event:"):
                event = line.split(b":", 1)[1].strip().decode()
                continue
            if not line.startswith(b"data:"):
                continue
            data = json.loads(line.split(b":", 1)[1])
            if event == "done":
                res["finish_reason"] = data["finish_reason"]
                res["latency_s"] = time.monotonic() - t0
                break
            if res["ttft_s"] is None:
                res["ttft_s"] = time.monotonic() - t0
            res["tokens"].append(int(data["token"]))
            if (cancel_after_tokens is not None
                    and len(res["tokens"]) >= cancel_after_tokens):
                res["cancelled_by_client"] = True
                res["latency_s"] = time.monotonic() - t0
                break
        return res
    except (OSError, asyncio.TimeoutError,
            asyncio.IncompleteReadError) as exc:
        res["error"] = repr(exc)
        return res
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


# -- driving modes -----------------------------------------------------------


async def run_closed_loop(host: str, port: int, payloads: List[dict], *,
                          concurrency: int = 4,
                          timeout_s: float = 60.0) -> List[dict]:
    """Fixed-concurrency workers; results in input order."""
    results: List[Optional[dict]] = [None] * len(payloads)
    it = iter(range(len(payloads)))

    async def worker():
        for i in it:  # the shared iterator is the work queue
            results[i] = await sse_generate(host, port, payloads[i],
                                            timeout_s=timeout_s)

    await asyncio.gather(*(worker() for _ in range(concurrency)))
    return results  # type: ignore[return-value]


async def run_open_loop(host: str, port: int, payloads: List[dict], *,
                        rate: float = 4.0, cancel_frac: float = 0.0,
                        seed: int = 0,
                        timeout_s: float = 60.0) -> List[dict]:
    """Poisson arrivals at ``rate``/s, independent of completions; a
    ``cancel_frac`` fraction of clients disconnect after their first
    token.  Results in submission order."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), len(payloads))
    cancels = rng.random(len(payloads)) < cancel_frac
    tasks = []
    for gap, payload, cancel in zip(gaps, payloads, cancels):
        await asyncio.sleep(float(gap))
        tasks.append(asyncio.ensure_future(sse_generate(
            host, port, payload,
            cancel_after_tokens=1 if cancel else None,
            timeout_s=timeout_s)))
    return list(await asyncio.gather(*tasks))


def summarize(results: List[dict], wall: float) -> dict:
    """Reduce per-request results to the benchmark/CI summary."""

    def pct(vals, q):
        return float(np.percentile(np.asarray(vals), q)) if vals else 0.0

    served = [r for r in results
              if r["status"] == 200 and not r["cancelled_by_client"]
              and r["finish_reason"] is not None]
    ttfts = [r["ttft_s"] for r in results if r["ttft_s"] is not None]
    lats = [r["latency_s"] for r in served if r["latency_s"] is not None]
    n_tok = sum(len(r["tokens"]) for r in results)
    by_priority = {}
    for prio in sorted({r.get("priority") for r in results
                        if r.get("priority") is not None}):
        sub = [r["ttft_s"] for r in results
               if r.get("priority") == prio and r["ttft_s"] is not None]
        by_priority[str(prio)] = {
            "requests": sum(r.get("priority") == prio for r in results),
            "ttft_p50_ms": pct(sub, 50) * 1e3,
            "ttft_p95_ms": pct(sub, 95) * 1e3,
        }
    return {
        "requests": len(results),
        "served": len(served),
        "cancelled_by_client": sum(r["cancelled_by_client"]
                                   for r in results),
        "rejected_429": sum(r["status"] == 429 for r in results),
        "errors": sum(r["status"] not in (200, 429) for r in results),
        "finish_reasons": {
            reason: sum(r["finish_reason"] == reason for r in results)
            for reason in sorted({r["finish_reason"] for r in results
                                  if r["finish_reason"] is not None})},
        "streamed_tokens": n_tok,
        "wall_s": wall,
        "tokens_per_s": n_tok / max(wall, 1e-9),
        "ttft_p50_ms": pct(ttfts, 50) * 1e3,
        "ttft_p95_ms": pct(ttfts, 95) * 1e3,
        "latency_p50_ms": pct(lats, 50) * 1e3,
        "latency_p95_ms": pct(lats, 95) * 1e3,
        **({"by_priority": by_priority} if by_priority else {}),
    }


def make_payloads(n: int, *, seed: int = 0, min_prompt: int = 4,
                  max_prompt: int = 24, min_new: int = 4, max_new: int = 16,
                  vocab: int = 256, timeout_s: Optional[float] = None,
                  priority_mix: Optional[List[float]] = None) -> List[dict]:
    """Reproducible random request bodies (mirrors ``make_trace`` dims
    without needing a model).  ``priority_mix`` = weights over priority
    classes ``0..len(mix)-1``, sampled per request into the body."""
    rng = np.random.default_rng(seed)
    weights = None
    if priority_mix is not None:
        weights = np.asarray(priority_mix, np.float64)
        if weights.ndim != 1 or weights.size < 1 or (weights < 0).any() \
                or weights.sum() <= 0:
            raise ValueError("priority_mix must be non-negative weights")
        weights = weights / weights.sum()
    out = []
    for _ in range(n):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        payload = {
            "prompt": rng.integers(0, vocab, plen).tolist(),
            "max_new_tokens": int(rng.integers(min_new, max_new + 1)),
        }
        if timeout_s is not None:
            payload["timeout_s"] = timeout_s
        if weights is not None:
            payload["priority"] = int(
                rng.choice(np.arange(weights.size), p=weights))
        out.append(payload)
    return out


def metric_value(text: str, name: str) -> Optional[float]:
    """Pull one un-labelled gauge/counter value out of a Prometheus
    exposition body; ``None`` if the series is absent."""
    for line in text.splitlines():
        if line.startswith(name) and not line.startswith("#"):
            rest = line[len(name):]
            if rest[:1] in (" ", "\t"):  # exact name, not a prefix
                try:
                    return float(rest.strip())
                except ValueError:
                    return None
    return None


# -- CLI ---------------------------------------------------------------------


async def _amain(args) -> int:
    # wait for the server (CI boots it concurrently)
    deadline = time.monotonic() + args.wait_s
    while True:
        try:
            status, _ = await fetch(args.host, args.port, "/healthz")
            if status == 200:
                break
        except (OSError, asyncio.TimeoutError):
            pass
        if time.monotonic() >= deadline:
            print(f"server at {args.host}:{args.port} not healthy within "
                  f"{args.wait_s}s", file=sys.stderr)
            return 1
        await asyncio.sleep(0.2)

    priority_mix = ([float(w) for w in args.priority_mix.split(",")]
                    if args.priority_mix else None)
    payloads = make_payloads(
        args.n_requests, seed=args.seed, max_prompt=args.max_prompt,
        max_new=args.max_new, vocab=args.vocab,
        timeout_s=args.request_timeout if args.request_timeout > 0
        else None, priority_mix=priority_mix)
    t0 = time.monotonic()
    if args.mode == "closed":
        results = await run_closed_loop(args.host, args.port, payloads,
                                        concurrency=args.concurrency,
                                        timeout_s=args.timeout_s)
    else:
        results = await run_open_loop(args.host, args.port, payloads,
                                      rate=args.rate,
                                      cancel_frac=args.cancel_frac,
                                      seed=args.seed,
                                      timeout_s=args.timeout_s)
    wall = time.monotonic() - t0
    for r, payload in zip(results, payloads):  # results in payload order
        r["priority"] = payload.get("priority")
    summary = {"mode": args.mode, **summarize(results, wall)}

    try:
        status, metrics_body = await fetch(args.host, args.port, "/metrics")
        metrics_text = metrics_body.decode("utf-8", "replace")
        summary["metrics_scraped"] = status == 200
    except (OSError, asyncio.TimeoutError):
        status, metrics_text = 0, ""
        summary["metrics_scraped"] = False

    print(json.dumps(summary, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"summary": summary, "results": results}, f, indent=2)

    if args.strict:
        problems = []
        if not summary["metrics_scraped"]:
            problems.append("/metrics not scrapeable")
        for name in ("repro_serve_ttft_seconds",
                     "repro_serve_prefix_hit_rate",
                     "repro_serve_completions_total"):
            if name not in metrics_text:
                problems.append(f"metric {name} missing from /metrics")
        if summary["errors"]:
            problems.append(f"{summary['errors']} request(s) without a "
                            "terminal outcome")
        if args.cancel_frac > 0 and not summary["cancelled_by_client"]:
            problems.append("cancel-frac > 0 but no client cancelled")
        if summary["served"] == 0:
            problems.append("no request was served to completion")
        violations = metric_value(metrics_text,
                                  "repro_serve_preempt_violations_total")
        if violations:  # absent (no preemption support) is not a failure
            problems.append(f"{int(violations)} preemption violation(s): "
                            "a lower-priority request preempted a higher "
                            "one")
        # every stream has terminated client-side, but the engine drains
        # its last slots asynchronously — poll briefly before calling a
        # non-zero blocks_in_use a leak
        in_use = metric_value(metrics_text, "repro_serve_kv_blocks_in_use")
        for _ in range(25):
            if not in_use:  # None (dense layout) or drained to 0
                break
            await asyncio.sleep(0.2)
            try:
                _, body = await fetch(args.host, args.port, "/metrics")
                in_use = metric_value(body.decode("utf-8", "replace"),
                                      "repro_serve_kv_blocks_in_use")
            except (OSError, asyncio.TimeoutError):
                break
        if in_use:
            problems.append(f"{int(in_use)} KV block(s) still in use "
                            "after drain (leak)")
        if problems:
            print("STRICT FAILURES: " + "; ".join(problems),
                  file=sys.stderr)
            return 1
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8000)
    p.add_argument("--mode", choices=("closed", "open"), default="closed")
    p.add_argument("--n-requests", type=int, default=16)
    p.add_argument("--concurrency", type=int, default=4,
                   help="closed loop: requests kept in flight")
    p.add_argument("--rate", type=float, default=4.0,
                   help="open loop: Poisson arrivals per second")
    p.add_argument("--cancel-frac", type=float, default=0.0,
                   help="open loop: fraction of clients that disconnect "
                        "after their first token")
    p.add_argument("--max-prompt", type=int, default=24)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--vocab", type=int, default=256,
                   help="token-id range of the random prompts (must not "
                        "exceed the served model's vocab)")
    p.add_argument("--priority-mix", default="",
                   help="comma weights over priority classes 0..k-1 "
                        "(class 0 = most urgent) sampled per request, "
                        "e.g. 0.3,0.4,0.3; empty = all default priority")
    p.add_argument("--request-timeout", type=float, default=0.0,
                   help="per-request deadline sent in the body "
                        "(server cancels past it; 0 = none)")
    p.add_argument("--timeout-s", type=float, default=60.0,
                   help="client-side socket timeout per request")
    p.add_argument("--wait-s", type=float, default=60.0,
                   help="max seconds to wait for /healthz before failing")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="",
                   help="write {summary, results} JSON here")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on anomalies (missing metrics, "
                        "non-terminal requests, expected-but-absent "
                        "cancels, preemption priority violations, "
                        "leaked KV blocks)")
    args = p.parse_args(argv)
    return asyncio.run(_amain(args))


if __name__ == "__main__":
    sys.exit(main())
