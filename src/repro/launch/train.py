"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch paper-tiny --steps 200 \
        --batch 16 --seq 64 --ckpt-dir /tmp/run1 [--fact-rank 0.25 --solver random]

Production behaviours exercised here (and relied on at scale):
  * always-resume: restores the newest complete checkpoint before training —
    any crash/preemption is survivable by simply relaunching the same command;
  * SIGTERM/SIGINT → checkpoint-then-exit (clean preemption handling);
  * step-indexed data: batch k is a pure function of (seed, k), so resume and
    elastic re-sharding reproduce the exact stream;
  * Greenformer factorization-by-design via --fact-rank (the paper's use
    case 1) — one flag factorizes the model before training;
  * optional low-rank gradient compression (--grad-comp-rank) on the DP axis.
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import auto_fact
from repro.core.gradcomp import init_compressor
from repro.data import markov_lm_batch
from repro.models import build_model
from repro.optim import AdamW, linear_warmup_cosine
from repro.train import TrainState, make_train_step


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-tiny")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--warmup", type=int, default=20)
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--fact-rank", type=float, default=0.0,
                   help="Greenformer factorization-by-design rank ratio")
    p.add_argument("--solver", default="random")
    p.add_argument("--grad-comp-rank", type=int, default=0)
    p.add_argument("--reduced", action="store_true",
                   help="use the reduced (smoke) config of the arch")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced or args.arch != "paper-tiny":
        cfg = cfg.reduced() if args.arch != "paper-tiny" else cfg
    if args.reduced and args.arch == "paper-tiny":
        cfg = cfg.reduced()

    key = jax.random.PRNGKey(args.seed)
    model = build_model(key, cfg)
    if args.fact_rank:
        model, report = auto_fact(
            model, args.fact_rank, solver=args.solver, key=key,
            return_report=True)
        print(report.summary())

    opt = AdamW(linear_warmup_cosine(args.lr, args.warmup, args.steps),
                weight_decay=0.01, master_fp32=False)
    compressor = None
    compression_axis = None
    if args.grad_comp_rank:
        zero_grads = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros_like(p), model)
        compressor = init_compressor(zero_grads, args.grad_comp_rank, key)
    state = TrainState(model=model, opt=opt.init(model),
                       step=jnp.zeros((), jnp.int32), compressor=compressor)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt is not None:
        found, state = ckpt.restore_latest(state)
        if found is not None:
            start = found
            print(f"[resume] restored step {found}")

    step_fn = jax.jit(make_train_step(
        opt, accum=args.accum, compression_axis=compression_axis))

    stop = {"now": False}

    def _handler(sig, frame):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _handler)
    signal.signal(signal.SIGINT, _handler)

    t0 = time.time()
    metrics = {}
    for i in range(start, args.steps):
        b = markov_lm_batch(i, batch=args.batch, seq=args.seq,
                            vocab=cfg.vocab, seed=args.seed)
        state, metrics = step_fn(state, {"tokens": b.tokens,
                                         "labels": b.labels})
        if i % 20 == 0 or i == args.steps - 1:
            m = {k: round(float(v), 4) for k, v in metrics.items()}
            print(f"step {i:5d} {m} ({(time.time()-t0):.1f}s)", flush=True)
        if ckpt is not None and (
                (i + 1) % args.ckpt_every == 0 or stop["now"]
                or i == args.steps - 1):
            ckpt.save(i + 1, state)
        if stop["now"]:
            print(f"[preempt] checkpointed at step {i + 1}, exiting")
            return 0
    if metrics:
        print(f"done: final loss {float(metrics['loss']):.4f}")
    else:
        print(f"done: nothing to do (resumed at step {start} >= "
              f"{args.steps})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
