"""§Perf hillclimb driver: run tagged dry-run variants for the three chosen
(arch × shape) pairs and print before/after roofline comparisons.

    PYTHONPATH=src python -m repro.launch.perf [--run] [--report]

Variants (see EXPERIMENTS.md §Perf for the hypothesis log):
  seqpar   — Megatron sequence parallelism between blocks
  fact25   — Greenformer factorization-by-design @ rank ratio 0.25 (paper)
  fact25sp — both
  int8kv   — int8 KV cache (decode cells)
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.launch.dryrun import ARTIFACT_DIR, cell_path

# (arch, shape, mesh, tag, extra flags)
VARIANTS = [
    # pair 1 (paper-representative, biggest absolute collective load):
    # 1T MoE — expert factorization + sequence parallelism
    ("kimi-k2-1t-a32b", "train_4k", "pod", "fact25", ["--fact-rank", "0.25"]),
    ("kimi-k2-1t-a32b", "train_4k", "pod", "seqpar", ["--seq-parallel"]),
    ("kimi-k2-1t-a32b", "train_4k", "pod", "fact25sp",
     ["--fact-rank", "0.25", "--seq-parallel"]),
    # pair 2 (worst roofline fraction): memory-bound dense decode
    ("yi-9b", "decode_32k", "pod", "int8kv", ["--cache-dtype", "int8"]),
    ("yi-9b", "decode_32k", "pod", "fact25", ["--fact-rank", "0.25"]),
    ("yi-9b", "decode_32k", "pod", "fact25int8",
     ["--fact-rank", "0.25", "--cache-dtype", "int8"]),
    # pair 3 (most collective-bound cell): MQA decode
    ("granite-34b", "decode_32k", "pod", "fact25", ["--fact-rank", "0.25"]),
    ("granite-34b", "decode_32k", "pod", "int8kv", ["--cache-dtype", "int8"]),
    ("granite-34b", "decode_32k", "pod", "fact25int8",
     ["--fact-rank", "0.25", "--cache-dtype", "int8"]),
    # bonus (beyond the required three): dense train cell
    ("yi-9b", "train_4k", "pod", "seqpar", ["--seq-parallel"]),
    ("yi-9b", "train_4k", "pod", "fact25", ["--fact-rank", "0.25"]),
    ("yi-9b", "train_4k", "pod", "fact25sp",
     ["--fact-rank", "0.25", "--seq-parallel"]),
    # bonus: flash-style chunked attention kills the O(S²) prefill temps
    ("hymba-1.5b", "prefill_32k", "pod", "chunked", ["--attn-chunk", "1024"]),
    ("chameleon-34b", "prefill_32k", "pod", "chunked",
     ["--attn-chunk", "1024"]),
    ("chameleon-34b", "prefill_32k", "pod", "chunkedsp",
     ["--attn-chunk", "1024", "--seq-parallel"]),
    ("yi-9b", "train_4k", "pod", "allopt",
     ["--attn-chunk", "1024", "--seq-parallel", "--fact-rank", "0.25"]),
    ("kimi-k2-1t-a32b", "train_4k", "pod", "allopt",
     ["--attn-chunk", "1024", "--seq-parallel", "--fact-rank", "0.25"]),
]


def run_variants(force: bool = False) -> int:
    failures = 0
    for arch, shape, mesh, tag, flags in VARIANTS:
        path = cell_path(arch, shape, mesh, tag)
        if os.path.exists(path) and not force:
            print(f"[skip] {arch} {shape} {tag} (cached)")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--tag", tag] + flags
        print(f"[run ] {arch} {shape} {mesh} {tag}", flush=True)
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            failures += 1
            print(f"[FAIL] {tag}: {r.stdout[-1500:]}{r.stderr[-2000:]}")
        else:
            print(r.stdout.strip().splitlines()[-1])
    return failures


def _load(arch, shape, mesh, tag):
    path = cell_path(arch, shape, mesh, tag)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def report() -> None:
    pairs = sorted({(a, s, m) for a, s, m, _, _ in VARIANTS})
    for arch, shape, mesh in pairs:
        base = _load(arch, shape, mesh, "baseline")
        if base is None:
            continue
        print(f"\n== {arch} × {shape} ({mesh}) ==")
        rows = [("baseline", base)]
        for a, s, m, tag, _ in VARIANTS:
            if (a, s, m) == (arch, shape, mesh):
                d = _load(a, s, m, tag)
                if d:
                    rows.append((tag, d))
        print(f"{'variant':12s} {'compute_s':>11s} {'memory_s':>11s} "
              f"{'collect_s':>11s} {'bound_s':>11s} {'dominant':>10s} "
              f"{'Δbound':>7s}")
        base_bound = max(base["roofline"][k]
                         for k in ("compute_s", "memory_s", "collective_s"))
        for tag, d in rows:
            r = d["roofline"]
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            print(f"{tag:12s} {r['compute_s']:11.3e} {r['memory_s']:11.3e} "
                  f"{r['collective_s']:11.3e} {bound:11.3e} "
                  f"{r['dominant']:>10s} {base_bound/bound:6.2f}x")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--run", action="store_true")
    p.add_argument("--report", action="store_true")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()
    rc = 0
    if args.run or not args.report:
        rc = run_variants(args.force)
    if args.report or not args.run:
        report()
    sys.exit(1 if rc else 0)


if __name__ == "__main__":
    main()
