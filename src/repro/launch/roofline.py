"""Roofline table generator: reads artifacts/dryrun/*.json, emits the
EXPERIMENTS.md §Roofline table (single-pod baselines + any tagged variants).

    PYTHONPATH=src python -m repro.launch.roofline [--mesh pod] [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.dryrun import ARTIFACT_DIR


def load_cells(mesh: str = "pod", tag: str | None = "baseline") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("mesh") != mesh:
            continue
        if tag is not None and d.get("tag", "baseline") != tag:
            continue
        cells.append(d)
    return cells


def fmt_row(d: dict) -> str:
    r = d["roofline"]
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    frac = r["compute_s"] / bound if bound else 0.0
    return (f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {frac:.1%} | "
            f"{d['useful_flops_ratio']:.2f} |")


HEADER = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
          "bottleneck | roofline frac | 6ND/HLO |\n"
          "|---|---|---|---|---|---|---|---|")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--mesh", default="pod")
    p.add_argument("--tag", default="baseline")
    args = p.parse_args()

    cells = load_cells(args.mesh, args.tag)
    if not cells:
        print(f"no artifacts for mesh={args.mesh} tag={args.tag} — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print(HEADER)
    for d in cells:
        print(fmt_row(d))
    print(f"\n{len(cells)} cells (mesh={args.mesh}, tag={args.tag}); "
          "roofline frac = compute term / dominant term "
          "(1.0 = compute-bound at the roofline).")


if __name__ == "__main__":
    main()
