import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build ShapeDtypeStruct stand-ins (zero allocation), pjit the
step function onto the production mesh, ``.lower().compile()``, and record:
  * memory_analysis()  — per-device argument/output/temp bytes (fits check)
  * cost_analysis()    — HLO FLOPs + HBM bytes for the roofline
  * collective bytes   — parsed from the optimized HLO (see hlo_analysis)

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json; the roofline
table (launch/roofline.py, EXPERIMENTS.md §Roofline) is derived from them.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh pod # every cell, single-pod
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _active_param_counts(model_sds, cfg) -> tuple[int, int]:
    """(total params, active-per-token params) from the SDS tree."""
    import jax

    total = 0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(
        model_sds, is_leaf=lambda x: x is None)[0]
    for key_path, leaf in flat:
        if leaf is None or not hasattr(leaf, "size"):
            continue
        path = jax.tree_util.keystr(key_path)
        total += leaf.size
        if ".experts." in path:
            active += leaf.size * (cfg.top_k / max(cfg.n_experts, 1))
        elif "embed" in path and "pos" not in path:
            continue  # embedding lookups are gathers, not matmuls
        else:
            active += leaf.size
    return total, int(active)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             fact_rank: float = 0.0, tag: str = "",
             seq_parallel: bool = False, cache_dtype: str = "bfloat16",
             attn_chunk: int = 0) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, SHAPES
    from repro.core import auto_fact
    from repro.dist.sharding import (activation_mesh, cache_shardings,
                                     data_sharding, model_shardings)
    from repro.launch.hlo_analysis import (Roofline, collective_stats,
                                           model_flops)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs, model_specs
    from repro.optim import AdamW
    from repro.optim.adamw import AdamWState
    from repro.train import TrainState, make_train_step

    cfg = get_config(arch)
    if attn_chunk:
        cfg = cfg.replace(attn_chunk=attn_chunk)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.devices.size
    fsdp = True  # ZeRO-style param+optimizer sharding across the data axes

    is_train = shape.kind == "train"
    model_sds = model_specs(cfg, remat=is_train)
    if fact_rank:
        # factorization-by-design inside eval_shape: LED-structured model
        model_sds = jax.eval_shape(
            lambda m: auto_fact(m, fact_rank, solver="random",
                                key=jax.random.PRNGKey(0)), model_sds)
    ms = model_shardings(model_sds, mesh, fsdp=fsdp)
    specs = input_specs(cfg, shape_name, cache_dtype=cache_dtype)
    repl = NamedSharding(mesh, P())

    t0 = time.time()
    if is_train:
        opt = AdamW(1e-3, master_fp32=True)
        opt_sds = jax.eval_shape(opt.init, model_sds)
        state_sds = TrainState(model=model_sds, opt=opt_sds,
                               step=jax.ShapeDtypeStruct((), jnp.int32))
        opt_sh = AdamWState(step=repl, m=ms, v=ms,
                            master=ms if opt.master_fp32 else None)
        state_sh = TrainState(model=ms, opt=opt_sh, step=repl)
        batch_sds = specs["batch"]
        batch_sh = {k: data_sharding(mesh, v.shape)
                    for k, v in batch_sds.items()}
        step_fn = make_train_step(opt)
        with mesh, activation_mesh(mesh, seq_parallel=seq_parallel):
            lowered = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                              donate_argnums=(0,)).lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        cache_sds = specs["cache"]
        cache_sh = cache_shardings(cache_sds, mesh)
        tok_sh = data_sharding(mesh, specs["tokens"].shape)
        if cfg.family == "encdec":
            def prefill_fn(model, frames, tokens, cache):
                return model.prefill(frames, tokens, cache)
            fr_sh = data_sharding(mesh, specs["frames"].shape)
            with mesh, activation_mesh(mesh, seq_parallel=seq_parallel):
                lowered = jax.jit(
                    prefill_fn,
                    in_shardings=(ms, fr_sh, tok_sh, cache_sh),
                    donate_argnums=(3,),
                ).lower(model_sds, specs["frames"], specs["tokens"], cache_sds)
        else:
            def prefill_fn(model, tokens, cache):
                return model.prefill(tokens, cache)
            with mesh, activation_mesh(mesh, seq_parallel=seq_parallel):
                lowered = jax.jit(
                    prefill_fn, in_shardings=(ms, tok_sh, cache_sh),
                    donate_argnums=(2,),
                ).lower(model_sds, specs["tokens"], cache_sds)
    else:  # decode
        cache_sds = specs["cache"]
        cache_sh = cache_shardings(cache_sds, mesh)
        tok_sh = data_sharding(mesh, specs["token"].shape)

        def decode_fn(model, token, cache):
            return model.decode(token, cache)

        with mesh, activation_mesh(mesh, seq_parallel=seq_parallel):
            lowered = jax.jit(
                decode_fn, in_shardings=(ms, tok_sh, cache_sh),
                donate_argnums=(2,),
            ).lower(model_sds, specs["token"], cache_sds)

    compiled = lowered.compile()
    compile_s = time.time() - t0

    # raw XLA numbers (count while-loop bodies ONCE — kept for reference)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # trip-count-aware analysis (correct for scan-over-layers models)
    from repro.launch.hlo_costs import analyze

    hlo_text = compiled.as_text()
    costs = analyze(hlo_text)
    # the partitioned HLO has PER-DEVICE shapes; globalize so the roofline
    # formulas (X / (chips * rate)) yield per-chip seconds.
    flops = costs.flops * n_chips
    hbm_bytes = costs.bytes * n_chips
    stats = collective_stats(hlo_text)  # single-count legacy, for reference
    mem = compiled.memory_analysis()

    total, active = _active_param_counts(model_sds, cfg)
    n_tokens = shape.global_batch * (shape.seq_len if is_train else
                                     (shape.seq_len if shape.kind == "prefill"
                                      else 1))
    mflops = model_flops(active, n_tokens, training=is_train)
    collective_global = costs.total_collective_bytes * n_chips
    roof = Roofline(flops=flops, hbm_bytes=hbm_bytes,
                    collective_bytes=float(collective_global),
                    n_chips=n_chips)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "tag": tag or "baseline",
        "fact_rank": fact_rank,
        "seq_parallel": seq_parallel,
        "cache_dtype": cache_dtype,
        "attn_chunk": attn_chunk,
        "n_chips": n_chips,
        "compile_s": round(compile_s, 1),
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": collective_global,
        "collectives": {"bytes_per_device": costs.collective_bytes,
                        "count_per_device": costs.collective_count},
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes,
                              "collective_bytes_single_count":
                                  stats.total_bytes},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "params_total": total,
        "params_active": active,
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / flops) if flops else 0.0,
        "roofline": roof.as_dict(),
    }
    return result


def cell_path(arch, shape, mesh, tag="baseline"):
    suffix = "" if tag == "baseline" else f"__{tag}"
    return os.path.join(ARTIFACT_DIR,
                        f"{arch}__{shape}__{mesh}{suffix}.json")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["pod", "multipod", "both"],
                   default="both")
    p.add_argument("--all", action="store_true")
    p.add_argument("--fact-rank", type=float, default=0.0,
                   help="factorize-by-design at this rank ratio before lowering")
    p.add_argument("--seq-parallel", action="store_true",
                   help="Megatron sequence parallelism between blocks")
    p.add_argument("--cache-dtype", default="bfloat16",
                   help="KV/SSM cache dtype for decode/prefill cells")
    p.add_argument("--attn-chunk", type=int, default=0,
                   help="flash-style blockwise attention chunk (0 = dense)")
    p.add_argument("--tag", default="", help="artifact filename suffix")
    p.add_argument("--force", action="store_true")
    args = p.parse_args()

    os.makedirs(ARTIFACT_DIR, exist_ok=True)

    if args.all:
        from repro.configs import ARCH_IDS, applicable_shapes, get_config

        meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
        cells = [(a, s, m) for a in ARCH_IDS
                 for s in applicable_shapes(get_config(a)) for m in meshes]
        failures = 0
        for arch, shape, mesh_kind in cells:
            path = cell_path(arch, shape, mesh_kind, args.tag or "baseline")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {arch} {shape} {mesh_kind} (cached)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
            if args.fact_rank:
                cmd += ["--fact-rank", str(args.fact_rank)]
            if args.seq_parallel:
                cmd += ["--seq-parallel"]
            if args.cache_dtype != "bfloat16":
                cmd += ["--cache-dtype", args.cache_dtype]
            if args.attn_chunk:
                cmd += ["--attn-chunk", str(args.attn_chunk)]
            if args.tag:
                cmd += ["--tag", args.tag]
            print(f"[run ] {arch} {shape} {mesh_kind} ...", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures += 1
                print(f"[FAIL] {arch} {shape} {mesh_kind}:\n"
                      + r.stdout[-2000:] + r.stderr[-4000:])
            else:
                print(r.stdout.strip().splitlines()[-1])
        print(f"dry-run sweep complete: {len(cells)} cells, "
              f"{failures} failures")
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape and args.mesh != "both", \
        "single-cell mode needs --arch --shape --mesh {pod,multipod}"
    try:
        result = run_cell(args.arch, args.shape, args.mesh,
                          fact_rank=args.fact_rank, tag=args.tag,
                          seq_parallel=args.seq_parallel,
                          cache_dtype=args.cache_dtype,
                          attn_chunk=args.attn_chunk)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    path = cell_path(args.arch, args.shape, args.mesh, args.tag or "baseline")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    r = result["roofline"]
    print(f"[ok  ] {args.arch} {args.shape} {args.mesh}: "
          f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
          f"collective={r['collective_s']:.3e}s dominant={r['dominant']} "
          f"compile={result['compile_s']}s")


if __name__ == "__main__":
    main()
