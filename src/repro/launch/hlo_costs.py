"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
scan-over-layers transformer is undercounted by n_layers (verified
empirically; see EXPERIMENTS.md §Dry-run-methodology).  This module parses
the optimized HLO text into its computation graph and computes:

  * flops            — 2·prod(result)·prod(contracted dims) per ``dot``
                       (+ fusion-internal dots), ×trip-count inside whiles
  * memory bytes     — HloCostAnalysis-style operand+result bytes per op,
                       counting fusions as single nodes (their internals stay
                       in registers), ×trip-count inside whiles
  * collective bytes — result bytes per collective kind, ×trip-count

While-loop trip counts are recovered from the loop condition computation
(the scan bound appears as an ``s32[] constant(L)`` compared with the
induction variable).

This is an engineering approximation (elementwise flops ignored — dots
dominate the compute term; layout-only ops excluded from bytes), but unlike
raw cost_analysis it is *structurally correct* for scanned models, and it is
used consistently across every baseline/variant comparison.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops whose "bytes" are pure bookkeeping (no real data movement)
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter",
             "constant", "after-all", "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\((.*)$")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_list(text: str) -> list[tuple[str, list[int]]]:
    return [(dt, [int(x) for x in dims.split(",") if x])
            for dt, dims in _SHAPE_RE.findall(text)]


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        size = _DTYPE_BYTES.get(dt, 4)
        for d in dims:
            size *= d
        total += size
    return total


@dataclass
class Op:
    name: str
    kind: str
    result_shapes: list
    operands: list
    attrs: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)  # value name -> result shapes
    root: object = None  # the ROOT op
    op_by_name: dict = field(default_factory=dict)


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_count: dict = field(default_factory=dict)

    def add(self, other: "Costs", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0) + v * scale
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + v * scale

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def parse_computations(hlo: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    current = None
    for line in hlo.splitlines():
        header = _COMP_HEADER_RE.match(line)
        if header and line.rstrip().endswith("{"):
            current = Computation(name=header.group(2))
            comps[current.name] = current
            if header.group(1):
                entry = current.name
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _OP_LINE_RE.match(line)
        if not m:
            continue
        name, result_txt, kind, rest = m.groups()
        shapes = _shape_list(result_txt)
        # operand names: %refs inside the top-level parens of the op call
        paren = rest.split(")", 1)[0]
        operands = _OPERAND_RE.findall(paren)
        op = Op(name=name, kind=kind, result_shapes=shapes,
                operands=operands, attrs=rest)
        current.ops.append(op)
        current.defs[name] = shapes
        current.op_by_name[name] = op
        if line.lstrip().startswith("ROOT"):
            current.root = op
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    out = 1
    for _, dims in op.result_shapes:
        for d in dims:
            out *= d
    contract = 1
    m = _CONTRACT_RE.search(op.attrs)
    if m and op.operands:
        lhs_shapes = comp.defs.get(op.operands[0])
        if lhs_shapes:
            _, lhs_dims = lhs_shapes[0]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
    return 2.0 * out * contract


def _trip_count(comps: dict, cond_name: str) -> int:
    """Scan bounds appear as s32[] constants in the loop condition; the
    largest one is the trip count (induction starts at 0, compare is LT)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.kind == "constant" and op.result_shapes == [("s32", [])]:
            head = op.attrs.split(")")[0]
            if head.isdigit():
                best = max(best, int(head))
    return best


def _op_bytes(op: Op, comp: Computation) -> float:
    if op.kind in _FREE_OPS:
        return 0.0
    result = _nbytes(op.result_shapes)
    # indexing ops touch only the sliced region, not the whole operand
    # (matches HloCostAnalysis semantics; critical inside scan bodies where
    # the full layer-stacked weights are loop-invariant operands).
    if op.kind in ("dynamic-slice", "slice", "gather"):
        return float(2 * result)
    if op.kind in ("dynamic-update-slice", "scatter"):
        update = 0
        if len(op.operands) >= 2:
            shapes = comp.defs.get(op.operands[1])
            if shapes:
                update = _nbytes(shapes)
        return float(3 * update) if update else float(result)
    total = result
    for o in op.operands:
        shapes = comp.defs.get(o)
        if shapes:
            total += _nbytes(shapes)
    return float(total)


_SLICE_KINDS = ("dynamic-slice", "slice", "gather")


def _fusion_bytes(op: Op, comp: Computation, fused: Computation | None) -> float:
    """Fusion node traffic: result bytes + per-parameter read bytes.

    A parameter consumed ONLY through slice/gather ops inside the fusion
    (e.g. the scan body slicing one layer out of the stacked weights) reads
    just the sliced region, not the whole operand.  Symmetrically, a fusion
    whose ROOT is a dynamic-update-slice (the scan body writing one layer's
    slot of a stacked accumulator in place) writes just the update region."""
    if fused is None:
        return float(_nbytes(op.result_shapes)) + sum(
            _nbytes(comp.defs.get(o, [])) for o in op.operands)

    def write_bytes(inner_op) -> float:
        if inner_op is None:
            return 0.0
        if inner_op.kind == "dynamic-update-slice" and len(inner_op.operands) >= 2:
            upd = fused.defs.get(inner_op.operands[1])
            if upd:
                return float(2 * _nbytes(upd))  # read region + write region
        return float(_nbytes(inner_op.result_shapes))

    root = fused.root or (fused.ops[-1] if fused.ops else None)
    if root is not None and root.kind == "tuple":
        total = sum(write_bytes(fused.op_by_name.get(o)) for o in root.operands)
    else:
        total = write_bytes(root)
    # parameter index -> inner value name
    params: dict[int, str] = {}
    for inner_op in fused.ops:
        if inner_op.kind == "parameter":
            head = inner_op.attrs.split(")")[0]
            if head.isdigit():
                params[int(head)] = inner_op.name
    # consumers of each inner value
    consumers: dict[str, list[Op]] = {}
    for inner_op in fused.ops:
        for o in inner_op.operands:
            consumers.setdefault(o, []).append(inner_op)
    for idx, outer_name in enumerate(op.operands):
        shapes = comp.defs.get(outer_name)
        if not shapes:
            continue
        full = _nbytes(shapes)
        pname = params.get(idx)
        uses = consumers.get(pname, []) if pname else []

        def use_read(u) -> float | None:
            if u.kind in _SLICE_KINDS:
                return float(2 * _nbytes(u.result_shapes))
            if (u.kind == "dynamic-update-slice" and u.operands
                    and u.operands[0] == pname):
                return 0.0  # in-place buffer pass-through, not a full read
            return None  # unknown: treat as full read

        if uses:
            reads = [use_read(u) for u in uses]
            if all(r is not None for r in reads):
                total += min(full, sum(reads))
            else:
                total += full
        else:
            total += full
    return total


def analyze(hlo: str) -> Costs:
    comps, entry = parse_computations(hlo)

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def comp_cost(name: str) -> Costs:
        c = Costs()
        comp = comps.get(name)
        if comp is None:
            return c
        for op in comp.ops:
            if op.kind == "while":
                m = _COND_BODY_RE.search(op.attrs)
                if m:
                    trips = _trip_count(comps, m.group(1))
                    inner = Costs()
                    inner.add(comp_cost(m.group(2)))
                    inner.add(comp_cost(m.group(1)))
                    c.add(inner, scale=trips)
                continue
            if op.kind == "fusion":
                m = _CALLS_RE.search(op.attrs)
                if m:
                    # fused internals: count flops (dots), not bytes
                    inner = comp_cost(m.group(1))
                    c.flops += inner.flops
                    for k, v in inner.collective_bytes.items():
                        c.collective_bytes[k] = c.collective_bytes.get(k, 0) + v
                    c.bytes += _fusion_bytes(op, comp, comps.get(m.group(1)))
                else:
                    c.bytes += _op_bytes(op, comp)
                continue
            if op.kind in ("call", "conditional", "async-start"):
                # boundary is free: the callee's own ops account for their
                # traffic (e.g. a called slice-fusion reads one layer of a
                # loop-invariant stack, not the whole operand)
                for m in _OPERAND_RE.finditer(op.attrs):
                    if m.group(1) in comps:
                        c.add(comp_cost(m.group(1)))
                continue
            base = op.kind.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.kind.endswith("-done"):
                    continue
                nb = _nbytes(op.result_shapes)
                c.collective_bytes[base] = c.collective_bytes.get(base, 0) + nb
                c.collective_count[base] = c.collective_count.get(base, 0) + 1
                c.bytes += _op_bytes(op, comp)
                continue
            if op.kind == "dot":
                c.flops += _dot_flops(op, comp)
            elif op.kind == "convolution":
                # approximate: 2 * prod(result) * (input channels * window)
                c.flops += 2.0 * _nbytes(op.result_shapes)  # coarse lower bound
            c.bytes += _op_bytes(op, comp)
        return c

    total = Costs()
    if entry:
        total.add(comp_cost(entry))
    return total
