"""HLO-level analysis: collective byte counts + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic —
we parse the optimized HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware model (TPU v5e, per the assignment):
  peak bf16:   197 TFLOP/s per chip
  HBM bw:      819 GB/s per chip
  ICI link bw: ~50 GB/s per link
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = bf16[16,7168]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            size *= int(d)
    return size


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: int) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:  # async pair: count the start only
            continue
        dtype, dims, kind = m.groups()
        stats.add(kind, _shape_bytes(dtype, dims))
    return stats


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.n_chips * LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes, "n_chips": self.n_chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, n_chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_stats(compiled.as_text())
    return Roofline(flops=flops, hbm_bytes=hbm,
                    collective_bytes=float(stats.total_bytes),
                    n_chips=n_chips)


def model_flops(n_params_active: int, n_tokens: int, *,
                training: bool) -> float:
    """6·N·D for training, 2·N·D for inference forward."""
    mult = 6.0 if training else 2.0
    return mult * n_params_active * n_tokens
