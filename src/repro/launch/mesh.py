"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see 1.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
