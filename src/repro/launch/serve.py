"""Serving driver: continuous batching with a (optionally factorized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch paper-tiny \
        --batch 8 --max-len 256 --n-requests 32 \
        [--kv-layout paged --block-size 16 --decode-kernel pallas] \
        [--fact-rank 0.5 --solver svd]

Replays a Poisson arrival trace of variable-length prompts through the
continuous-batching engine (``repro.serve.ContinuousEngine``): requests are
admitted into recyclable slots mid-flight under one jitted prefill + one
jitted decode step.  The default KV layout is **paged** — slots share a
pool of ``--block-size``-token KV blocks through per-slot block tables,
with refcounted prefix caching for shared prompt prefixes — so
HBM-resident KV bytes track live tokens instead of ``batch * max_len``
(``--kv-layout dense`` restores the per-slot lanes for comparison; both
layouts produce bit-identical greedy tokens).  ``--decode-kernel pallas``
swaps the paged decode attention from the dense-gather reference to the
fused Pallas kernel (``repro.kernels.paged_attention`` — KV blocks stream
through VMEM inside the online-softmax loop; interpret mode off-TPU;
greedy tokens stay bit-identical).  ``--shared-prefix N`` gives every
prompt one common N-token system prefix to exercise the prefix cache.  Demonstrates the paper's post-training-factorization use case
end-to-end — the dense model is factorized with SVD *after* "training"
(here: at init), then served; tokens/s, p50/p95 latency, and HBM-resident
KV bytes are printed per variant.
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import (bench_trace, format_kv_stats, format_stats,
                         greedy_agreement, make_trace)


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-tiny")
    p.add_argument("--batch", type=int, default=8,
                   help="decode slots (requests in flight)")
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--max-prompt-len", type=int, default=64)
    p.add_argument("--n-requests", type=int, default=32)
    p.add_argument("--load", type=float, default=0.5,
                   help="expected request arrivals per decode step")
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--kv-layout", choices=("paged", "dense"),
                   default="paged")
    p.add_argument("--block-size", type=int, default=16,
                   help="tokens per KV block (paged layout)")
    p.add_argument("--n-blocks", type=int, default=0,
                   help="KV pool size; 0 = batch * ceil(max_len/block_size)")
    p.add_argument("--decode-kernel", choices=("reference", "pallas"),
                   default="reference",
                   help="paged decode attention: dense-gather reference or "
                        "the fused Pallas paged-attention kernel")
    p.add_argument("--shared-prefix", type=int, default=0,
                   help="common system-prompt tokens prepended to every "
                        "request (prefix-cache workload)")
    p.add_argument("--fact-rank", type=float, default=0.0)
    p.add_argument("--solver", default="svd")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--reduced", action="store_true")
    args = p.parse_args(argv)

    min_prompt = 4
    if not 0 <= args.shared_prefix <= args.max_prompt_len - min_prompt:
        p.error(f"--shared-prefix must be in [0, {args.max_prompt_len} - "
                f"{min_prompt}] so prompts still fit --max-prompt-len")
    if args.kv_layout != "paged" and args.decode_kernel != "reference":
        p.error("--decode-kernel pallas requires --kv-layout paged")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    trace = make_trace(args.n_requests, seed=args.seed, load=args.load,
                       min_prompt=min_prompt,
                       max_prompt=args.max_prompt_len - args.shared_prefix,
                       min_new=4, max_new=args.max_new, vocab=cfg.vocab,
                       shared_prefix=args.shared_prefix)

    dims = dict(batch=args.batch, max_len=args.max_len,
                max_prompt_len=args.max_prompt_len,
                kv_layout=args.kv_layout)
    if args.kv_layout == "paged":
        dims["block_size"] = args.block_size
        dims["decode_kernel"] = args.decode_kernel
        if args.n_blocks:
            dims["n_blocks"] = args.n_blocks
    dense_done, stats = bench_trace(model, cfg, trace, **dims)
    print(format_stats("dense", stats))
    print(format_kv_stats("dense", stats))

    if args.fact_rank:
        fact, report = auto_fact(model, args.fact_rank, solver=args.solver,
                                 key=jax.random.PRNGKey(1),
                                 return_report=True)
        print(report.summary())
        fact_done, fstats = bench_trace(fact, cfg, trace, **dims)
        print(format_stats("factorized", fstats))
        print(format_kv_stats("factorized", fstats))
        agree = greedy_agreement(dense_done, fact_done)
        print(f"greedy token agreement dense vs factorized: {agree:.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
