"""Serving driver: continuous batching with a (optionally factorized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch paper-tiny \
        --batch 8 --max-len 128 --n-requests 32 [--fact-rank 0.5 --solver svd]

Replays a Poisson arrival trace of variable-length prompts through the
continuous-batching engine (``repro.serve.ContinuousEngine``): requests are
admitted into recyclable slots mid-flight under one jitted prefill + one
jitted decode step.  Demonstrates the paper's post-training-factorization
use case end-to-end — the dense model is factorized with SVD *after*
"training" (here: at init), then served; tokens/s and p50/p95 per-request
latency for dense vs factorized are printed side by side, plus greedy-token
agreement between the two.
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import bench_trace, format_stats, greedy_agreement, make_trace


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-tiny")
    p.add_argument("--batch", type=int, default=8,
                   help="decode slots (requests in flight)")
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--max-prompt-len", type=int, default=64)
    p.add_argument("--n-requests", type=int, default=32)
    p.add_argument("--load", type=float, default=0.5,
                   help="expected request arrivals per decode step")
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--fact-rank", type=float, default=0.0)
    p.add_argument("--solver", default="svd")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--reduced", action="store_true")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    trace = make_trace(args.n_requests, seed=args.seed, load=args.load,
                       min_prompt=4, max_prompt=args.max_prompt_len,
                       min_new=4, max_new=args.max_new, vocab=cfg.vocab)

    dims = dict(batch=args.batch, max_len=args.max_len,
                max_prompt_len=args.max_prompt_len)
    dense_done, stats = bench_trace(model, cfg, trace, **dims)
    print(format_stats("dense", stats))

    if args.fact_rank:
        fact, report = auto_fact(model, args.fact_rank, solver=args.solver,
                                 key=jax.random.PRNGKey(1),
                                 return_report=True)
        print(report.summary())
        fact_done, fstats = bench_trace(fact, cfg, trace, **dims)
        print(format_stats("factorized", fstats))
        agree = greedy_agreement(dense_done, fact_done)
        print(f"greedy token agreement dense vs factorized: {agree:.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
