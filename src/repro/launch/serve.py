"""Serving driver: batched prefill + decode with a (optionally factorized)
model.

    PYTHONPATH=src python -m repro.launch.serve --arch paper-tiny \
        --batch 8 --prompt-len 64 --gen 32 [--fact-rank 0.5 --solver svd]

Demonstrates the paper's post-training-factorization use case end-to-end:
the dense model is factorized with SVD *after* "training" (here: at init),
then served; tokens/s for dense vs factorized are printed side by side.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import Engine


def bench_engine(model, cfg, batch, prompt_len, gen, max_len) -> tuple:
    eng = Engine(model, cfg, batch=batch, max_len=max_len,
                 cache_dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(0), (batch, prompt_len),
                              0, cfg.vocab)
    out = eng.greedy(toks, gen)  # warmup + compile
    eng.reset()
    t0 = time.time()
    out = eng.greedy(toks, gen)
    jax.block_until_ready(out)
    dt = time.time() - t0
    return out, batch * gen / dt


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-tiny")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--fact-rank", type=float, default=0.0)
    p.add_argument("--solver", default="svd")
    p.add_argument("--reduced", action="store_true")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen

    out, tps = bench_engine(model, cfg, args.batch, args.prompt_len,
                            args.gen, max_len)
    print(f"dense      : {tps:9.1f} tok/s   sample: {out[0, :8].tolist()}")

    if args.fact_rank:
        fact, report = auto_fact(model, args.fact_rank, solver=args.solver,
                                 key=jax.random.PRNGKey(1),
                                 return_report=True)
        print(report.summary())
        fout, ftps = bench_engine(fact, cfg, args.batch, args.prompt_len,
                                  args.gen, max_len)
        agree = float(jnp.mean((out == fout).astype(jnp.float32)))
        print(f"factorized : {ftps:9.1f} tok/s   sample: "
              f"{fout[0, :8].tolist()}  (token agreement {agree:.1%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
