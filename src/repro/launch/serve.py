"""Serving driver: continuous batching with a (optionally factorized) model.

    PYTHONPATH=src python -m repro.launch.serve --arch paper-tiny \
        --batch 8 --max-len 256 --n-requests 32 \
        [--kv-layout paged --block-size 16 --decode-kernel pallas] \
        [--chunk-size 32 --buckets 8,16,32 --prefill-budget 32] \
        [--no-prefix-reuse --prefix-retain 64] [--stream] \
        [--factorize --rank 0.5 --solver svd] [--spec-k 4]

Replays a Poisson arrival trace of variable-length prompts through the
continuous-batching engine (``repro.serve.ContinuousEngine``): requests are
admitted into recyclable slots mid-flight under one jitted decode step and
a **chunked, bucketed prefill** — prompts are consumed ``--chunk-size``
tokens at a time (each span right-padded to a width from ``--buckets``, so
the chunk jit compiles at 2-3 widths), spending at most
``--prefill-budget`` padded tokens per engine step so a long prompt's
prefill interleaves with decode instead of stalling it.

The default KV layout is **paged** — slots share a pool of
``--block-size``-token KV blocks through per-slot block tables, with
refcounted prefix caching for shared prompt prefixes — so HBM-resident KV
bytes track live tokens instead of ``batch * max_len``.  Prefix hits skip
the *compute* too: prefill starts after the longest cached block-chain
(``--no-prefix-reuse`` disables), and freed prefix blocks stay parked on
an LRU (``--prefix-retain`` blocks; default the whole pool) so hits
survive idle periods.  ``--kv-layout dense`` restores the per-slot lanes
for comparison; both layouts produce bit-identical greedy tokens.
``--decode-kernel pallas`` swaps the paged decode attention from the
dense-gather reference to the fused Pallas kernel
(``repro.kernels.paged_attention`` — interpret mode off-TPU; greedy
tokens stay bit-identical).  ``--prefill-kernel pallas`` does the same
for the chunked-prefill attention on EITHER KV layout
(``repro.kernels.chunk_attention``, flash-style online softmax over the
resident prefix + the chunk's fresh K/V — greedy tokens stay
bit-identical).  ``--shared-prefix N`` gives every prompt one
common N-token system prefix to exercise the prefix cache;
``--long-frac/--long-prompt`` mix in a heavy prompt tail to exercise
chunking.

**Heterogeneous families.**  ``--arch hymba-1.5b`` (hybrid sliding-window
attention + SSM) and ``--arch mamba2-2.7b`` (pure SSM) serve through the
same engine via per-slot state — ring-buffer KV lanes (O(window) per
slot) and/or conv/ssm recurrent state (O(1) per slot).  These state
kinds cannot be paged or prefix-cached, so the engine degrades the paged
knobs gracefully (prefix reuse auto-off, block reservation skipped) and
reports the effective ``cache_kind`` in its stats; ``--decode-kernel
pallas`` is attention-paged-only and ``--prefill-kernel pallas`` needs
position-addressable KV lanes — both error for these families.

**Scheduling policy.**  ``--priority-mix 0.2,0.8`` samples per-request
priority classes into the trace (class 0 = most urgent; FIFO within a
class, ``--aging-every`` bounds cross-class starvation), and the engine
preempts running decodes of a strictly lower class when a higher-class
head is blocked — the victim's committed blocks park on the prefix-cache
LRU and it resumes later as a prefix-hit re-admission with a
bit-identical greedy stream (``--no-preemption`` disables).
``--slo-ttft S`` plugs in the SLO adapter that retunes
``--prefill-budget`` online against an observed-TTFT p95 target.  See
``src/repro/serve/README.md`` §Scheduling policy.

``--stream`` switches from batch replay to the streaming API: tokens are
printed as SSE-style ``data:`` lines the moment they land
(``ContinuousEngine.stream()`` / ``on_token``).

``--http`` boots the real network front door instead of a local replay:
an asyncio HTTP server (``repro.serve.http``) on ``--host``/``--port``
serving ``POST /v1/generate`` (SSE token streaming, per-request
deadlines, client-disconnect cancellation), ``GET /metrics`` (Prometheus
text: TTFT/latency quantiles, prefix-hit rate, KV blocks in use), and
``GET /healthz``.  ``--max-pending`` bounds the admission queue (a full
queue answers 429 with ``Retry-After`` — backpressure instead of
unbounded buffering) and ``--request-timeout`` sets the default
per-request deadline in seconds (0 = none; an expired request is
cancelled and reported ``finish_reason="cancelled"``).  Drive it with
``python -m repro.launch.loadgen`` (closed- and open-loop client).
``--http`` serves the model the other flags select — including
``--factorize`` and ``--spec-k`` variants — and ignores the trace knobs
(clients bring the traffic).

Demonstrates the paper's post-training-factorization use case end-to-end —
``--factorize`` SVD-factorizes the dense model *after* "training" (here:
at init; rank ``--rank`` as a ratio of min(m, n), embed/lm_head kept
dense, r_max gate off so ``--rank 1.0`` reconstructs exactly) and serves
it through the same engine, reporting dense-vs-factorized greedy
agreement alongside tokens/s, p50/p95 latency, TTFT, HBM-resident KV
bytes, and the admission-path profile.  ``--fact-rank R`` is the
deprecated spelling of ``--factorize --rank R``.

``--spec-k K`` turns on **speculative decoding**: a ``--rank``-ratio
factorized draft of the model proposes K greedy tokens per round and the
dense model verifies them in ONE batched multi-token decode step,
committing the agreeing prefix plus its own next token — the greedy
output is bit-identical to plain dense decoding by construction (the
driver asserts it), and the acceptance rate printed per run is the
fraction of drafted tokens the verifier kept.
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import (ContinuousEngine, bench_trace, format_kv_stats,
                         format_prefill_stats, format_stats,
                         greedy_agreement, make_trace)


def stream_trace(model, cfg, trace, *, out=sys.stdout, **dims) -> int:
    """SSE-style streaming driver: replay ``trace`` through
    ``ContinuousEngine.stream()``, printing one ``data:`` line per landed
    token and an ``event: done`` line per completion.  Returns the number
    of streamed tokens."""
    engine = ContinuousEngine(model, cfg, **dims)
    pending = sorted(trace, key=lambda p: p[0])
    i, n_tok, ticks = 0, 0, 0

    def feed(_eng=None) -> None:
        """Submit every arrival due by the step clock (ticks once per
        engine step via the on_step hook — step_log itself is a bounded
        deque, so its length cannot serve as a clock)."""
        nonlocal i, ticks
        if _eng is not None:
            ticks += 1
        while i < len(pending) and pending[i][0] <= ticks:
            engine.submit(pending[i][1])
            i += 1

    feed()
    while i < len(pending) or not engine.scheduler.idle:
        # feed through the on_step hook, not the yield points: a step can
        # produce no token while prompts are mid-chunked-prefill, and timed
        # arrivals must keep flowing into the free slots regardless
        for uid, tok, comp in engine.stream(on_step=feed):
            if tok is not None:  # None = completion-only event (cancelled)
                n_tok += 1
                print(f"data: {json.dumps({'id': uid, 'token': tok})}",
                      file=out)
            if comp is not None:
                done = {"id": uid, "reason": comp.finish_reason,
                        "n_tokens": len(comp.tokens)}
                print(f"event: done\ndata: {json.dumps(done)}", file=out)
        if i < len(pending) and engine.scheduler.idle:
            # idle gap: jump the clock to the next arrival, so the burst
            # due around it still batches instead of trickling in late
            ticks = max(ticks, int(np.ceil(pending[i][0])))
            feed()
    return n_tok


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-tiny")
    p.add_argument("--batch", type=int, default=8,
                   help="decode slots (requests in flight)")
    p.add_argument("--max-len", type=int, default=256)
    p.add_argument("--max-prompt-len", type=int, default=64)
    p.add_argument("--n-requests", type=int, default=32)
    p.add_argument("--load", type=float, default=0.5,
                   help="expected request arrivals per decode step")
    p.add_argument("--max-new", type=int, default=32)
    p.add_argument("--kv-layout", choices=("paged", "dense"),
                   default="paged")
    p.add_argument("--block-size", type=int, default=16,
                   help="tokens per KV block (paged layout)")
    p.add_argument("--n-blocks", type=int, default=0,
                   help="KV pool size; 0 = batch * ceil(max_len/block_size)")
    p.add_argument("--decode-kernel", choices=("reference", "pallas"),
                   default="reference",
                   help="paged decode attention: dense-gather reference or "
                        "the fused Pallas paged-attention kernel")
    p.add_argument("--prefill-kernel", choices=("reference", "pallas"),
                   default="reference",
                   help="chunked-prefill attention (paged or dense KV): "
                        "dense-gather reference or the flash Pallas "
                        "prefill-chunk kernel")
    p.add_argument("--chunk-size", type=int, default=32,
                   help="max prompt tokens consumed per prefill chunk")
    p.add_argument("--buckets", default="",
                   help="comma-separated chunk compile widths "
                        "(default: chunk_size and its halvings)")
    p.add_argument("--prefill-budget", type=int, default=0,
                   help="max padded prefill tokens per engine step "
                        "(0 = chunk_size); decode advances in between")
    p.add_argument("--no-prefix-reuse", action="store_true",
                   help="disable prefix-cache compute skip AND retention")
    p.add_argument("--prefix-retain", type=int, default=-1,
                   help="freed prefix blocks kept warm on the LRU "
                        "(-1 = whole pool, 0 = recycle immediately)")
    p.add_argument("--shared-prefix", type=int, default=0,
                   help="common system-prompt tokens prepended to every "
                        "request (prefix-cache workload)")
    p.add_argument("--long-frac", type=float, default=0.0,
                   help="fraction of requests drawn as long prompts")
    p.add_argument("--long-prompt", type=int, default=0,
                   help="prompt length of the long fraction "
                        "(default: max_prompt_len minus the shared prefix)")
    p.add_argument("--priority-mix", default="",
                   help="comma-separated weights over priority classes "
                        "0..k-1 (0 = most urgent), sampled per trace "
                        "request — e.g. '0.2,0.8' = 20%% urgent traffic "
                        "(empty = everything class 1)")
    p.add_argument("--no-preemption", action="store_true",
                   help="disable decode preemption: a blocked higher-"
                        "priority head waits instead of evicting a "
                        "lower-priority running decode")
    p.add_argument("--aging-every", type=int, default=16,
                   help="starvation bound: the oldest pending class head "
                        "is bypassed by at most this many consecutive "
                        "admissions before being forced to run")
    p.add_argument("--slo-ttft", type=float, default=0.0,
                   help="TTFT SLO target in seconds: adapts the prefill "
                        "chunk budget online against the observed p95 "
                        "(repro.serve.slo.SloBudgetAdapter; 0 = off)")
    p.add_argument("--stream", action="store_true",
                   help="print tokens as SSE-style data: lines as they "
                        "land instead of batch stats")
    p.add_argument("--http", action="store_true",
                   help="serve over HTTP instead of replaying a trace: "
                        "POST /v1/generate (SSE streaming, deadlines, "
                        "disconnect cancellation), GET /metrics "
                        "(Prometheus), GET /healthz; drive with "
                        "repro.launch.loadgen")
    p.add_argument("--host", default="127.0.0.1",
                   help="HTTP bind address (--http)")
    p.add_argument("--port", type=int, default=8000,
                   help="HTTP port; 0 picks an ephemeral one (--http)")
    p.add_argument("--max-pending", type=int, default=64,
                   help="admission queue bound; a full queue answers "
                        "429 backpressure (--http)")
    p.add_argument("--request-timeout", type=float, default=0.0,
                   help="default per-request deadline in seconds; an "
                        "expired request is cancelled (0 = none, --http)")
    p.add_argument("--factorize", action="store_true",
                   help="serve the auto_fact-factorized model (rank from "
                        "--rank, embed/lm_head excluded, r_max gate off so "
                        "--rank 1.0 is an exact full-rank factorization) "
                        "and report dense-vs-factorized greedy agreement")
    p.add_argument("--rank", type=float, default=0.5,
                   help="factorization rank as a ratio of min(m, n) per "
                        "layer (1.0 = exact full rank)")
    p.add_argument("--fact-rank", type=float, default=0.0,
                   help="deprecated alias for --factorize --rank R")
    p.add_argument("--solver", default="svd",
                   choices=("svd", "snmf", "random"))
    p.add_argument("--spec-k", type=int, default=0,
                   help="speculative decoding draft depth: a rank---rank "
                        "factorized draft proposes k tokens per round, the "
                        "dense model verifies them in one multi-token step "
                        "(greedy output stays bit-identical; 0 = off)")
    p.add_argument("--mesh", default="",
                   help="serving device mesh 'dp,tp' ({data, model} axes; "
                        "e.g. '2,2' = 2-way data x 2-way tensor "
                        "parallelism over the first 4 devices).  Empty = "
                        "single-device.  Defaults to $REPRO_MESH.  "
                        "CPU-testable: export XLA_FLAGS="
                        "--xla_force_host_platform_device_count=8")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--reduced", action="store_true")
    args = p.parse_args(argv)
    if args.fact_rank:  # pre-PR6 spelling
        args.factorize, args.rank = True, args.fact_rank

    min_prompt = 4
    if not 0 <= args.shared_prefix <= args.max_prompt_len - min_prompt:
        p.error(f"--shared-prefix must be in [0, {args.max_prompt_len} - "
                f"{min_prompt}] so prompts still fit --max-prompt-len")
    if args.kv_layout != "paged" and args.decode_kernel != "reference":
        p.error("--decode-kernel pallas requires --kv-layout paged")
    long_prompt = args.long_prompt or args.max_prompt_len - args.shared_prefix
    if not 0 < long_prompt <= args.max_prompt_len - args.shared_prefix:
        p.error("--long-prompt must fit --max-prompt-len with the prefix")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    kind = getattr(model, "cache_kind", lambda c: None)(cfg)
    if kind not in (None, "kv"):
        if args.decode_kernel == "pallas":
            p.error(f"--decode-kernel pallas needs paged attention KV; "
                    f"{args.arch} serves via per-slot {kind!r} state")
        if args.prefill_kernel == "pallas":
            p.error(f"--prefill-kernel pallas needs position-addressable "
                    f"attention KV; {args.arch} serves via per-slot "
                    f"{kind!r} state")
        if args.spec_k:
            p.error(f"--spec-k needs a multi-token-capable KV cache; "
                    f"{args.arch} serves via per-slot {kind!r} state")
        print(f"# {args.arch}: per-slot {kind!r} state — paged layout / "
              "prefix cache knobs inactive")
    priority_mix = (tuple(float(w) for w in args.priority_mix.split(","))
                    if args.priority_mix else None)
    trace = make_trace(args.n_requests, seed=args.seed, load=args.load,
                       min_prompt=min_prompt,
                       max_prompt=args.max_prompt_len - args.shared_prefix,
                       min_new=4, max_new=args.max_new, vocab=cfg.vocab,
                       shared_prefix=args.shared_prefix,
                       long_frac=args.long_frac, long_prompt=long_prompt,
                       priority_mix=priority_mix)

    dims = dict(batch=args.batch, max_len=args.max_len,
                max_prompt_len=args.max_prompt_len,
                kv_layout=args.kv_layout, chunk_size=args.chunk_size,
                preemption=not args.no_preemption,
                aging_every=args.aging_every)
    if args.slo_ttft:
        from repro.serve import SloBudgetAdapter
        dims["prefill_budget_hook"] = SloBudgetAdapter(args.slo_ttft)
    if args.prefill_kernel != "reference":
        # both KV layouts take the flash prefill-chunk kernel; per-slot
        # ring/ssm families were rejected above
        dims["prefill_kernel"] = args.prefill_kernel
    if args.buckets:
        dims["buckets"] = tuple(int(b) for b in args.buckets.split(","))
    if args.prefill_budget:
        dims["prefill_chunk_budget"] = args.prefill_budget
    if args.kv_layout == "paged":
        dims["block_size"] = args.block_size
        dims["decode_kernel"] = args.decode_kernel
        dims["prefix_reuse"] = not args.no_prefix_reuse
        if args.n_blocks:
            dims["n_blocks"] = args.n_blocks
        if args.prefix_retain >= 0:
            dims["prefix_retain_blocks"] = args.prefix_retain

    from repro.dist.runtime import global_config, make_serve_mesh
    if args.mesh:
        global_config.mesh_spec = args.mesh
    try:
        mesh = make_serve_mesh()
    except ValueError as e:
        p.error(str(e))
    if mesh is not None:
        if (mesh.shape["model"] > 1
                and "pallas" in (args.decode_kernel, args.prefill_kernel)):
            p.error("pallas kernels are single-shard; use the reference "
                    "kernels with a model (tp) axis > 1")
        dims["mesh"] = mesh
        print(f"# mesh: data={mesh.shape['data']} x "
              f"model={mesh.shape['model']} on "
              f"{mesh.devices.size} {mesh.devices.flat[0].platform} devices")

    if args.http:
        if args.stream:
            p.error("--http and --stream are mutually exclusive")
        from repro.serve.http import serve as http_serve
        serve_model = model
        if args.factorize:
            serve_model = auto_fact(model, args.rank, solver=args.solver,
                                    key=jax.random.PRNGKey(1),
                                    exclude=["embed", "lm_head"], gate=False)
        if args.spec_k:
            dims["draft_model"] = auto_fact(
                serve_model, args.rank, solver=args.solver,
                key=jax.random.PRNGKey(1),
                exclude=["embed", "lm_head"], gate=False)
            dims["spec_k"] = args.spec_k
        engine = ContinuousEngine(serve_model, cfg, **dims)
        # compile warmup, one prompt per reachable bucket width (mirrors
        # bench_trace): the first live request must not pay the jit
        for plen in sorted({min(w, args.max_prompt_len)
                            for w in engine.buckets}):
            engine.submit(np.zeros(plen, np.int32), max_new_tokens=2)
        engine.run()
        engine.reset_stats()
        http_serve(engine, host=args.host, port=args.port,
                   max_pending=args.max_pending,
                   default_timeout_s=args.request_timeout or None)
        return 0

    if args.stream:
        if args.spec_k:
            p.error("--stream and --spec-k are mutually exclusive (the "
                    "streaming driver replays the plain decode path)")
        n_tok = stream_trace(model, cfg, trace, **dims)
        print(f": streamed {n_tok} tokens from {args.n_requests} requests")
        return 0

    dense_done, stats = bench_trace(model, cfg, trace, **dims)
    print(format_stats("dense", stats))
    print(format_kv_stats("dense", stats))
    print(format_prefill_stats("dense", stats))
    if priority_mix or stats.get("preemptions"):
        print(f"{'scheduling':11s}: {stats['preemptions']} preempted / "
              f"{stats['resumes']} resumed, "
              f"violations {stats['preempt_violations']} (must be 0)")

    if args.factorize:
        fact, report = auto_fact(model, args.rank, solver=args.solver,
                                 key=jax.random.PRNGKey(1),
                                 exclude=["embed", "lm_head"], gate=False,
                                 return_report=True)
        print(report.summary())
        fact_done, fstats = bench_trace(fact, cfg, trace, **dims)
        print(format_stats("factorized", fstats))
        print(format_kv_stats("factorized", fstats))
        print(format_prefill_stats("factorized", fstats))
        agree = greedy_agreement(dense_done, fact_done)
        print(f"greedy token agreement dense vs factorized: {agree:.1%}")

    if args.spec_k:
        # low-rank draft + dense verify: same greedy tokens, fewer rounds
        draft = auto_fact(model, args.rank, solver=args.solver,
                          key=jax.random.PRNGKey(1),
                          exclude=["embed", "lm_head"], gate=False)
        spec_done, sstats = bench_trace(model, cfg, trace, **dims,
                                        draft_model=draft,
                                        spec_k=args.spec_k)
        print(format_stats("speculative", sstats))
        print(f"speculative decode: k={sstats['spec_k']} "
              f"rounds={sstats['spec_rounds']} "
              f"accepted {sstats['spec_accepted_tokens']}"
              f"/{sstats['spec_drafted_tokens']} drafted "
              f"({sstats['spec_acceptance_rate']:.1%})")
        agree = greedy_agreement(dense_done, spec_done)
        print(f"greedy token agreement dense vs speculative: {agree:.1%}")
        assert agree == 1.0, "speculative decoding must be bit-exact"
    return 0


if __name__ == "__main__":
    sys.exit(main())
