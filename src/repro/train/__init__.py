from repro.train.loss import accuracy, cross_entropy
from repro.train.step import TrainState, make_eval_step, make_train_step

__all__ = ["accuracy", "cross_entropy", "TrainState", "make_eval_step",
           "make_train_step"]
