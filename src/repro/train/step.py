"""Train-step factory: grad accumulation, clipping, MoE aux loss, optional
low-rank gradient compression — one jittable function per configuration.

``TrainState`` is a plain pytree so it shards/checkpoints like everything
else.  The step is built once per (model template × optimizer × options) and
jitted/pjitted by the caller with the desired shardings.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.gradcomp import CompressorState, compress_and_reduce
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.clip import clip_by_global_norm
from repro.train.loss import cross_entropy


class TrainState(NamedTuple):
    model: Any
    opt: AdamWState
    step: jax.Array
    compressor: Optional[CompressorState] = None


def _forward_loss(model, batch, aux_weight: float):
    if "frames" in batch:  # encoder-decoder (whisper): stub frame embeddings
        logits, aux = model(batch["frames"], batch["tokens"])
    else:
        logits, aux = model(batch["tokens"])
    ce = cross_entropy(logits, batch["labels"])
    return ce + aux_weight * aux, (ce, aux)


def make_train_step(optimizer: AdamW, *, aux_weight: float = 0.01,
                    clip_norm: float = 1.0, accum: int = 1,
                    compression_axis: Optional[str] = None):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``accum > 1`` splits the batch into microbatches folded with lax.scan
    (bounds activation memory AND the synchronization quantum — straggler
    mitigation).  ``compression_axis`` enables PowerSGD-style low-rank
    gradient reduction over that mesh axis (use inside shard_map).
    """

    def loss_fn(model, batch):
        return _forward_loss(model, batch, aux_weight)

    def train_step(state: TrainState, batch):
        model = state.model

        if accum == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(model, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def micro_step(acc, mb):
                (l, (c, a)), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(model, mb)
                acc_g, acc_l, acc_c, acc_a = acc
                acc_g = jax.tree_util.tree_map(
                    lambda x, y: None if x is None else x + y, acc_g, g,
                    is_leaf=lambda x: x is None)
                return (acc_g, acc_l + l, acc_c + c, acc_a + a), None

            zero_g = jax.tree_util.tree_map(
                lambda p: None if p is None else jnp.zeros(p.shape, jnp.float32),
                model)
            init = (zero_g, jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
            (grads, loss, ce, aux), _ = jax.lax.scan(micro_step, init, micro)
            grads = jax.tree_util.tree_map(
                lambda g: None if g is None else g / accum, grads,
                is_leaf=lambda x: x is None)
            loss, ce, aux = loss / accum, ce / accum, aux / accum

        compressor = state.compressor
        if compressor is not None:
            grads, compressor = compress_and_reduce(
                grads, compressor, axis_name=compression_axis)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_model, new_opt = optimizer.update(grads, state.opt, model)
        metrics = {"loss": loss, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return TrainState(model=new_model, opt=new_opt, step=state.step + 1,
                          compressor=compressor), metrics

    return train_step


def make_eval_step(*, aux_weight: float = 0.0):
    def eval_step(model, batch):
        loss, (ce, aux) = _forward_loss(model, batch, aux_weight)
        return {"loss": loss, "ce": ce, "aux": aux}

    return eval_step
