"""Losses (fp32 regardless of model compute dtype)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE in nats. logits: (..., vocab); labels: (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
