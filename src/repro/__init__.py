"""repro — Greenformer (factorization toolkit) as a JAX/TPU training and
serving framework.

Public one-liner API, mirroring the paper:

    from repro import auto_fact
    fact_model = auto_fact(model, rank=128, solver='svd', num_iter=50)
"""

from repro.core import auto_fact, defactorize, r_max, resolve_rank

__all__ = ["auto_fact", "defactorize", "r_max", "resolve_rank"]
__version__ = "1.0.0"
