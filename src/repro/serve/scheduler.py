"""Request-level scheduler for continuous batching.

Pure-Python bookkeeping — no jax here.  The :class:`Scheduler` owns the
pending queues and the per-slot lifecycle

    submit -> pending -> admit(slot) -> PREFILLING -> bind -> running
           -> finish/evict -> slot free

while :class:`repro.serve.engine.ContinuousEngine` owns the device side
(jitted chunked prefill/decode, the batched KV cache, batched sampling
params).  Admission no longer implies a completed prefill: a slot spends
zero or more engine steps in the PREFILLING state while the engine feeds
its prompt in chunks (decode lanes keep advancing in between), and
``bind`` — called with the first sampled token once the final chunk's
logits land — moves it to running.  Prefilling slots are occupied (not
offered to ``next_admission``) but not decoded (absent from
``running_slots``).  Slots are recycled: the moment a request finishes,
its slot is handed to the next pending request without touching the
other in-flight rows.

**Priority classes.**  Every request carries an integer ``priority``
(0 = most urgent; default ``1``).  Pending requests queue per class and
``next_admission`` serves the head of the best (lowest-numbered)
non-empty class — within a class, admission order always equals
submission order and the ``admissible`` gate applies to the head only,
so a large request at the head of its class cannot be starved by a
stream of small ones behind it.  Across classes a **starvation bound**
holds: after ``aging_every`` consecutive admissions that bypass the
oldest class head (smallest uid among the heads), the next admission is
forced to be that oldest head — so low priority always eventually runs,
no matter how fast high-priority traffic arrives.

**Deadlines.**  ``timeout_s`` stamps an absolute ``deadline`` at submit
time; :meth:`expire_pending` (called by the engine at the top of every
step) drops still-queued requests whose deadline has passed with a
``finish_reason="cancelled"`` completion — a request that can no longer
meet its deadline never wastes a slot.  Routed/running requests keep
being expired by the HTTP front door's deadline sweep.

A request can be **cancelled** in any live state (the HTTP front door
does this on client disconnect and deadline expiry): ``find`` locates
the uid (O(1) for pending — a disconnect storm must not scan the whole
queue per cancel), ``cancel_pending``/``cancel_prefilling`` evict
un-bound requests with a ``finish_reason="cancelled"`` completion, and a
running slot goes through the ordinary ``finish`` with the explicit
``"cancelled"`` reason — the engine owns releasing the device-side slot
state and paged blocks in each case.  A running slot can also be
**preempted** (:meth:`preempt`): the slot empties WITHOUT emitting a
completion — the engine requeues the remainder of the request
(:meth:`requeue`, same uid) and merges the token halves when it finally
finishes.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

_uid_counter = itertools.count()

#: every finish_reason a Completion may carry — ``finish`` rejects
#: anything else, so no reason can exist that neither the classifier nor
#: an explicit eviction path (cancel / preempt) computed
FINISH_REASONS = ("stop", "length", "cache_full", "cancelled", "preempted")


@dataclass
class Request:
    """One generation request with its own sampling parameters."""

    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    temperature: float = 0.0  # 0 => greedy
    stop_ids: Tuple[int, ...] = ()
    priority: int = 1  # class, 0 = most urgent
    timeout_s: Optional[float] = None  # relative deadline (None = none)
    uid: int = field(default_factory=lambda: next(_uid_counter))
    submitted_at: float = 0.0  # stamped by Scheduler.submit
    deadline: float = 0.0      # absolute monotonic; 0 = none

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if int(self.priority) < 0:
            raise ValueError("priority must be >= 0 (0 = most urgent)")
        self.priority = int(self.priority)
        if self.timeout_s is not None and not self.timeout_s > 0:
            raise ValueError("timeout_s must be > 0 (or None)")


@dataclass
class Completion:
    """A finished request: generated tokens + lifecycle timestamps.

    ``first_token_at`` is 0.0 for a request cancelled before its first
    token landed (``ttft`` is NaN there — stats reducers skip such
    completions).  ``preemptions`` counts how many times the request was
    preempted and resumed before finishing (its ``tokens`` are the full
    merged stream across lives)."""

    uid: int
    prompt_len: int
    tokens: list  # generated ids, including the stop token if one fired
    finish_reason: str  # one of FINISH_REASONS
    priority: int = 1
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0
    preemptions: int = 0

    @property
    def latency(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def ttft(self) -> float:
        """Time to first token; NaN when no token ever landed (cancelled
        before the first sample) — a NaN poisons any reducer loudly
        instead of a huge negative epoch delta skewing it silently."""
        if self.first_token_at <= 0.0:
            return float("nan")
        return self.first_token_at - self.submitted_at


@dataclass
class _Slot:
    request: Request
    tokens: list
    first_token_at: float


class Scheduler:
    """Priority-class admission over ``n_slots`` recyclable decode slots.

    ``aging_every`` is the starvation bound: the oldest pending class
    head is bypassed by at most that many consecutive admissions before
    it is forced to the front (see the module docstring)."""

    def __init__(self, n_slots: int, *, aging_every: int = 16):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if aging_every < 1:
            raise ValueError("need aging_every >= 1")
        self.n_slots = n_slots
        self.aging_every = aging_every
        # per-class FIFO of uids; _pending is the uid -> Request index
        # (insertion-ordered = global submission order).  Cancellation
        # deletes from the index only — queue entries whose uid is gone
        # are lazily dropped at the head, so a cancel is O(1) instead of
        # an O(n_pending) deque scan (quadratic under a disconnect storm)
        self._queues: Dict[int, deque] = {}
        self._pending: Dict[int, Request] = {}
        self._aged_bypass = 0  # admissions since the oldest head last ran
        self.slots: list = [None] * n_slots
        self.prefilling: dict = {}  # slot -> Request (admitted, not bound)
        # bounded admission log (uids, admission order) for tests/introspection
        self.admitted: deque = deque(maxlen=1024)
        # every uid this scheduler has accepted, for duplicate detection
        # (a set of ints — cheap even for very long-lived servers)
        self._seen_uids: set = set()

    # -- queue ---------------------------------------------------------------

    def submit(self, request: Request) -> int:
        """Queue a request; returns the uid admission/completion will carry.

        The scheduler works on a private copy: stamping ``submitted_at``
        on the caller's object made a re-used :class:`Request` carry a
        stale timestamp, and resubmitting the same object reused its uid
        — colliding in every per-uid map downstream (``stream()``'s
        per-step event maps, the HTTP front door's response routing).  A
        uid this scheduler has already accepted is re-issued fresh, so
        the returned uid is always unique within this scheduler."""
        if request.uid in self._seen_uids:
            request = dataclasses.replace(request,
                                          uid=next(_uid_counter))
        else:
            request = dataclasses.replace(request)
        request.submitted_at = time.monotonic()
        if request.timeout_s is not None:
            request.deadline = request.submitted_at + request.timeout_s
        self._seen_uids.add(request.uid)
        self._enqueue(request)
        return request.uid

    def requeue(self, request: Request) -> None:
        """Re-queue a preempted request's remainder under its ORIGINAL
        uid (streams and response routes keyed by uid must survive the
        preemption), without re-stamping ``submitted_at`` — its latency
        clock keeps running across lives."""
        assert request.uid not in self._pending, "uid already pending"
        self._seen_uids.add(request.uid)
        self._enqueue(request)

    def _enqueue(self, request: Request) -> None:
        self._pending[request.uid] = request
        self._queues.setdefault(request.priority, deque()).append(
            request.uid)

    @property
    def pending(self) -> Tuple[Request, ...]:
        """Live pending requests in submission order (introspection)."""
        return tuple(self._pending.values())

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_prefilling(self) -> int:
        return len(self.prefilling)

    @property
    def idle(self) -> bool:
        return (not self._pending and self.n_running == 0
                and not self.prefilling)

    def running_slots(self) -> list:
        """Slots in the DECODE phase (prefilling slots are excluded — they
        have no sampled token to advance yet)."""
        return [i for i, s in enumerate(self.slots) if s is not None]

    def free_slot(self) -> Optional[int]:
        """Lowest-index free slot, or None when the batch is full.
        Prefilling slots are occupied."""
        for i, s in enumerate(self.slots):
            if s is None and i not in self.prefilling:
                return i
        return None

    def _class_heads(self) -> list:
        """(priority, head Request) per non-empty class, best class
        first; lazily drops cancelled uids off each queue head."""
        heads = []
        for prio in sorted(self._queues):
            q = self._queues[prio]
            while q and q[0] not in self._pending:
                q.popleft()  # cancelled/expired: lazy deletion
            if q:
                heads.append((prio, self._pending[q[0]]))
        return heads

    def peek_next(self) -> Optional[Request]:
        """The request ``next_admission`` would offer first (the best
        class head) — the engine's preemption policy keys on it."""
        heads = self._class_heads()
        return heads[0][1] if heads else None

    def next_admission(self, admissible=None) -> Optional[Tuple[int, Request]]:
        """(slot, request) for the next admissible pending request.

        The best (lowest-numbered) non-empty priority class is served
        first, FIFO within the class.  Every ``aging_every``-th
        admission that would bypass the oldest class head (smallest uid
        among heads) is instead forced to BE that oldest head — the
        starvation bound.  ``admissible`` (e.g. the paged engine's
        free-block reservation check) gates the chosen head only: if it
        cannot be admitted, nothing is — later requests never jump the
        chosen head, so a large request cannot be starved by a stream of
        small ones."""
        slot = self.free_slot()
        if slot is None:
            return None
        heads = self._class_heads()
        if not heads:
            return None
        oldest = min(heads, key=lambda h: h[1].uid)[1]
        choice = heads[0][1]
        if self._aged_bypass >= self.aging_every - 1:
            choice = oldest
        if admissible is not None and not admissible(choice):
            return None
        if choice.uid == oldest.uid:
            self._aged_bypass = 0
        else:
            self._aged_bypass += 1
        del self._pending[choice.uid]
        return slot, choice

    # -- deadlines -----------------------------------------------------------

    def expire_pending(self, now: Optional[float] = None) -> list:
        """Drop every still-queued request whose deadline has passed;
        returns their ``finish_reason="cancelled"`` completions.  The
        engine calls this at the top of each step, so queued requests
        honour their deadline even with no HTTP front door attached."""
        now = time.monotonic() if now is None else now
        dead = [r for r in self._pending.values()
                if r.deadline and r.deadline <= now]
        out = []
        for r in dead:
            del self._pending[r.uid]
            out.append(self._cancelled(r))
        return out

    # -- cancellation --------------------------------------------------------

    def find(self, uid: int) -> Tuple[Optional[str], Optional[int]]:
        """Locate a live uid: ``("pending"|"prefilling"|"running", slot)``
        (slot is None for pending), or ``(None, None)`` when the uid is
        unknown or already finished."""
        if uid in self._pending:
            return "pending", None
        for slot, r in self.prefilling.items():
            if r.uid == uid:
                return "prefilling", slot
        for slot, s in enumerate(self.slots):
            if s is not None and s.request.uid == uid:
                return "running", slot
        return None, None

    def _cancelled(self, request: Request) -> Completion:
        return Completion(
            uid=request.uid,
            prompt_len=int(request.prompt.size),
            tokens=[],
            finish_reason="cancelled",
            priority=request.priority,
            submitted_at=request.submitted_at,
            first_token_at=0.0,  # never produced one
            finished_at=time.monotonic(),
        )

    def cancel_pending(self, uid: int) -> Optional[Completion]:
        """Drop a still-queued request; returns its 'cancelled' Completion
        (no tokens), or None if the uid is not pending.  O(1): the uid
        index is dropped here and the class queue entry lazily at its
        head — a disconnect storm stays linear overall."""
        r = self._pending.pop(uid, None)
        if r is None:
            return None
        return self._cancelled(r)

    def cancel_prefilling(self, slot: int) -> Completion:
        """Evict a mid-prefill slot (engine releases its device state and
        blocks separately); returns the 'cancelled' Completion."""
        return self._cancelled(self.prefilling.pop(slot))

    # -- per-slot lifecycle --------------------------------------------------

    def begin_prefill(self, slot: int, request: Request) -> None:
        """Occupy ``slot`` for a request whose prompt is being chunked in;
        the slot joins decode only at :meth:`bind`."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        assert slot not in self.prefilling, f"slot {slot} already prefilling"
        self.prefilling[slot] = request

    def bind(self, slot: int, request: Request, first_token: int) -> None:
        """Attach an admitted request to its slot (prefill done)."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        self.prefilling.pop(slot, None)
        self.admitted.append(request.uid)
        self.slots[slot] = _Slot(request=request, tokens=[int(first_token)],
                                 first_token_at=time.monotonic())

    def append_token(self, slot: int, token: int) -> None:
        self.slots[slot].tokens.append(int(token))

    def preempt(self, slot: int) -> Tuple[Request, list, float]:
        """Empty a RUNNING slot without a completion: returns the evicted
        ``(request, tokens_so_far, first_token_at)``.  The engine owns
        requeueing the remainder (:meth:`requeue`) and merging the token
        halves when the resumed request finishes — the client-visible
        stream never sees a terminal event for a preemption."""
        s = self.slots[slot]
        assert s is not None, f"preempt of empty slot {slot}"
        self.slots[slot] = None
        return s.request, s.tokens, s.first_token_at

    def finish(self, slot: int, reason: str) -> Completion:
        """Evict the slot's request and free the slot for reuse."""
        if reason not in FINISH_REASONS:
            raise ValueError(f"unknown finish_reason {reason!r}; "
                             f"expected one of {FINISH_REASONS}")
        s = self.slots[slot]
        self.slots[slot] = None
        return Completion(
            uid=s.request.uid,
            prompt_len=int(s.request.prompt.size),
            tokens=s.tokens,
            finish_reason=reason,
            priority=s.request.priority,
            submitted_at=s.request.submitted_at,
            first_token_at=s.first_token_at,
            finished_at=time.monotonic(),
        )

    def finish_reason(self, slot: int, cache_pos: int, max_len: int) -> str:
        """Classify why a slot's request stopped (host-side mirror of the
        batched done mask computed on device).  Raises on a slot that no
        natural stop condition explains — an eviction with some OTHER
        cause (cancel, preemption) must pass its reason explicitly, never
        be mislabelled ``"length"`` by a silent fallthrough."""
        s = self.slots[slot]
        if s.tokens and s.tokens[-1] in s.request.stop_ids:
            return "stop"
        if len(s.tokens) >= s.request.max_new_tokens:
            return "length"
        if cache_pos >= max_len:
            return "cache_full"
        raise ValueError(
            f"slot {slot} (uid {s.request.uid}) evicted with no stop "
            f"condition met ({len(s.tokens)}/{s.request.max_new_tokens} "
            f"tokens, cache_pos {cache_pos}/{max_len}) — pass an explicit "
            "reason for cancel/preempt evictions")


__all__ = ["Request", "Completion", "Scheduler", "FINISH_REASONS"]
