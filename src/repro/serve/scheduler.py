"""Request-level scheduler for continuous batching.

Pure-Python bookkeeping — no jax here.  The :class:`Scheduler` owns the
pending FIFO queue and the per-slot lifecycle

    submit -> pending -> admit(slot) -> PREFILLING -> bind -> running
           -> finish/evict -> slot free

while :class:`repro.serve.engine.ContinuousEngine` owns the device side
(jitted chunked prefill/decode, the batched KV cache, batched sampling
params).  Admission no longer implies a completed prefill: a slot spends
zero or more engine steps in the PREFILLING state while the engine feeds
its prompt in chunks (decode lanes keep advancing in between), and
``bind`` — called with the first sampled token once the final chunk's
logits land — moves it to running.  Prefilling slots are occupied (not
offered to ``next_admission``) but not decoded (absent from
``running_slots``).  Slots are recycled: the moment a request finishes,
its slot is handed to the next pending request without touching the
other in-flight rows.

A request can be **cancelled** in any live state (the HTTP front door
does this on client disconnect and deadline expiry): ``find`` locates
the uid, ``cancel_pending``/``cancel_prefilling`` evict un-bound
requests with a ``finish_reason="cancelled"`` completion, and a running
slot goes through the ordinary ``finish`` with the explicit
``"cancelled"`` reason — the engine owns releasing the device-side slot
state and paged blocks in each case.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

_uid_counter = itertools.count()


@dataclass
class Request:
    """One generation request with its own sampling parameters."""

    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    temperature: float = 0.0  # 0 => greedy
    stop_ids: Tuple[int, ...] = ()
    uid: int = field(default_factory=lambda: next(_uid_counter))
    submitted_at: float = 0.0  # stamped by Scheduler.submit

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclass
class Completion:
    """A finished request: generated tokens + lifecycle timestamps.

    ``first_token_at`` is 0.0 for a request cancelled before its first
    token landed (``ttft`` is meaningless there — stats reducers skip
    such completions)."""

    uid: int
    prompt_len: int
    tokens: list  # generated ids, including the stop token if one fired
    finish_reason: str  # 'stop' | 'length' | 'cache_full' | 'cancelled'
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def ttft(self) -> float:
        return self.first_token_at - self.submitted_at


@dataclass
class _Slot:
    request: Request
    tokens: list
    first_token_at: float


class Scheduler:
    """FIFO admission over ``n_slots`` recyclable decode slots."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.pending: deque = deque()
        self.slots: list = [None] * n_slots
        self.prefilling: dict = {}  # slot -> Request (admitted, not bound)
        # bounded admission log (uids, FIFO order) for tests/introspection
        self.admitted: deque = deque(maxlen=1024)
        # every uid this scheduler has accepted, for duplicate detection
        # (a set of ints — cheap even for very long-lived servers)
        self._seen_uids: set = set()

    # -- queue ---------------------------------------------------------------

    def submit(self, request: Request) -> int:
        """Queue a request; returns the uid admission/completion will carry.

        The scheduler works on a private copy: stamping ``submitted_at``
        on the caller's object made a re-used :class:`Request` carry a
        stale timestamp, and resubmitting the same object reused its uid
        — colliding in every per-uid map downstream (``stream()``'s
        per-step event maps, the HTTP front door's response routing).  A
        uid this scheduler has already accepted is re-issued fresh, so
        the returned uid is always unique within this scheduler."""
        if request.uid in self._seen_uids:
            request = dataclasses.replace(request,
                                          uid=next(_uid_counter))
        else:
            request = dataclasses.replace(request)
        request.submitted_at = time.monotonic()
        self._seen_uids.add(request.uid)
        self.pending.append(request)
        return request.uid

    @property
    def n_pending(self) -> int:
        return len(self.pending)

    @property
    def n_running(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_prefilling(self) -> int:
        return len(self.prefilling)

    @property
    def idle(self) -> bool:
        return (not self.pending and self.n_running == 0
                and not self.prefilling)

    def running_slots(self) -> list:
        """Slots in the DECODE phase (prefilling slots are excluded — they
        have no sampled token to advance yet)."""
        return [i for i, s in enumerate(self.slots) if s is not None]

    def free_slot(self) -> Optional[int]:
        """Lowest-index free slot, or None when the batch is full.
        Prefilling slots are occupied."""
        for i, s in enumerate(self.slots):
            if s is None and i not in self.prefilling:
                return i
        return None

    def next_admission(self, admissible=None) -> Optional[Tuple[int, Request]]:
        """(slot, request) for the next admissible pending request.

        ``admissible`` (e.g. the paged engine's free-block reservation
        check) gates the HEAD of the queue only: if the head request cannot
        be admitted, nothing is — later requests never jump the queue, so
        admission order always equals submission order and a large request
        at the head cannot be starved by a stream of small ones."""
        slot = self.free_slot()
        if slot is None or not self.pending:
            return None
        if admissible is not None and not admissible(self.pending[0]):
            return None
        return slot, self.pending.popleft()

    # -- cancellation --------------------------------------------------------

    def find(self, uid: int) -> Tuple[Optional[str], Optional[int]]:
        """Locate a live uid: ``("pending"|"prefilling"|"running", slot)``
        (slot is None for pending), or ``(None, None)`` when the uid is
        unknown or already finished."""
        for r in self.pending:
            if r.uid == uid:
                return "pending", None
        for slot, r in self.prefilling.items():
            if r.uid == uid:
                return "prefilling", slot
        for slot, s in enumerate(self.slots):
            if s is not None and s.request.uid == uid:
                return "running", slot
        return None, None

    def _cancelled(self, request: Request) -> Completion:
        return Completion(
            uid=request.uid,
            prompt_len=int(request.prompt.size),
            tokens=[],
            finish_reason="cancelled",
            submitted_at=request.submitted_at,
            first_token_at=0.0,  # never produced one
            finished_at=time.monotonic(),
        )

    def cancel_pending(self, uid: int) -> Optional[Completion]:
        """Drop a still-queued request; returns its 'cancelled' Completion
        (no tokens), or None if the uid is not pending."""
        for i, r in enumerate(self.pending):
            if r.uid == uid:
                del self.pending[i]
                return self._cancelled(r)
        return None

    def cancel_prefilling(self, slot: int) -> Completion:
        """Evict a mid-prefill slot (engine releases its device state and
        blocks separately); returns the 'cancelled' Completion."""
        return self._cancelled(self.prefilling.pop(slot))

    # -- per-slot lifecycle --------------------------------------------------

    def begin_prefill(self, slot: int, request: Request) -> None:
        """Occupy ``slot`` for a request whose prompt is being chunked in;
        the slot joins decode only at :meth:`bind`."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        assert slot not in self.prefilling, f"slot {slot} already prefilling"
        self.prefilling[slot] = request

    def bind(self, slot: int, request: Request, first_token: int) -> None:
        """Attach an admitted request to its slot (prefill done)."""
        assert self.slots[slot] is None, f"slot {slot} busy"
        self.prefilling.pop(slot, None)
        self.admitted.append(request.uid)
        self.slots[slot] = _Slot(request=request, tokens=[int(first_token)],
                                 first_token_at=time.monotonic())

    def append_token(self, slot: int, token: int) -> None:
        self.slots[slot].tokens.append(int(token))

    def finish(self, slot: int, reason: str) -> Completion:
        """Evict the slot's request and free the slot for reuse."""
        s = self.slots[slot]
        self.slots[slot] = None
        return Completion(
            uid=s.request.uid,
            prompt_len=int(s.request.prompt.size),
            tokens=s.tokens,
            finish_reason=reason,
            submitted_at=s.request.submitted_at,
            first_token_at=s.first_token_at,
            finished_at=time.monotonic(),
        )

    def finish_reason(self, slot: int, cache_pos: int, max_len: int) -> str:
        """Classify why a slot's request stopped (host-side mirror of the
        batched done mask computed on device)."""
        s = self.slots[slot]
        if s.tokens and s.tokens[-1] in s.request.stop_ids:
            return "stop"
        if len(s.tokens) >= s.request.max_new_tokens:
            return "length"
        return "cache_full" if cache_pos >= max_len else "length"


__all__ = ["Request", "Completion", "Scheduler"]
