"""Arrival-trace generation and replay for serving benchmarks.

A trace is a list of ``(arrival_tick, Request)`` pairs: inter-arrival gaps
are exponential (a Poisson process in units of decode steps, scaled by
``load`` = expected new requests per decode step), prompt lengths and
generation budgets are sampled per request.  ``replay`` drives a
:class:`~repro.serve.engine.ContinuousEngine` through the trace — requests
are submitted when the engine's step counter passes their arrival tick, so
admission genuinely interleaves with in-flight decoding — and
``latency_stats`` reduces the completions to throughput + p50/p95.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.scheduler import Completion, Request


def make_trace(n_requests: int, *, seed: int = 0, load: float = 0.25,
               min_prompt: int = 4, max_prompt: int = 64,
               min_new: int = 4, max_new: int = 32,
               temperature: float = 0.0, vocab: int = 256,
               shared_prefix: int = 0, long_frac: float = 0.0,
               long_prompt: int = 0,
               priority_mix: Optional[Sequence[float]] = None,
               timeout_s: Optional[float] = None,
               ) -> List[Tuple[float, Request]]:
    """Sample a reproducible trace of variable-length requests.

    ``shared_prefix > 0`` prepends one common random prefix of that many
    tokens to every prompt — the shared-system-prompt workload the paged
    engine's prefix cache serves from a single refcounted block set.

    ``long_frac``/``long_prompt`` mix in a heavy tail: each request is,
    with probability ``long_frac``, a ``long_prompt``-token prompt instead
    of a ``[min_prompt, max_prompt]`` draw — the mixed long/short workload
    where monolithic prefill stalls decode and chunked prefill must not.

    ``priority_mix`` turns on mixed-priority traffic: weights over the
    priority classes ``0..len(mix)-1`` (e.g. ``(0.2, 0.8)`` = 20% class-0
    urgent, 80% class-1), sampled per request.  ``timeout_s`` stamps the
    same queued-admission deadline onto every request."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(load, 1e-6), n_requests)
    arrivals = np.cumsum(gaps)
    prefix = (rng.integers(0, vocab, shared_prefix).astype(np.int32)
              if shared_prefix else None)
    classes = weights = None
    if priority_mix is not None:
        weights = np.asarray(priority_mix, np.float64)
        if weights.ndim != 1 or weights.size < 1 or (weights < 0).any() \
                or weights.sum() <= 0:
            raise ValueError("priority_mix must be non-negative weights")
        weights = weights / weights.sum()
        classes = np.arange(weights.size)
    trace = []
    for t in arrivals:
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        if long_frac and rng.random() < long_frac:
            plen = long_prompt
        prompt = rng.integers(0, vocab, plen).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        trace.append((float(t), Request(
            prompt=prompt,
            max_new_tokens=int(rng.integers(min_new, max_new + 1)),
            temperature=temperature,
            priority=(int(rng.choice(classes, p=weights))
                      if classes is not None else 1),
            timeout_s=timeout_s,
        )))
    return trace


def replay(engine, trace: List[Tuple[float, Request]],
           max_steps: int = 100_000) -> Tuple[List[Completion], float]:
    """Run a trace to completion. Returns (completions, wall seconds)."""
    pending = sorted(trace, key=lambda p: p[0])
    done: List[Completion] = []
    i, tick = 0, 0
    t0 = time.monotonic()
    while i < len(pending) or not engine.scheduler.idle:
        while i < len(pending) and pending[i][0] <= tick:
            engine.submit(pending[i][1])  # engine-level limit validation
            i += 1
        done.extend(engine.step())
        tick += 1
        if tick >= max_steps:
            raise RuntimeError(f"trace did not drain in {max_steps} steps")
    return sorted(done, key=lambda c: c.uid), time.monotonic() - t0


def latency_stats(completions: List[Completion], wall: float) -> dict:
    """Throughput + per-request latency percentiles for a replay."""
    if not completions:
        return {"requests": 0, "generated_tokens": 0, "wall_s": wall,
                "tokens_per_s": 0.0, "latency_p50_ms": 0.0,
                "latency_p95_ms": 0.0, "ttft_p50_ms": 0.0,
                "ttft_p95_ms": 0.0}
    lats = np.array([c.latency for c in completions])
    # a request cancelled before its first token has first_token_at == 0.0
    # — its "ttft" would be a huge negative epoch delta, not a latency
    ttfts = np.array([c.ttft for c in completions if c.first_token_at > 0]
                     or [0.0])
    n_tok = int(sum(len(c.tokens) for c in completions))
    return {
        "requests": len(completions),
        "generated_tokens": n_tok,
        "wall_s": wall,
        "tokens_per_s": n_tok / max(wall, 1e-9),
        "latency_p50_ms": float(np.percentile(lats, 50) * 1e3),
        "latency_p95_ms": float(np.percentile(lats, 95) * 1e3),
        "ttft_p50_ms": float(np.percentile(ttfts, 50) * 1e3),
        "ttft_p95_ms": float(np.percentile(ttfts, 95) * 1e3),
    }


def stall_stats(step_log: List[dict]) -> dict:
    """Admission-latency profile of one replay from the engine's per-step
    log: how long each engine step took (each step ends in at most one
    batched decode advance, so a step's wall time IS the inter-decode-step
    stall its prefill work causes) and how many padded prefill tokens were
    computed inside single steps — the deterministic counterpart the
    benchmark asserts on (wall times are recorded, not asserted)."""
    if not step_log:
        return {"steps": 0, "step_wall_p50_ms": 0.0, "step_wall_p95_ms": 0.0,
                "step_wall_max_ms": 0.0, "step_prefill_tokens_p95": 0.0,
                "step_prefill_tokens_max": 0}
    walls = np.array([s["wall_s"] for s in step_log])
    ptoks = np.array([s["prefill_tokens"] for s in step_log])
    return {
        "steps": len(step_log),
        "step_wall_p50_ms": float(np.percentile(walls, 50) * 1e3),
        "step_wall_p95_ms": float(np.percentile(walls, 95) * 1e3),
        "step_wall_max_ms": float(walls.max() * 1e3),
        "step_prefill_tokens_p95": float(np.percentile(ptoks, 95)),
        "step_prefill_tokens_max": int(ptoks.max()),
    }


def bench_trace(model, cfg, trace: List[Tuple[float, Request]], *,
                batch: int, max_len: int, max_prompt_len: int,
                **engine_kwargs) -> Tuple[List[Completion], dict]:
    """Build a ContinuousEngine, warm the jitted prefill/decode pair, then
    replay ``trace`` — the shared body of the serve driver and benchmark.
    Extra kwargs (``kv_layout``, ``block_size``, ``chunk_size``, ...) pass
    through to the engine; its ``kv_stats()``, ``prefill_stats()``, and
    the per-step stall profile are merged into the stats."""
    from repro.serve.engine import ContinuousEngine

    engine = ContinuousEngine(model, cfg, batch=batch, max_len=max_len,
                              max_prompt_len=max_prompt_len, **engine_kwargs)
    # compile warmup: one prompt per reachable chunk bucket width, so the
    # replay never pays a mid-trace jit (plus the decode/bind steps)
    for plen in sorted({min(w, max_prompt_len) for w in engine.buckets}):
        engine.submit(np.zeros(plen, np.int32), max_new_tokens=2)
    engine.run()
    engine.reset_stats()  # profile the trace, not the warmup
    completions, wall = replay(engine, trace)
    stats = latency_stats(completions, wall)
    stats.update(engine.kv_stats())
    stats.update(engine.prefill_stats())
    stats.update(stall_stats(engine.step_log))
    stats.update(engine.preempt_stats())
    if engine.spec_k:
        stats.update(engine.spec_stats())
    return completions, stats


def greedy_agreement(a: List[Completion], b: List[Completion]) -> float:
    """Mean per-request token agreement between two replays of one trace
    (compared over the common prefix when lengths differ).

    Pairs with no overlapping tokens — e.g. one side cancelled before its
    first token — carry no evidence either way and are skipped rather
    than poisoning the mean with NaN; with no comparable pair at all the
    agreement is vacuously 1.0."""
    scores = []
    for ca, cb in zip(a, b):
        n = min(len(ca.tokens), len(cb.tokens))
        if n == 0:
            continue
        ta, tb = np.array(ca.tokens[:n]), np.array(cb.tokens[:n])
        scores.append(np.mean(ta == tb))
    return float(np.mean(scores)) if scores else 1.0


def format_stats(label: str, stats: dict) -> str:
    return (f"{label:11s}: {stats['tokens_per_s']:9.1f} tok/s   "
            f"p50 {stats['latency_p50_ms']:7.1f} ms   "
            f"p95 {stats['latency_p95_ms']:7.1f} ms   "
            f"ttft p50 {stats['ttft_p50_ms']:6.1f} ms   "
            f"({stats['requests']} reqs, {stats['generated_tokens']} tok)")


def format_kv_stats(label: str, stats: dict) -> str:
    """One-line render of ``ContinuousEngine.kv_stats()`` (merged into
    ``bench_trace`` stats) — the single formatter for every driver."""
    extra = ""
    layout = stats["kv_layout"]
    kind = stats.get("cache_kind", "kv")
    if layout == "paged":
        extra = (f"   ({stats['peak_blocks_in_use']}/{stats['n_blocks']} "
                 f"blocks x {stats['block_size']} tok, "
                 f"{stats['prefix_hit_tokens']} prefix-hit tok)")
    if "draft_kv_allocated_bytes" in stats:  # speculative draft pool
        extra += (f"   (+draft "
                  f"{stats['draft_kv_allocated_bytes'] / 1024:.1f} KiB)")
    elif kind != "kv":  # per-slot ring / ssm / hybrid state
        layout = kind
        if "kv_lane_tokens" in stats:
            extra = f"   (ring lanes x {stats['kv_lane_tokens']} tok)"
    return (f"{label:11s}: KV[{layout}] resident "
            f"{stats['kv_peak_resident_bytes'] / 1024:8.1f} KiB / allocated "
            f"{stats['kv_allocated_bytes'] / 1024:8.1f} KiB{extra}")


def format_prefill_stats(label: str, stats: dict) -> str:
    """One-line render of the admission-path profile (merged
    ``prefill_stats()`` + ``stall_stats``)."""
    return (f"{label:11s}: prefill {stats['prefill_tokens_computed']}"
            f"/{stats['prompt_tokens_admitted']} tok computed "
            f"({stats['prefix_hit_rate']:.0%} prefix-skip)   "
            f"chunks {stats['prefill_chunks']} "
            f"@<= {stats['max_step_prefill_tokens']} tok/step   "
            f"step p95 {stats['step_wall_p95_ms']:6.2f} ms "
            f"max {stats['step_wall_max_ms']:6.2f} ms")


__all__ = ["make_trace", "replay", "latency_stats", "stall_stats",
           "format_stats", "format_kv_stats", "format_prefill_stats",
           "bench_trace", "greedy_agreement"]
