"""SLO-aware adaptation of the engine's prefill chunk budget.

``prefill_chunk_budget`` trades TTFT against decode throughput: a bigger
budget drains the prefill backlog faster (queued prompts bind sooner),
a smaller one spends more of each step on running decodes.  The right
value depends on load, so :class:`SloBudgetAdapter` retunes it online
against a time-to-first-token target: plug one in as
``ContinuousEngine(prefill_budget_hook=...)`` and the engine calls it at
the top of every ``step()`` with itself as the argument; a non-``None``
return becomes the new budget.

The control law is deliberately boring — multiplicative increase when
the observed TTFT p95 misses the target, multiplicative decrease when it
sits comfortably under half of it, clamped to
``[min_budget, max_budget]`` and fed by the engine's bind-time
``recent_ttfts`` deque (resumed lives of preempted requests are excluded
there, so preemption does not pollute the signal).  Hysteresis comes
from the observation window: the adapter only moves after ``window``
fresh observations since its last move.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


class SloBudgetAdapter:
    """Retune ``prefill_chunk_budget`` against a TTFT SLO.

    Parameters
    ----------
    target_ttft_s:
        The SLO: observed bind-time TTFT p95 should sit at or under this.
    min_budget / max_budget:
        Clamp for the adapted budget.  ``min_budget`` defaults to the
        engine's largest bucket width (so one full chunk always fits a
        step); ``max_budget`` defaults to 8x the engine's starting
        budget.
    window:
        Fresh TTFT observations required between moves (also the number
        of most-recent observations the p95 is computed over).
    grow / shrink:
        Multiplicative step applied on miss / comfortable-hit.
    """

    def __init__(self, target_ttft_s: float, *,
                 min_budget: Optional[int] = None,
                 max_budget: Optional[int] = None,
                 window: int = 16, grow: float = 2.0, shrink: float = 0.5):
        if not target_ttft_s > 0:
            raise ValueError("need target_ttft_s > 0")
        if window < 1:
            raise ValueError("need window >= 1")
        if not (grow > 1.0 and 0.0 < shrink < 1.0):
            raise ValueError("need grow > 1 and 0 < shrink < 1")
        self.target_ttft_s = target_ttft_s
        self.min_budget, self.max_budget = min_budget, max_budget
        self.window, self.grow, self.shrink = window, grow, shrink
        self.adaptations = 0   # budget moves applied
        self.last_p95 = float("nan")
        self._seen = 0         # engine TTFT observations consumed so far

    def __call__(self, engine) -> Optional[int]:
        total = len(engine.recent_ttfts)
        if total - self._seen < self.window:
            return None  # not enough fresh signal since the last move
        self._seen = total
        ttfts = list(engine.recent_ttfts)[-self.window:]
        p95 = self.last_p95 = float(np.percentile(ttfts, 95))
        lo = (max(engine.buckets) if self.min_budget is None
              else self.min_budget)
        hi = (8 * engine.prefill_chunk_budget if self.max_budget is None
              else self.max_budget)
        if self.max_budget is None:
            # resolve the default cap ONCE, against the starting budget —
            # a ratcheting cap would make the ceiling unbounded
            self.max_budget = hi
        cur = engine.prefill_chunk_budget
        if p95 > self.target_ttft_s:
            new = min(hi, int(cur * self.grow))
        elif p95 < 0.5 * self.target_ttft_s:
            new = max(lo, int(cur * self.shrink))
        else:
            return None
        if new == cur:
            return None
        self.adaptations += 1
        return new


__all__ = ["SloBudgetAdapter"]
