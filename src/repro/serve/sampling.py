"""Shared token-sampling helpers for every serving path.

One implementation used by one-shot ``generate``, the lock-step ``Engine``,
and ``ContinuousEngine``'s jitted bind/decode steps, so the three engines
cannot drift (they are asserted bit-identical by the differential tests —
a private fork of the sampler in any one of them is how that breaks).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_tokens(logits: jax.Array) -> jax.Array:
    """Argmax over the vocab axis -> int32 token ids."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_tokens(logits: jax.Array, temp: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Per-row temperature sampling: greedy rows and sampled rows coexist
    in one batch (Gumbel-max so a single argmax serves both branches).

    ``logits``: (batch, vocab); ``temp``: (batch,) float32, 0 => greedy.
    """
    greedy = greedy_tokens(logits)
    g = jax.random.gumbel(key, logits.shape, jnp.float32)
    t = jnp.maximum(temp, 1e-6)[:, None]
    sampled = jnp.argmax(logits.astype(jnp.float32) / t + g, axis=-1)
    return jnp.where(temp > 0, sampled, greedy).astype(jnp.int32)


__all__ = ["greedy_tokens", "sample_tokens"]
