from repro.serve.engine import Engine, generate

__all__ = ["Engine", "generate"]
