"""Serving subsystem: continuous batching over factorized (or dense) models.

Three layers:

* ``repro.serve.engine`` — device execution.  ``generate`` (one-shot
  prefill + scan decode, the equivalence baseline), ``Engine`` (lock-step
  fixed batch, kept for SSM/encdec caches), and ``ContinuousEngine``: a
  fixed slot batch where requests join and leave mid-flight under ONE
  jitted prefill and ONE jitted decode step.  Prompts are right-padded to
  a fixed prefill width and spliced into per-slot KV-cache lanes with
  ``lax.dynamic_update_slice``; per-request sampling params (temperature,
  max_new_tokens, stop ids) ride along as batched arrays so stop/evict
  decisions happen in-graph.
* ``repro.serve.scheduler`` — host lifecycle.  FIFO pending queue,
  admit -> prefill -> decode -> finish/evict, slot recycling.
* ``repro.serve.trace`` — Poisson arrival traces, replay, latency stats.

Quick use::

    eng = ContinuousEngine(model, cfg, batch=8, max_len=256,
                           max_prompt_len=64)
    eng.submit([1, 2, 3], max_new_tokens=16)           # greedy
    eng.submit(prompt2, max_new_tokens=8, temperature=0.7, stop_ids=(0,))
    completions = eng.run()                            # drain the queue
"""

from repro.serve.engine import ContinuousEngine, Engine, generate
from repro.serve.scheduler import Completion, Request, Scheduler
from repro.serve.trace import (bench_trace, format_stats, greedy_agreement,
                               latency_stats, make_trace, replay)

__all__ = ["Engine", "ContinuousEngine", "generate", "Request", "Completion",
           "Scheduler", "make_trace", "replay", "latency_stats",
           "format_stats", "bench_trace", "greedy_agreement"]
