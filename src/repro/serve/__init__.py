"""Serving subsystem: continuous batching over a paged KV cache.

Four layers:

* ``repro.serve.engine`` — device execution.  ``generate`` (one-shot
  prefill + scan decode, the equivalence baseline), ``Engine`` (lock-step
  fixed batch, kept for SSM/encdec caches), and ``ContinuousEngine``: a
  fixed slot batch where requests join and leave mid-flight under ONE
  jitted prefill and ONE jitted decode step.  The default KV layout is
  **paged**: all slots share a pool of ``block_size``-token KV blocks
  (``PagedKVCache.k/v: (n_layers, n_blocks, block_size, kv_heads,
  head_dim)``) and each slot maps logical position ``p`` to pool row
  ``table[slot, p // block_size] * block_size + p % block_size`` through
  its block-table row (``table: (batch, ceil(max_len / block_size))``
  int32, sentinel ``n_blocks`` for unmapped entries).  Decode is a
  gather/scatter against the table inside the same single jitted step;
  HBM spent on KV is proportional to live tokens, not ``batch *
  max_len``.  ``kv_layout="dense"`` keeps the original per-slot lanes as
  the bit-exactness baseline, and ``decode_kernel="pallas"`` swaps the
  paged decode gather+attention for the fused
  :func:`repro.kernels.paged_attention` kernel (KV blocks stream through
  VMEM inside an online-softmax loop; greedy tokens bit-identical to the
  ``"reference"`` dense-gather path).
* ``repro.serve.paging`` — host block bookkeeping.  Refcounted
  ``BlockAllocator`` over the pool, ``PrefixCache`` keyed by sha256
  hash-chains over *full* prompt blocks (``key_i = sha256(key_{i-1} ||
  block_tokens)``) so requests sharing a system prompt reuse the same
  refcounted prefill blocks (shared blocks are immutable; a request
  extends past them into freshly allocated blocks — copy-on-extend
  without the copy), and ``PagedCacheManager``, which reserves
  ``ceil(min(prompt_len + max_new, max_len) / block_size)`` blocks per
  request at admission so decode can never run out of blocks
  mid-request.
* ``repro.serve.scheduler`` — host lifecycle.  FIFO pending queue,
  admit -> prefill -> decode -> finish/evict, slot recycling.  When the
  block pool cannot hold the head request's reservation, admission
  defers (head-of-line, so FIFO order is preserved and nothing starves)
  and resumes as finished requests free their blocks.
* ``repro.serve.trace`` — Poisson arrival traces (optionally with a
  shared system-prompt prefix), replay, latency + KV-memory stats.

Greedy outputs are bit-identical across ``generate``, ``Engine``, and
both ``ContinuousEngine`` layouts — enforced by the differential harness
in ``tests/test_paging.py``.

Quick use::

    eng = ContinuousEngine(model, cfg, batch=8, max_len=256,
                           max_prompt_len=64, block_size=16)
    eng.submit([1, 2, 3], max_new_tokens=16)           # greedy
    eng.submit(prompt2, max_new_tokens=8, temperature=0.7, stop_ids=(0,))
    completions = eng.run()                            # drain the queue
    print(eng.kv_stats())  # peak HBM-resident KV bytes, prefix hits, ...
"""

from repro.nn.attention import UnsupportedCacheError
from repro.serve.engine import ContinuousEngine, Engine, generate
from repro.serve.paging import (BlockAllocator, PagedCacheManager,
                                PrefixCache, chain_keys)
from repro.serve.scheduler import Completion, Request, Scheduler
from repro.serve.trace import (bench_trace, format_kv_stats, format_stats,
                               greedy_agreement, latency_stats, make_trace,
                               replay)

__all__ = ["Engine", "ContinuousEngine", "generate", "Request", "Completion",
           "Scheduler", "BlockAllocator", "PagedCacheManager", "PrefixCache",
           "UnsupportedCacheError", "chain_keys", "make_trace", "replay",
           "latency_stats", "format_stats", "format_kv_stats", "bench_trace",
           "greedy_agreement"]
