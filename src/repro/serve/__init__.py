"""Serving subsystem: continuous batching over a paged KV cache with
chunked, prefix-aware, bucketed prefill.  (``README.md`` in this package
walks the full admission pipeline.)

Five modules:

* ``repro.serve.engine`` — device execution.  ``generate`` (one-shot
  prefill + scan decode, the equivalence baseline), ``Engine`` (lock-step
  fixed batch, kept for encdec caches and as a baseline), and
  ``ContinuousEngine``: a fixed slot batch where requests join and leave
  mid-flight.  The model's ``cache_kind(cfg)`` capability probe selects
  the per-slot state family — ``"kv"`` (paged / dense attention KV),
  ``"ring"`` (sliding-window ring lanes, O(window) per slot), ``"ssm"``
  (mamba conv/ssm recurrent state, O(1) per slot), ``"hybrid"`` (hymba:
  ring + ssm); non-KV kinds cannot be paged or prefix-cached, so those
  knobs degrade gracefully (see ``README.md`` §Cache kinds).  Prompts
  are prefilled in bucket-padded chunks (2-3 compile widths) under a
  per-step token budget, interleaved with ONE jitted batched decode
  step — a long prompt never freezes the running decode lanes.  The
  default KV layout is **paged**: all slots share a pool of
  ``block_size``-token KV blocks (``PagedKVCache.k/v: (n_layers,
  n_blocks, block_size, kv_heads, head_dim)``) and each slot maps
  logical position ``p`` to pool row ``table[slot, p // block_size] *
  block_size + p % block_size`` through its block-table row (``table:
  (batch, ceil(max_len / block_size))`` int32, sentinel ``n_blocks`` for
  unmapped entries); HBM spent on KV is proportional to live tokens, not
  ``batch * max_len``.  A prompt whose prefix is already resident starts
  prefilling AFTER the cached blocks (compute skipped, not just memory).
  ``kv_layout="dense"`` keeps the original per-slot lanes as the
  bit-exactness baseline, and ``decode_kernel="pallas"`` swaps the paged
  decode gather+attention for the fused
  :func:`repro.kernels.paged_attention` kernel (KV blocks stream through
  VMEM inside an online-softmax loop; greedy tokens bit-identical to the
  ``"reference"`` dense-gather path).  ``stream()`` / ``on_token`` yield
  tokens as they land.  ``draft_model``/``spec_k`` turn on greedy
  **speculative decoding**: a low-rank ``auto_fact`` draft proposes
  ``spec_k`` tokens per round and the dense model verifies them in one
  multi-token decode step — output bit-identical to plain greedy by
  construction, acceptance rate in ``spec_stats()`` (see ``README.md``
  §Factorized serving & speculative decoding).
* ``repro.serve.paging`` — host block bookkeeping.  Refcounted
  ``BlockAllocator`` over the pool, ``PrefixCache`` keyed by sha256
  hash-chains over *full* prompt blocks (``key_i = sha256(key_{i-1} ||
  block_tokens)``) so requests sharing a system prompt reuse the same
  refcounted prefill blocks (shared blocks are immutable; a request
  extends past them into freshly allocated blocks — copy-on-extend
  without the copy), and ``PagedCacheManager``, which reserves
  ``ceil(min(prompt_len + max_new, max_len) / block_size)`` blocks per
  request at admission so decode can never run out of blocks
  mid-request, reports the longest cached block-chain so prefill can
  skip it, gates same-step dependents until their provider's chunks
  publish the shared blocks, and parks freed prefix blocks on an LRU so
  hits survive idle periods.
* ``repro.serve.scheduler`` — host lifecycle.  Priority-class pending
  queues (0 = most urgent; FIFO within a class, an ``aging_every``
  starvation bound across classes), deadline-aware admission
  (``timeout_s`` drops still-queued requests at expiry), admit ->
  PREFILLING (chunks in flight) -> bind -> decode -> finish/evict,
  slot recycling.  When the block pool cannot hold the chosen head's
  reservation, admission defers (head-of-line within the class, so
  nothing starves); with ``preemption`` on, the engine instead evicts a
  strictly-lower-priority running decode and resumes it later as a
  prefix-hit re-admission (bit-identical greedy stream, merged
  Completion — see ``README.md`` §Scheduling policy).
* ``repro.serve.slo`` — ``SloBudgetAdapter``, an engine
  ``prefill_budget_hook`` that retunes ``prefill_chunk_budget`` online
  against a TTFT SLO target.
* ``repro.serve.sampling`` — the one greedy/temperature sampler every
  engine shares (Gumbel-max merge of greedy and sampled rows).
* ``repro.serve.trace`` — Poisson arrival traces (optionally with a
  shared system-prompt prefix and/or a long-prompt tail), replay,
  latency + KV-memory + admission-stall stats.
* ``repro.serve.http`` — the async HTTP front door: ``POST
  /v1/generate`` with SSE token streaming, per-request deadlines and
  client-disconnect **cancellation** (propagated into
  ``ContinuousEngine.cancel`` — slot, parked frontier, and refcounted
  paged blocks all released mid-prefill or mid-decode), a bounded
  admission queue answering 429 backpressure, and ``GET /metrics``
  Prometheus exposition of the engine stats.  ``BackgroundServer`` runs
  it on a daemon thread for synchronous callers;
  ``repro.launch.loadgen`` is the matching closed-/open-loop client.

Greedy outputs are bit-identical across ``generate``, ``Engine``, both
``ContinuousEngine`` layouts, every cache kind, and any prefill
chunking — enforced by the differential harnesses in
``tests/test_paging.py``, ``tests/test_chunked_prefill.py``, and
``tests/test_hetero_serving.py`` (hymba/mamba), with ring-buffer
invariants property-tested in ``tests/test_ring_buffer.py``.  One carve-out: capacity-factor MoE
routing is sequence-length-dependent, so MoE prompts see slightly
different expert-capacity dropping under any padding or chunking of the
prefill (this was already true of the monolithic padded prefill vs
exact-length ``generate``); the bit-identity contract covers
capacity-exact models.

Quick use::

    eng = ContinuousEngine(model, cfg, batch=8, max_len=256,
                           max_prompt_len=64, block_size=16,
                           chunk_size=32, prefill_chunk_budget=32)
    eng.submit([1, 2, 3], max_new_tokens=16)           # greedy
    eng.submit(prompt2, max_new_tokens=8, temperature=0.7, stop_ids=(0,))
    completions = eng.run()                            # drain the queue
    for uid, tok, done in eng.stream(): ...            # or stream tokens
    print(eng.kv_stats())       # resident KV bytes, prefix hits, ...
    print(eng.prefill_stats())  # chunks, computed vs skipped tokens, ...
"""

from repro.nn.attention import UnsupportedCacheError
from repro.serve.engine import ContinuousEngine, Engine, generate
from repro.serve.http import BackgroundServer, HttpServer, ServeMetrics
from repro.serve.paging import (BlockAllocator, PagedCacheManager,
                                PrefixCache, chain_keys)
from repro.serve.sampling import greedy_tokens, sample_tokens
from repro.serve.scheduler import Completion, Request, Scheduler
from repro.serve.slo import SloBudgetAdapter
from repro.serve.trace import (bench_trace, format_kv_stats,
                               format_prefill_stats, format_stats,
                               greedy_agreement, latency_stats, make_trace,
                               replay, stall_stats)

__all__ = ["Engine", "ContinuousEngine", "generate", "Request", "Completion",
           "Scheduler", "BlockAllocator", "PagedCacheManager", "PrefixCache",
           "UnsupportedCacheError", "chain_keys", "make_trace", "replay",
           "latency_stats", "stall_stats", "format_stats", "format_kv_stats",
           "format_prefill_stats", "bench_trace", "greedy_agreement",
           "greedy_tokens", "sample_tokens", "HttpServer",
           "BackgroundServer", "ServeMetrics", "SloBudgetAdapter"]
