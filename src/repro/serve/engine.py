"""Serving engines: one-shot ``generate``, the lock-step ``Engine``
baseline, and the continuous-batching ``ContinuousEngine``.

``generate`` is the jittable one-shot core (prefill + ``lax.scan`` decode);
``Engine`` keeps the fixed-slot lock-step shape (every row prefills and
decodes together — still the right tool for SSM/encdec caches and for
bit-exactness baselines).  ``ContinuousEngine`` is the serving system:
requests are admitted into recyclable slots mid-flight, each slot carrying
its own KV-cache lane, position counter, and sampling params, under ONE
jitted prefill and ONE jitted decode step — no recompiles as traffic
arrives.  See ``repro.serve.scheduler`` for the request lifecycle and
``repro.serve.trace`` for workload replay.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.attention import UnsupportedCacheError
from repro.serve.paging import PagedCacheManager
from repro.serve.scheduler import Completion, Request, Scheduler


def generate(model, tokens: jax.Array, cache, *, n_steps: int,
             temperature: float = 0.0, key: Optional[jax.Array] = None):
    """Prefill on ``tokens`` then decode ``n_steps`` tokens.

    Returns (generated (batch, n_steps), final cache)."""
    logits, cache = model.prefill(tokens, cache)

    def sample(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(k, logits[:, -1] / temperature)

    if key is None:
        key = jax.random.PRNGKey(0)
    first = sample(logits, key)

    def step(carry, k):
        tok, cache = carry
        logits, cache = model.decode(tok[:, None], cache)
        nxt = sample(logits, k)
        return (nxt, cache), tok

    keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
    (last, cache), toks = jax.lax.scan(step, (first, cache), keys)
    out = jnp.concatenate([toks.T, last[:, None]], axis=1)[:, :n_steps]
    return out, cache


class Engine:
    """Fixed-slot lock-step batching (the pre-continuous baseline).

    One jitted prefill + one jitted decode step; every row moves together.
    Kept for SSM/encdec cache families and as the equivalence baseline for
    ``ContinuousEngine``."""

    def __init__(self, model, cfg, *, batch: int, max_len: int,
                 cache_dtype=jnp.bfloat16, enc_len: Optional[int] = None):
        self.model, self.cfg = model, cfg
        self.batch, self.max_len = batch, max_len
        kwargs = {"enc_len": enc_len} if enc_len is not None else {}
        self._cache0 = model.init_cache(batch, max_len, cfg,
                                        dtype=cache_dtype, **kwargs)
        self._prefill = jax.jit(lambda toks, c: model.prefill(toks, c))
        self._decode = jax.jit(lambda tok, c: model.decode(tok, c))
        self.cache = self._cache0

    def reset(self) -> None:
        self.cache = self._cache0

    def prefill(self, tokens: jax.Array) -> jax.Array:
        logits, self.cache = self._prefill(tokens, self.cache)
        return logits

    def decode_step(self, tok: jax.Array) -> jax.Array:
        logits, self.cache = self._decode(tok, self.cache)
        return logits

    def greedy(self, tokens: jax.Array, n_steps: int) -> jax.Array:
        logits = self.prefill(tokens)
        out = [jnp.argmax(logits[:, -1], -1)]
        for _ in range(n_steps - 1):
            logits = self.decode_step(out[-1][:, None])
            out.append(jnp.argmax(logits[:, -1], -1))
        return jnp.stack(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class _SlotArrays(NamedTuple):
    """Per-slot device state: the batched half of the request lifecycle."""

    tok: jax.Array       # (B,) int32 — last sampled token per slot
    active: jax.Array    # (B,) bool — slot holds a live request
    temp: jax.Array      # (B,) float32 — 0 => greedy
    n_gen: jax.Array     # (B,) int32 — tokens generated so far (incl. first)
    max_new: jax.Array   # (B,) int32
    stop_ids: jax.Array  # (B, K) int32, -1 padded


def _sample(logits: jax.Array, temp: jax.Array, key: jax.Array) -> jax.Array:
    """Per-row temperature sampling: greedy rows and sampled rows coexist
    in one batch (Gumbel-max so a single argmax serves both branches)."""
    greedy = jnp.argmax(logits, axis=-1)
    g = jax.random.gumbel(key, logits.shape, jnp.float32)
    t = jnp.maximum(temp, 1e-6)[:, None]
    sampled = jnp.argmax(logits.astype(jnp.float32) / t + g, axis=-1)
    return jnp.where(temp > 0, sampled, greedy).astype(jnp.int32)


class ContinuousEngine:
    """Continuous-batching serving engine over a fixed slot batch.

    Requests join and leave mid-flight: a prefill runs on a single-row lane
    (prompts right-padded to ``max_prompt_len`` so the jit compiles once),
    the lane's K/V rows are committed into the batched cache at the free
    slot, and the batched decode step advances every active slot at its own
    position.  Stop-token / max-token / cache-full eviction is computed
    in-graph from batched per-request params; the host scheduler only
    mirrors the lifecycle and collects tokens.

    Two KV layouts (``kv_layout``):

    * ``"paged"`` (default) — all slots share one pool of
      ``block_size``-token KV blocks (:class:`repro.nn.attention.
      PagedKVCache`); a host-side :class:`~repro.serve.paging.
      PagedCacheManager` reserves ``ceil(min(prompt+max_new, max_len) /
      block_size)`` blocks per request at admission (so decode can never
      exhaust the pool mid-request), shares full prompt blocks between
      requests with equal prefixes (hash-keyed, refcounted), and defers
      FIFO admission while the pool is out of blocks.  HBM spent on KV is
      proportional to live tokens instead of ``batch * max_len``.
    * ``"dense"`` — the original per-slot layout: every slot reserves a
      dense ``max_len`` lane, spliced with ``lax.dynamic_update_slice``.
      Kept as the bit-exactness baseline and for the benchmark comparison.

    ``decode_kernel`` (paged layout only) picks the decode attention
    implementation: ``"reference"`` materializes the dense gather from
    the pool before masked attention; ``"pallas"`` runs the fused
    :func:`repro.kernels.paged_attention` kernel, streaming KV blocks
    through VMEM inside an online-softmax loop (interpret mode off-TPU).
    Greedy tokens are bit-identical between the two.

    Requires a global-attention KV cache (``cfg.window == 0``) — ring-buffer
    lanes cannot be slot-recycled or paged yet (see ROADMAP).
    """

    def __init__(self, model, cfg, *, batch: int, max_len: int,
                 max_prompt_len: int, max_stop_ids: int = 4,
                 cache_dtype=jnp.float32, seed: int = 0,
                 kv_layout: str = "paged", block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 decode_kernel: str = "reference"):
        if cfg.window:
            raise UnsupportedCacheError(
                "continuous batching needs a global-attention KV cache "
                f"(cfg.window == 0, got {cfg.window}); sliding-window "
                "ring-buffer lanes cannot be slot-recycled or paged yet",
                roadmap_item="ring-buffer (sliding-window) caches in "
                "per-slot mode so hymba-family models can serve "
                "continuously")
        if not 0 < max_prompt_len < max_len:
            raise ValueError("need 0 < max_prompt_len < max_len")
        if kv_layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if decode_kernel not in ("reference", "pallas"):
            raise ValueError(f"unknown decode_kernel {decode_kernel!r}")
        if decode_kernel == "pallas" and kv_layout != "paged":
            raise ValueError(
                "decode_kernel='pallas' is the fused paged-attention "
                "kernel; it requires kv_layout='paged'")
        self.decode_kernel = decode_kernel
        self.model, self.cfg = model, cfg
        self.batch, self.max_len = batch, max_len
        self.max_prompt_len, self.max_stop_ids = max_prompt_len, max_stop_ids
        self.kv_layout, self.cache_dtype = kv_layout, jnp.dtype(cache_dtype)
        if kv_layout == "paged":
            if block_size < 1:
                raise ValueError("need block_size >= 1")
            self.block_size = block_size
            self.n_blocks = (batch * (-(-max_len // block_size))
                             if n_blocks is None else n_blocks)
            if not hasattr(model, "init_paged_cache"):
                raise UnsupportedCacheError(
                    f"{type(model).__name__} has no paged KV cache; the "
                    "paged layout supports attention-KV models only",
                    roadmap_item="extend per-slot state to Mamba conv/ssm "
                    "states and Whisper enc caches")
            self.cache = model.init_paged_cache(
                batch, max_len, cfg, n_blocks=self.n_blocks,
                block_size=block_size, dtype=cache_dtype)
            self.manager = PagedCacheManager(
                n_blocks=self.n_blocks, block_size=block_size, batch=batch,
                max_len=max_len)
            self._table_dirty = False
            lane_len = max_prompt_len
        else:
            try:
                self.cache = model.init_cache(batch, max_len, cfg,
                                              dtype=cache_dtype,
                                              per_slot=True)
            except TypeError:
                raise UnsupportedCacheError(
                    f"{type(model).__name__} has no per-slot KV cache; "
                    "continuous batching supports attention-KV models only",
                    roadmap_item="extend per-slot state to Mamba conv/ssm "
                    "states and Whisper enc caches")
            self.manager = None
            lane_len = max_len
        self._lane0 = model.init_cache(1, lane_len, cfg, dtype=cache_dtype)
        self.state = _SlotArrays(
            tok=jnp.zeros((batch,), jnp.int32),
            active=jnp.zeros((batch,), bool),
            temp=jnp.zeros((batch,), jnp.float32),
            n_gen=jnp.zeros((batch,), jnp.int32),
            max_new=jnp.ones((batch,), jnp.int32),
            stop_ids=jnp.full((batch, max_stop_ids), -1, jnp.int32),
        )
        self.scheduler = Scheduler(batch)
        self._base_key = jax.random.PRNGKey(seed)
        self._tick = 0

        def prefill_fn(toks, lane, length, temp, key):
            logits, lane = model.prefill(toks, lane, length=length)
            first = _sample(logits[:, 0], temp[None], key)[0]
            return first, lane

        def bind_state(state, slot, length, first, temp, max_new, stop_row):
            done0 = (jnp.any(first == stop_row) | (max_new <= 1)
                     | (length >= max_len))
            state = state._replace(
                tok=state.tok.at[slot].set(first),
                active=state.active.at[slot].set(~done0),
                temp=state.temp.at[slot].set(temp),
                n_gen=state.n_gen.at[slot].set(1),
                max_new=state.max_new.at[slot].set(max_new),
                stop_ids=state.stop_ids.at[slot].set(stop_row),
            )
            return state, done0

        def admit_fn(cache, state, lane, slot, length, first, temp,
                     max_new, stop_row):
            k = jax.lax.dynamic_update_slice(cache.k, lane.k,
                                             (0, slot, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(cache.v, lane.v,
                                             (0, slot, 0, 0, 0))
            ln = cache.length.at[:, slot].set(length)
            state, done0 = bind_state(state, slot, length, first, temp,
                                      max_new, stop_row)
            return cache._replace(k=k, v=v, length=ln), state, done0

        def commit_fn(cache, state, lane, dst, slot, length, first, temp,
                      max_new, stop_row):
            # scatter the lane's first `length` K/V rows into the pool
            # blocks picked by the allocator; `dst` points cached-prefix and
            # padding positions at the out-of-range sentinel row, so
            # mode='drop' leaves shared blocks untouched
            L, nb, bs = cache.k.shape[:3]
            tail = cache.k.shape[3:]
            pool_k = cache.k.reshape(L, nb * bs, *tail)
            pool_v = cache.v.reshape(L, nb * bs, *tail)
            pool_k = pool_k.at[:, dst].set(lane.k[:, 0], mode="drop")
            pool_v = pool_v.at[:, dst].set(lane.v[:, 0], mode="drop")
            ln = cache.length.at[:, slot].set(length)
            state, done0 = bind_state(state, slot, length, first, temp,
                                      max_new, stop_row)
            return cache._replace(k=pool_k.reshape(cache.k.shape),
                                  v=pool_v.reshape(cache.v.shape),
                                  length=ln), state, done0

        if self.manager is not None:
            # paged decode takes the kernel knob; dense/per-slot model
            # families keep their original decode signature
            dk = self.decode_kernel

            def model_decode(tok, cache):
                return model.decode(tok, cache, decode_kernel=dk)
        else:
            model_decode = model.decode

        def decode_fn(cache, state, key):
            logits, new_cache = model_decode(state.tok[:, None], cache)
            nxt = _sample(logits[:, 0], state.temp, key)
            nxt = jnp.where(state.active, nxt, state.tok)
            # frozen slots keep their cache position and token
            length = jnp.where(state.active[None, :], new_cache.length,
                               cache.length)
            n_gen = jnp.where(state.active, state.n_gen + 1, state.n_gen)
            stop_hit = jnp.any(nxt[:, None] == state.stop_ids, axis=-1)
            done = state.active & (stop_hit | (n_gen >= state.max_new)
                                   | (length[0] >= max_len))
            state = state._replace(tok=nxt, active=state.active & ~done,
                                   n_gen=n_gen)
            return new_cache._replace(length=length), state, nxt, done

        self._prefill = jax.jit(prefill_fn)
        self._admit = jax.jit(commit_fn if self.manager is not None
                              else admit_fn, donate_argnums=(0, 1))
        self._decode = jax.jit(decode_fn, donate_argnums=(0, 1))

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: Optional[int] = None,
               temperature: float = 0.0,
               stop_ids: Sequence[int] = ()) -> int:
        """Queue one request; returns its uid (FIFO admission).

        ``prompt`` is either a token-id sequence (with ``max_new_tokens``
        etc. given here) or a prebuilt :class:`Request` — both go through
        the same engine-limit validation."""
        if isinstance(prompt, Request):
            req = prompt
        else:
            if max_new_tokens is None:
                raise ValueError("max_new_tokens is required")
            req = Request(prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens,
                          temperature=temperature, stop_ids=tuple(stop_ids))
        if req.prompt.size > self.max_prompt_len:
            raise ValueError(
                f"prompt length {req.prompt.size} > max_prompt_len "
                f"{self.max_prompt_len}")
        if len(req.stop_ids) > self.max_stop_ids:
            raise ValueError(f"more than {self.max_stop_ids} stop ids")
        if self.manager is not None:
            need = self.manager.blocks_needed(self._total_tokens(req))
            if need > self.n_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool has only "
                    f"{self.n_blocks}; raise n_blocks or lower "
                    "max_new_tokens")
        return self.scheduler.submit(req)

    def _total_tokens(self, req: Request) -> int:
        """Worst-case cache positions a request can occupy (reservation)."""
        return min(int(req.prompt.size) + int(req.max_new_tokens),
                   self.max_len)

    def _next_key(self) -> jax.Array:
        self._tick += 1
        return jax.random.fold_in(self._base_key, self._tick)

    # -- serving loop --------------------------------------------------------

    def _next_admission(self):
        """FIFO head-of-line admission; the paged layout additionally gates
        on the head request's block reservation fitting the free pool."""
        if self.manager is None:
            return self.scheduler.next_admission()
        return self.scheduler.next_admission(
            admissible=lambda r: self.manager.can_admit(
                r.prompt, self._total_tokens(r)))

    def _finish(self, slot: int, cache_pos: int) -> Completion:
        """Evict a finished slot: classify, release its KV blocks (paged),
        and hand the slot back to the scheduler."""
        reason = self.scheduler.finish_reason(slot, cache_pos, self.max_len)
        if self.manager is not None:
            self.manager.release(slot)
            self._table_dirty = True
        return self.scheduler.finish(slot, reason)

    def step(self) -> list:
        """Admit pending requests into free slots, then run one batched
        decode step.  Returns the :class:`Completion`s finished this step."""
        finished = []
        while (adm := self._next_admission()) is not None:
            slot, req = adm
            toks = np.zeros((1, self.max_prompt_len), np.int32)
            toks[0, :req.prompt.size] = req.prompt
            stop_row = np.full((self.max_stop_ids,), -1, np.int32)
            stop_row[:len(req.stop_ids)] = req.stop_ids
            first, lane = self._prefill(
                jnp.asarray(toks), self._lane0,
                jnp.asarray(req.prompt.size, jnp.int32),
                jnp.asarray(req.temperature, jnp.float32), self._next_key())
            args = (jnp.asarray(slot, jnp.int32),
                    jnp.asarray(req.prompt.size, jnp.int32), first,
                    jnp.asarray(req.temperature, jnp.float32),
                    jnp.asarray(req.max_new_tokens, jnp.int32),
                    jnp.asarray(stop_row))
            if self.manager is not None:
                _, dst = self.manager.admit(slot, req.prompt,
                                            self._total_tokens(req),
                                            self.max_prompt_len)
                self._table_dirty = True
                self.cache, self.state, done0 = self._admit(
                    self.cache, self.state, lane, jnp.asarray(dst), *args)
            else:
                self.cache, self.state, done0 = self._admit(
                    self.cache, self.state, lane, *args)
            self.scheduler.bind(slot, req, int(first))
            if bool(done0):
                finished.append(self._finish(slot, req.prompt.size))

        running = self.scheduler.running_slots()
        if running:
            if self.manager is not None and self._table_dirty:
                self.cache = self.cache._replace(
                    table=jnp.asarray(self.manager.tables))
                self._table_dirty = False
            self.cache, self.state, nxt, done = self._decode(
                self.cache, self.state, self._next_key())
            nxt_np, done_np = np.asarray(nxt), np.asarray(done)
            pos_np = np.asarray(self.cache.length[0])
            for slot in running:
                self.scheduler.append_token(slot, nxt_np[slot])
                if done_np[slot]:
                    finished.append(self._finish(slot, int(pos_np[slot])))
        return finished

    # -- introspection -------------------------------------------------------

    def kv_stats(self) -> dict:
        """HBM accounting for the KV cache (bytes, both layouts).

        ``kv_allocated_bytes`` is what the layout reserves up front;
        ``kv_peak_resident_bytes`` is the high-water mark of bytes holding
        live tokens — for the dense layout the two coincide (every slot
        pins a ``max_len`` lane), for the paged layout the peak tracks
        blocks actually in use, which is what a right-sized pool would
        need."""
        alloc = 2 * self.cache.k.size * self.cache.k.dtype.itemsize
        if self.manager is None:
            return {"kv_layout": "dense", "kv_allocated_bytes": alloc,
                    "kv_peak_resident_bytes": alloc}
        block_bytes = 2 * (self.cache.k.size // self.n_blocks
                           ) * self.cache.k.dtype.itemsize
        a = self.manager.allocator
        return {"kv_layout": "paged", "kv_allocated_bytes": alloc,
                "kv_peak_resident_bytes": a.peak_in_use * block_bytes,
                "block_size": self.block_size, "n_blocks": self.n_blocks,
                "peak_blocks_in_use": a.peak_in_use,
                "blocks_in_use": a.n_in_use,
                "prefix_hit_tokens": self.manager.prefix_hit_tokens,
                "decode_kernel": self.decode_kernel}

    def run(self, max_steps: Optional[int] = None) -> list:
        """Step until every submitted request has finished."""
        out, steps = [], 0
        while not self.scheduler.idle:
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return sorted(out, key=lambda c: c.uid)


__all__ = ["generate", "Engine", "ContinuousEngine", "Request", "Completion",
           "UnsupportedCacheError"]
