"""Batched serving engine: prefill + autoregressive decode.

``generate`` is the jittable core (greedy or temperature sampling via
``lax.scan`` over decode steps); ``Engine`` wraps it with cache management
and request batching for the serve driver / examples.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def generate(model, tokens: jax.Array, cache, *, n_steps: int,
             temperature: float = 0.0, key: Optional[jax.Array] = None):
    """Prefill on ``tokens`` then decode ``n_steps`` tokens.

    Returns (generated (batch, n_steps), final cache)."""
    logits, cache = model.prefill(tokens, cache)

    def sample(logits, k):
        if temperature <= 0.0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(k, logits[:, -1] / temperature)

    if key is None:
        key = jax.random.PRNGKey(0)
    first = sample(logits, key)

    def step(carry, k):
        tok, cache = carry
        logits, cache = model.decode(tok[:, None], cache)
        nxt = sample(logits, k)
        return (nxt, cache), tok

    keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
    (last, cache), toks = jax.lax.scan(step, (first, cache), keys)
    out = jnp.concatenate([toks.T, last[:, None]], axis=1)[:, :n_steps]
    return out, cache


class Engine:
    """Fixed-slot batched serving (the production serving shape).

    One jitted prefill + one jitted decode step; requests are padded into the
    fixed batch. For the assigned decode shapes this is exactly the
    ``serve_step`` the dry-run lowers."""

    def __init__(self, model, cfg, *, batch: int, max_len: int,
                 cache_dtype=jnp.bfloat16, enc_len: Optional[int] = None):
        self.model, self.cfg = model, cfg
        self.batch, self.max_len = batch, max_len
        kwargs = {"enc_len": enc_len} if enc_len is not None else {}
        self._cache0 = model.init_cache(batch, max_len, cfg,
                                        dtype=cache_dtype, **kwargs)
        self._prefill = jax.jit(lambda toks, c: model.prefill(toks, c))
        self._decode = jax.jit(lambda tok, c: model.decode(tok, c))
        self.cache = self._cache0

    def reset(self) -> None:
        self.cache = self._cache0

    def prefill(self, tokens: jax.Array) -> jax.Array:
        logits, self.cache = self._prefill(tokens, self.cache)
        return logits

    def decode_step(self, tok: jax.Array) -> jax.Array:
        logits, self.cache = self._decode(tok, self.cache)
        return logits

    def greedy(self, tokens: jax.Array, n_steps: int) -> jax.Array:
        logits = self.prefill(tokens)
        out = [jnp.argmax(logits[:, -1], -1)]
        for _ in range(n_steps - 1):
            logits = self.decode_step(out[-1][:, None])
            out.append(jnp.argmax(logits[:, -1], -1))
        return jnp.stack(out, axis=1)
