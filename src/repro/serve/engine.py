"""Serving engines: one-shot ``generate``, the lock-step ``Engine``
baseline, and the continuous-batching ``ContinuousEngine``.

``generate`` is the jittable one-shot core (prefill + ``lax.scan`` decode);
``Engine`` keeps the fixed-slot lock-step shape (every row prefills and
decodes together — still the right tool for encdec caches and for
bit-exactness baselines).  ``ContinuousEngine`` is the serving system:
requests are admitted into recyclable slots mid-flight, each slot carrying
its own per-slot state — an attention KV lane (paged, dense, or
ring-buffer), SSM conv/ssm recurrent state, or both (hymba) — plus a
position counter and sampling params.  Prompts are
prefilled in **bucket-padded chunks interleaved with decode steps** — a
long prompt no longer freezes the running decode lanes for its whole
prefill, and a prompt whose prefix is already resident in the paged pool
starts prefilling *after* the cached blocks instead of recomputing them.
See ``repro.serve.scheduler`` for the request lifecycle,
``repro.serve.paging`` for block/prefix bookkeeping, and
``repro.serve.trace`` for workload replay.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Iterator, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.runtime import global_config
from repro.dist.sharding import (activation_mesh, cache_shardings,
                                 data_sharding, model_shardings)
from repro.nn.attention import UnsupportedCacheError
from repro.serve.paging import PagedCacheManager
from repro.serve.sampling import greedy_tokens, sample_tokens
from repro.serve.scheduler import Completion, Request, Scheduler


def generate(model, tokens: jax.Array, cache, *, n_steps: int,
             temperature: float = 0.0, key: Optional[jax.Array] = None):
    """Prefill on ``tokens`` then decode ``n_steps`` tokens.

    Returns (generated (batch, n_steps), final cache)."""
    logits, cache = model.prefill(tokens, cache)
    batch = tokens.shape[0]
    temp = jnp.full((batch,), temperature, jnp.float32)

    if key is None:
        key = jax.random.PRNGKey(0)
    first = sample_tokens(logits[:, -1], temp, key)

    def step(carry, k):
        tok, cache = carry
        logits, cache = model.decode(tok[:, None], cache)
        nxt = sample_tokens(logits[:, -1], temp, k)
        return (nxt, cache), tok

    keys = jax.random.split(jax.random.fold_in(key, 1), n_steps)
    (last, cache), toks = jax.lax.scan(step, (first, cache), keys)
    out = jnp.concatenate([toks.T, last[:, None]], axis=1)[:, :n_steps]
    return out, cache


class Engine:
    """Fixed-slot lock-step batching (the pre-continuous baseline).

    One jitted prefill + one jitted decode step; every row moves together.
    Kept for encdec cache families and as the equivalence baseline for
    ``ContinuousEngine``."""

    def __init__(self, model, cfg, *, batch: int, max_len: int,
                 cache_dtype=jnp.bfloat16, enc_len: Optional[int] = None):
        self.model, self.cfg = model, cfg
        self.batch, self.max_len = batch, max_len
        kwargs = {"enc_len": enc_len} if enc_len is not None else {}
        self._cache0 = model.init_cache(batch, max_len, cfg,
                                        dtype=cache_dtype, **kwargs)
        self._prefill = jax.jit(lambda toks, c: model.prefill(toks, c))
        self._decode = jax.jit(lambda tok, c: model.decode(tok, c))
        self.cache = self._cache0

    def reset(self) -> None:
        self.cache = self._cache0

    def prefill(self, tokens: jax.Array) -> jax.Array:
        logits, self.cache = self._prefill(tokens, self.cache)
        return logits

    def decode_step(self, tok: jax.Array) -> jax.Array:
        logits, self.cache = self._decode(tok, self.cache)
        return logits

    def greedy(self, tokens: jax.Array, n_steps: int) -> jax.Array:
        logits = self.prefill(tokens)
        out = [greedy_tokens(logits[:, -1])]
        for _ in range(n_steps - 1):
            logits = self.decode_step(out[-1][:, None])
            out.append(greedy_tokens(logits[:, -1]))
        return jnp.stack(out, axis=1)


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class _SlotArrays(NamedTuple):
    """Per-slot device state: the batched half of the request lifecycle."""

    tok: jax.Array       # (B,) int32 — last sampled token per slot
    active: jax.Array    # (B,) bool — slot holds a live request
    temp: jax.Array      # (B,) float32 — 0 => greedy
    n_gen: jax.Array     # (B,) int32 — tokens generated so far (incl. first)
    max_new: jax.Array   # (B,) int32
    stop_ids: jax.Array  # (B, K) int32, -1 padded


@dataclass
class _PrefillTask:
    """Host mirror of one in-flight chunked prefill."""

    req: Request
    slot: int
    seq: int             # admission order (chunks advance round-robin in seq)
    plen: int
    cached: int          # leading tokens resident via prefix hit (no write)
    consumed: int        # prompt positions fed so far (starts at skip point)
    hit_bids: Tuple[int, ...] = ()   # shared blocks the chunks read
    logits: Optional[jax.Array] = None  # (1, vocab) from the latest chunk
    chunks: int = 0


class ContinuousEngine:
    """Continuous-batching serving engine over a fixed slot batch.

    Requests join and leave mid-flight.  ``step()`` is a small policy
    loop::

        admit  — pop FIFO-pending requests into free slots while the block
                 reservation fits (paged); no compute happens here
        chunk  — advance in-flight prefills in a ROTATING round-robin,
                 one bucket-padded chunk at a time, spending at most
                 ``prefill_chunk_budget`` padded tokens per step (a long
                 prompt's prefill spreads over many steps; the decode
                 lanes below keep moving, and the rotation means a short
                 prompt behind a long one binds in its own step instead
                 of waiting out the whole long prefill)
        bind   — a prefill that consumed its whole prompt samples its first
                 token from the final chunk's logits and joins the decode
                 batch (this is the TTFT moment)
        decode — ONE jitted batched decode step advances every bound slot
                 at its own position; stop/max/cache-full eviction computed
                 in-graph

    **Chunked + bucketed prefill.**  A prompt is consumed ``chunk_size``
    tokens at a time; each span is right-padded to the smallest width in
    ``buckets`` that fits, so the chunk jit compiles at 2–3 widths instead
    of one ``max_prompt_len`` pad (and instead of per-prompt-length
    recompiles).  Chunk K/V rows scatter into the slot's lane (dense) or
    freshly reserved pool blocks (paged) at the chunk's position offset;
    chunk attention sees everything before it, so any chunking of a prompt
    is bit-identical to the monolithic prefill.

    **Prefix-aware admission (paged only).**  Admission asks the
    :class:`~repro.serve.paging.PagedCacheManager` for the longest cached
    block-chain matching the prompt; hit blocks are attached to the slot's
    table and prefill STARTS at the hit boundary — cached prefix compute is
    skipped, not just its memory (when the whole prompt hits, only the
    final token is recomputed to produce first-sample logits).  Freed
    prefix blocks are parked on an LRU (``prefix_retain_blocks``) so hits
    survive idle periods.  A prefill whose hit blocks were registered by a
    still-running prefill waits until the provider publishes them.

    Two KV layouts (``kv_layout``): ``"paged"`` (default) — all slots
    share one pool of ``block_size``-token KV blocks with per-slot block
    tables, reservation-based admission, refcounted prefix sharing;
    ``"dense"`` — per-slot ``max_len`` lanes, kept as the bit-exactness
    baseline.  ``decode_kernel`` (paged only) picks the decode attention:
    ``"reference"`` dense-gather or ``"pallas"`` fused
    :func:`repro.kernels.paged_attention` (interpret mode off-TPU).
    ``prefill_kernel`` (either layout, cache kind ``"kv"`` only) does the
    same for the chunked-prefill attention: ``"reference"`` dense-gather
    or ``"pallas"`` flash :func:`repro.kernels.chunk_attention`.
    Greedy tokens are bit-identical across all of it.

    **Heterogeneous per-slot state.**  The model declares its state
    family through the ``cache_kind(cfg)`` capability probe: ``"kv"``
    (global-attention transformers — both layouts above apply), ``"ring"``
    (sliding-window transformers: per-slot ring lanes, ``slot(p) = p %
    window``), ``"ssm"`` (mamba: per-slot conv/ssm recurrent state), and
    ``"hybrid"`` (hymba: ring lanes + ssm state).  Non-``"kv"`` kinds
    cannot be paged or prefix-cached — the state is either not
    position-addressable (ssm) or O(window) by construction (ring) — so
    admission degrades gracefully: the engine serves them through the
    per-slot layout regardless of ``kv_layout``, with prefix reuse
    auto-off and block reservation skipped.  Stale state from a recycled
    slot never leaks: ring masks exclude lanes the new request has not
    written, and the first prefill chunk zeros the slot's ssm lanes
    in-graph.  Because recurrent/ring state has no out-of-range "parked"
    row, the batched decode step freezes inactive slots by a slot-wise
    select over the cache instead of relying on dropped writes.  Models
    without a probe (whisper enc-dec) are rejected with a structured
    :class:`UnsupportedCacheError` naming the remaining ROADMAP item.

    **Speculative decoding** (``draft_model`` + ``spec_k``).  The paper's
    low-rank factorized model (``auto_fact``) drafts ``spec_k`` tokens
    greedily with cheap single-token steps, then the dense model verifies
    all of them in ONE multi-token decode step (k queries under a ``kpos
    <= qpos`` mask — see :meth:`repro.nn.attention.Attention.decode`) and
    the agreeing prefix plus one correction token is emitted.  Every
    emitted token is an argmax of DENSE logits conditioned on previously
    emitted tokens, so greedy output is bit-identical to the plain dense
    engine by construction — the draft quality only moves the acceptance
    rate (speed), never the tokens.  The draft keeps its own cache
    mirroring the verifier's layout (same block tables when paged); both
    length frontiers advance together by the accepted count, and rows past
    the frontier are rewritten before they can be attended.  Greedy-only:
    ``submit`` rejects ``temperature != 0`` when speculation is on.

    Streaming: ``stream()`` yields ``(uid, token, completion|None)`` as
    tokens land (``token`` is ``None`` for a request that finished a step
    without emitting one — cancellation, ``max_steps`` truncation), and
    ``on_token`` (callable ``(uid, token)``) fires inside ``step()`` for
    push-style consumers.  A raising ``on_token`` never corrupts the
    step: the error is swallowed and recorded in ``on_token_errors``.

    **Cancellation.**  ``cancel(uid)`` is thread-safe (the HTTP front
    door calls it from the asyncio event loop while ``step()`` runs in
    an executor thread) and takes effect at the start of the next
    ``step()``, which returns the ``finish_reason="cancelled"``
    :class:`Completion` like any other finish.  A pending request is
    dropped from the queue; a mid-prefill or mid-decode request releases
    its slot, parked write frontier, and every refcounted paged block.
    One wrinkle: a cancelled prefill may have registered prefix blocks
    that later admissions already hit but that its chunks never wrote —
    those dependents are *rewound* to recompute (and publish) the
    orphaned span themselves, so prefix sharing never deadlocks on a
    dead writer (see :meth:`_rewind_dependents`).

    **Priority, deadlines, preemption.**  ``submit`` takes a per-request
    ``priority`` class (0 = most urgent; default 1) and optional
    ``timeout_s``: admission serves the best non-empty class FIFO-within-
    class with a starvation bound (``aging_every`` — see
    :class:`repro.serve.scheduler`), and a request still QUEUED past its
    deadline finishes ``"cancelled"`` without ever taking a slot.  With
    ``preemption=True`` (default) a pending head that cannot be admitted
    — batch full, or its block reservation doesn't fit — evicts a
    running decode of a STRICTLY worse class: the victim's lane freezes,
    its committed blocks are registered under their prefix-chain keys
    and parked on the retention LRU, and the remainder requeues under
    the same uid as ``prompt ++ tokens`` with the leftover token budget
    — resuming later as a prefix-hit admission that recomputes only the
    partial last block.  Greedy resumed streams are bit-identical to the
    unpreempted replay (the repo-wide guarantee extends across
    preemption); the final :class:`Completion` merges all lives (full
    token stream, original ``prompt_len``, true ``first_token_at``,
    ``preemptions`` count).  Equal-priority traffic never preempts, so a
    priority-free workload is served exactly as before.  The optional
    ``prefill_budget_hook`` (see :class:`repro.serve.slo.SloBudgetAdapter`)
    is called at the top of every step and may retune
    ``prefill_chunk_budget`` against a TTFT SLO.
    """

    def __init__(self, model, cfg, *, batch: int, max_len: int,
                 max_prompt_len: int, max_stop_ids: int = 4,
                 cache_dtype=jnp.float32, seed: int = 0,
                 kv_layout: str = "paged", block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 decode_kernel: str = "reference",
                 prefill_kernel: str = "reference",
                 chunk_size: int = 32,
                 buckets: Optional[Sequence[int]] = None,
                 prefill_chunk_budget: Optional[int] = None,
                 prefix_reuse: bool = True,
                 prefix_retain_blocks: Optional[int] = None,
                 draft_model=None, spec_k: int = 0,
                 mesh=None,
                 preemption: bool = True, aging_every: int = 16,
                 prefill_budget_hook: Optional[
                     Callable[["ContinuousEngine"], Optional[int]]] = None):
        probe = getattr(model, "cache_kind", None)
        if probe is None:
            raise UnsupportedCacheError(
                f"{type(model).__name__} declares no serving cache kind; "
                "continuous batching needs per-slot state "
                "(cache_kind(cfg) capability probe)",
                roadmap_item="extend per-slot state to Whisper enc-dec "
                "caches (encoder K/V + cross-attention lanes)")
        self.cache_kind = probe(cfg)
        if self.cache_kind not in ("kv", "ring", "ssm", "hybrid"):
            raise UnsupportedCacheError(
                f"{type(model).__name__} reports unknown cache kind "
                f"{self.cache_kind!r}")
        if (draft_model is None) != (spec_k == 0):
            raise ValueError(
                "speculative decoding needs BOTH draft_model and spec_k >= 1 "
                "(or neither)")
        if spec_k < 0:
            raise ValueError("need spec_k >= 0")
        if draft_model is not None:
            dprobe = getattr(draft_model, "cache_kind", None)
            if (self.cache_kind != "kv" or dprobe is None
                    or dprobe(cfg) != "kv"):
                raise UnsupportedCacheError(
                    "speculative decoding requires the 'kv' cache kind for "
                    "both verifier and draft (multi-token verification needs "
                    "position-addressable KV lanes; ring/ssm/hybrid state "
                    "advances one token at a time)")
        self.spec_k = spec_k
        self.draft_model = draft_model
        if not 0 < max_prompt_len < max_len:
            raise ValueError("need 0 < max_prompt_len < max_len")
        if kv_layout not in ("paged", "dense"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if decode_kernel not in ("reference", "pallas"):
            raise ValueError(f"unknown decode_kernel {decode_kernel!r}")
        if decode_kernel == "pallas" and (kv_layout != "paged"
                                          or self.cache_kind != "kv"):
            raise ValueError(
                "decode_kernel='pallas' is the fused paged-attention "
                "kernel; it requires kv_layout='paged' (cache kind 'kv')")
        if prefill_kernel not in ("reference", "pallas"):
            raise ValueError(f"unknown prefill_kernel {prefill_kernel!r}")
        if prefill_kernel == "pallas" and self.cache_kind != "kv":
            # mirror the decode-kernel guard: the flash prefill-chunk
            # kernel streams a position-addressable KV prefix (paged pool
            # or dense lane); ring/ssm/hybrid per-slot state has neither
            raise UnsupportedCacheError(
                "prefill_kernel='pallas' is the flash prefill-chunk "
                "attention kernel; it requires position-addressable KV "
                "lanes (cache kind 'kv' — ring/ssm/hybrid state prefills "
                "through the reference path)",
                roadmap_item="make the kernels actually fast, and prove "
                "it compiled")
        if mesh is not None and mesh.shape.get("model", 1) > 1 \
                and (decode_kernel == "pallas" or prefill_kernel == "pallas"):
            # the fused kernels address the full kv-head dim per program;
            # under tensor parallelism each model shard holds a head slice
            # the kernels cannot see, so refuse instead of silently
            # gathering the pool onto every shard
            raise UnsupportedCacheError(
                "decode_kernel/prefill_kernel='pallas' are single-shard "
                "kernels; a mesh with model axis > 1 shards the KV heads "
                "— use the reference kernels under tensor parallelism",
                roadmap_item="make the kernels actually fast, and prove "
                "it compiled (shard-local Pallas decode/prefill under "
                "tensor parallelism)")
        if self.cache_kind != "kv":
            # ring / ssm / hybrid state cannot be paged or prefix-cached:
            # degrade gracefully to the per-slot layout (block reservation
            # skipped, prefix reuse auto-off)
            kv_layout = "dense"
        if chunk_size < 1:
            raise ValueError("need chunk_size >= 1")
        if buckets is None:
            # 2-3 compile widths: chunk_size plus halvings, so short prompts
            # and final partial chunks don't pay the full chunk pad
            buckets = sorted({max(1, chunk_size // 4),
                              max(1, chunk_size // 2), chunk_size})
        buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not buckets or buckets[0] < 1:
            raise ValueError("buckets must be positive widths")
        if buckets[-1] < chunk_size:
            raise ValueError(
                f"largest bucket {buckets[-1]} < chunk_size {chunk_size}: "
                "a full chunk would not fit any compile width")
        self.chunk_size, self.buckets = chunk_size, buckets
        self.prefill_chunk_budget = (chunk_size if prefill_chunk_budget
                                     is None else prefill_chunk_budget)
        if self.prefill_chunk_budget < 1:
            raise ValueError("need prefill_chunk_budget >= 1")
        self.decode_kernel = decode_kernel
        self.prefill_kernel = prefill_kernel
        self.model, self.cfg = model, cfg
        self.batch, self.max_len = batch, max_len
        self.max_prompt_len, self.max_stop_ids = max_prompt_len, max_stop_ids
        self.kv_layout, self.cache_dtype = kv_layout, jnp.dtype(cache_dtype)
        if not hasattr(model, "prefill_chunk"):
            raise UnsupportedCacheError(
                f"{type(model).__name__} has no chunked-prefill path; "
                "continuous batching admits prompts chunk by chunk",
                roadmap_item="extend per-slot state to Whisper enc-dec "
                "caches (encoder K/V + cross-attention lanes)")
        if kv_layout == "paged":
            if block_size < 1:
                raise ValueError("need block_size >= 1")
            self.block_size = block_size
            self.n_blocks = (batch * (-(-max_len // block_size))
                             if n_blocks is None else n_blocks)
            if not hasattr(model, "init_paged_cache"):
                raise UnsupportedCacheError(
                    f"{type(model).__name__} has no paged KV cache; the "
                    "paged layout supports attention-KV models only")
            self.cache = model.init_paged_cache(
                batch, max_len, cfg, n_blocks=self.n_blocks,
                block_size=block_size, dtype=cache_dtype)
            retain = (self.n_blocks if prefix_retain_blocks is None
                      else prefix_retain_blocks)
            self.manager = PagedCacheManager(
                n_blocks=self.n_blocks, block_size=block_size, batch=batch,
                max_len=max_len, retain_blocks=retain if prefix_reuse else 0,
                prefix_reuse=prefix_reuse)
            self._table_dirty = False
            self._park_pos = self.manager.max_table * block_size
        else:
            try:
                self.cache = model.init_cache(batch, max_len, cfg,
                                              dtype=cache_dtype,
                                              per_slot=True)
            except TypeError:
                raise UnsupportedCacheError(
                    f"{type(model).__name__} has no per-slot cache; "
                    "continuous batching needs independently advancing "
                    "slot state",
                    roadmap_item="extend per-slot state to Whisper "
                    "enc-dec caches (encoder K/V + cross-attention lanes)")
            self.manager = None
            self._park_pos = max_len
        if draft_model is not None:
            # the draft mirrors the verifier's cache layout; when paged it
            # shares the SAME block tables (one allocation drives both
            # pools), so reservation/refcount bookkeeping stays single
            if kv_layout == "paged":
                self.draft_cache = draft_model.init_paged_cache(
                    batch, max_len, cfg, n_blocks=self.n_blocks,
                    block_size=block_size, dtype=cache_dtype)
            else:
                self.draft_cache = draft_model.init_cache(
                    batch, max_len, cfg, dtype=cache_dtype, per_slot=True)
        else:
            self.draft_cache = None
        self.state = _SlotArrays(
            tok=jnp.zeros((batch,), jnp.int32),
            active=jnp.zeros((batch,), bool),
            temp=jnp.zeros((batch,), jnp.float32),
            n_gen=jnp.zeros((batch,), jnp.int32),
            max_new=jnp.ones((batch,), jnp.int32),
            stop_ids=jnp.full((batch, max_stop_ids), -1, jnp.int32),
        )
        self.mesh = mesh
        if mesh is not None:
            # Mesh-native placement, done ONCE at construction: params via
            # the Megatron specs, caches via the paged/dense cache rules
            # (paged pool global over data, kv heads over "model", block
            # tables and slot batch over "data"), slot state over "data".
            # The host-side allocator (self.manager) stays global — block
            # ids are placement-free; only the device tables shard.  The
            # jits below trace under activation_mesh and pin every
            # returned cache/state leaf back to its placement, so
            # donation keeps layouts stable step over step.
            fsdp = global_config.fsdp_params
            model = self.model = jax.device_put(
                model, model_shardings(model, mesh, fsdp=fsdp))
            self._cache_sh = cache_shardings(self.cache, mesh)
            self.cache = jax.device_put(self.cache, self._cache_sh)
            if self.draft_cache is not None:
                draft_model = self.draft_model = jax.device_put(
                    draft_model, model_shardings(draft_model, mesh,
                                                 fsdp=fsdp))
                self._draft_sh = cache_shardings(self.draft_cache, mesh)
                self.draft_cache = jax.device_put(self.draft_cache,
                                                  self._draft_sh)
            else:
                self._draft_sh = None
            self._state_sh = _SlotArrays(*(data_sharding(mesh, a.shape)
                                           for a in self.state))
            self.state = jax.device_put(self.state, self._state_sh)
        self.scheduler = Scheduler(batch, aging_every=aging_every)
        self.preemption = preemption
        self.prefill_budget_hook = prefill_budget_hook
        # uid -> earlier-lives state of a preempted request (tokens already
        # emitted, original prompt_len / first_token_at); merged into the
        # final Completion so clients see ONE request, not its lives
        self._resume_state: dict = {}
        self._preemptions = 0
        self._resumes = 0
        self._preempt_violations = 0  # lower-preempts-higher (must stay 0)
        # bind-time TTFT observations (seconds) for SLO adaptation hooks
        self.recent_ttfts: deque = deque(maxlen=256)
        self.hook_errors: deque = deque(maxlen=64)
        self._base_key = jax.random.PRNGKey(seed)
        self._tick = 0
        self._prefills: dict = {}  # slot -> _PrefillTask
        self._admit_seq = 0
        self._rr_seq = 0  # last admission seq served a chunk (rotation)
        self.on_token: Optional[Callable[[int, int], None]] = None
        # a raising on_token must not desync host/device state mid-step:
        # errors are recorded here (bounded) instead of propagating
        self.on_token_errors: deque = deque(maxlen=64)
        self._cancel_lock = threading.Lock()
        self._cancel_uids: set = set()  # uids to cancel at next step()
        self._step_events: list = []  # (uid, token) landed this step
        # prefill accounting (prefill_stats() / benchmarks); bounded like
        # scheduler.admitted so a long-lived server cannot leak step dicts
        self.step_log: deque = deque(maxlen=65536)
        self._prompt_tokens_admitted = 0
        self._prefill_tokens_computed = 0  # true prompt tokens run
        self._prefill_tokens_padded = 0    # bucket widths run (compute cost)
        self._prefix_skipped_tokens = 0    # prompt tokens never recomputed
        self._prefill_chunks = 0
        self._max_step_prefill_tokens = 0
        self._spec_rounds = 0
        self._spec_drafted = 0
        self._spec_accepted = 0

        # the prefill-kernel kwarg rides along only when non-default: the
        # kv-kind guard above means every model that can see it accepts it,
        # and ring/ssm/hybrid families keep their original prefill_chunk
        # signature untouched
        pk_kw = ({} if prefill_kernel == "reference"
                 else {"prefill_kernel": prefill_kernel})

        if draft_model is None:
            def chunk_fn(need_logits, toks, cache, slot, offset, n_valid,
                         dst=None):
                kw = {} if dst is None else {"dst": dst}
                return model.prefill_chunk(toks, cache, slot=slot,
                                           offset=offset, n_valid=n_valid,
                                           need_logits=need_logits,
                                           **pk_kw, **kw)
        else:
            # the draft prefills the same chunk into its own cache (logits
            # never needed — the verifier's final chunk seeds the first
            # sample; the draft only ever decodes)
            def chunk_fn(need_logits, toks, cache, dcache, slot, offset,
                         n_valid, dst=None):
                kw = {} if dst is None else {"dst": dst}
                logits, cache = model.prefill_chunk(
                    toks, cache, slot=slot, offset=offset, n_valid=n_valid,
                    need_logits=need_logits, **pk_kw, **kw)
                _, dcache = draft_model.prefill_chunk(
                    toks, dcache, slot=slot, offset=offset, n_valid=n_valid,
                    need_logits=False, **pk_kw, **kw)
                return logits, cache, dcache

        def bind_fn(state, slot, logits, length, temp, max_new, stop_row,
                    key):
            first = sample_tokens(logits, temp[None], key)[0]
            done0 = (jnp.any(first == stop_row) | (max_new <= 1)
                     | (length >= max_len))
            state = state._replace(
                tok=state.tok.at[slot].set(first),
                active=state.active.at[slot].set(~done0),
                temp=state.temp.at[slot].set(temp),
                n_gen=state.n_gen.at[slot].set(1),
                max_new=state.max_new.at[slot].set(max_new),
                stop_ids=state.stop_ids.at[slot].set(stop_row),
            )
            return state, first, done0

        if self.manager is not None:
            # paged decode takes the kernel knob; dense/per-slot model
            # families keep their original decode signature
            dk = self.decode_kernel

            def model_decode(tok, cache):
                return model.decode(tok, cache, decode_kernel=dk)

            def draft_decode(tok, dcache):
                return draft_model.decode(tok, dcache, decode_kernel=dk)
        else:
            model_decode = model.decode
            draft_decode = (draft_model.decode if draft_model is not None
                            else None)

        stateful = self.cache_kind != "kv"

        def decode_fn(cache, state, key):
            logits, new_cache = model_decode(state.tok[:, None], cache)
            nxt = sample_tokens(logits[:, 0], state.temp, key)
            nxt = jnp.where(state.active, nxt, state.tok)
            if stateful:
                # ring / recurrent state has no out-of-range park row the
                # scatter could drop into: freeze inactive slots (finished
                # or mid-chunked-prefill) by a slot-wise select over the
                # whole cache — every leaf carries the slot axis at dim 1
                act = state.active
                new_cache = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(
                        act.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o),
                    new_cache, cache)
                length = new_cache.length
            else:
                # frozen slots keep their cache position and token
                length = jnp.where(state.active[None, :], new_cache.length,
                                   cache.length)
                new_cache = new_cache._replace(length=length)
            n_gen = jnp.where(state.active, state.n_gen + 1, state.n_gen)
            stop_hit = jnp.any(nxt[:, None] == state.stop_ids, axis=-1)
            done = state.active & (stop_hit | (n_gen >= state.max_new)
                                   | (length[0] >= max_len))
            state = state._replace(tok=nxt, active=state.active & ~done,
                                   n_gen=n_gen)
            return new_cache, state, nxt, done

        def spec_draft_fn(dcache, vlen, state):
            """Draft ``spec_k`` greedy tokens per slot with the factorized
            model (cheap single-token steps).  The draft frontier is synced
            from the VERIFIER's length ``vlen`` at entry — the verifier's
            counter is the single source of truth for committed positions,
            so the draft cache needs no bookkeeping of its own (and the two
            caches never share a length buffer, which donation forbids).
            Inactive slots run parked: their writes drop and their drafted
            tokens are frozen to ``state.tok``."""
            dcache = dcache._replace(length=vlen)

            def body(carry, _):
                tok, dc = carry
                logits, dc = draft_decode(tok[:, None], dc)
                nxt = greedy_tokens(logits[:, 0])
                nxt = jnp.where(state.active, nxt, tok)
                return (nxt, dc), nxt

            (_, dcache), drafts = jax.lax.scan(
                body, (state.tok, dcache), None, length=spec_k)
            return dcache, drafts.T  # (B, k)

        def spec_verify_fn(cache, state, drafts):
            """Verify ``spec_k`` drafted tokens in ONE dense multi-token
            decode and commit the agreeing prefix + one correction token.

            Inputs ``X = [tok, d_1 .. d_{k-1}]`` decode at positions
            ``pos0 .. pos0+k-1``; ``g_j = argmax`` of the dense logits at
            position ``pos0+j`` is what sequential greedy would emit after
            ``X_0..X_j``, so drafts verify via ``d_{j+1} == g_j`` and the
            emitted tokens are ALWAYS ``g_0..g_{m-1}`` — dense argmaxes
            conditioned on accepted context, bit-exact to plain greedy no
            matter what the draft produced.  The frontier lands at
            ``pos0 + m``; rows past that hold unaccepted writes the next
            round rewrites before any query can attend them."""
            k = spec_k
            pos0 = cache.length[0]  # (B,) pre-decode frontier, all layers ==
            inp = jnp.concatenate([state.tok[:, None], drafts[:, :-1]],
                                  axis=1)
            logits, cache = model_decode(inp, cache)
            g = greedy_tokens(logits)  # (B, k)
            lead = jnp.cumprod((drafts == g).astype(jnp.int32), axis=1)
            n_match = lead.sum(axis=1)  # leading drafts that verified
            m0 = jnp.minimum(n_match + 1, k)  # + one correction token
            j = jnp.arange(k)
            # per-token stop conditions, mirroring decode_fn's done logic
            stop_hit = jnp.any(g[:, :, None] == state.stop_ids[:, None, :],
                               axis=-1)
            done_at = (stop_hit
                       | (state.n_gen[:, None] + j[None, :] + 1
                          >= state.max_new[:, None])
                       | (pos0[:, None] + j[None, :] + 1 >= max_len))
            d32 = done_at.astype(jnp.int32)
            prior_done = jnp.cumsum(d32, axis=1) - d32
            emit = ((j[None, :] < m0[:, None]) & (prior_done == 0)
                    & state.active[:, None])
            m = emit.sum(axis=1)  # (B,) tokens actually emitted
            done = jnp.any(done_at & emit, axis=1)
            new_tok = jnp.take_along_axis(
                g, jnp.maximum(m - 1, 0)[:, None], axis=1)[:, 0]
            new_tok = jnp.where(state.active, new_tok, state.tok)
            # frontier = pos0 + m for live slots; parked/frozen slots get
            # their pre-decode value back (the multi-token step advanced
            # every row's counter by k)
            new_len = jnp.broadcast_to(
                jnp.where(state.active, pos0 + m, pos0)[None, :],
                cache.length.shape)
            cache = cache._replace(length=new_len)
            n_gen = state.n_gen + jnp.where(state.active, m, 0)
            n_acc = jnp.where(state.active, jnp.minimum(n_match, m), 0)
            state = state._replace(tok=new_tok,
                                   active=state.active & ~done, n_gen=n_gen)
            return cache, state, g, m, n_acc, done

        if mesh is not None:
            # Wrap every jitted body: the trace runs inside activation_mesh
            # (the ContextVar is read at TRACE time, so the scope rides
            # into the compiled step no matter which thread later calls
            # it), and the returned cache/state trees are pinned to their
            # construction-time shardings — donated buffers then round-trip
            # with identical layouts and the `.sharding` of self.cache
            # stays the intended NamedSharding forever.
            cache_sh, draft_sh = self._cache_sh, self._draft_sh
            state_sh = self._state_sh

            def _pin(tree, sh):
                return jax.tree_util.tree_map(
                    jax.lax.with_sharding_constraint, tree, sh)

            inner_chunk = chunk_fn
            if draft_model is None:
                def chunk_fn(need_logits, toks, cache, *rest):
                    with activation_mesh(mesh):
                        logits, c = inner_chunk(need_logits, toks, cache,
                                                *rest)
                    return logits, _pin(c, cache_sh)
            else:
                def chunk_fn(need_logits, toks, cache, dcache, *rest):
                    with activation_mesh(mesh):
                        logits, c, dc = inner_chunk(need_logits, toks,
                                                    cache, dcache, *rest)
                    return logits, _pin(c, cache_sh), _pin(dc, draft_sh)

            inner_bind = bind_fn

            def bind_fn(state, *rest):
                st, first, done0 = inner_bind(state, *rest)
                return _pin(st, state_sh), first, done0

            inner_decode = decode_fn

            def decode_fn(cache, state, key):
                with activation_mesh(mesh):
                    c, st, nxt, done = inner_decode(cache, state, key)
                return _pin(c, cache_sh), _pin(st, state_sh), nxt, done

            if draft_model is not None:
                inner_spec_draft = spec_draft_fn

                def spec_draft_fn(dcache, vlen, state):
                    with activation_mesh(mesh):
                        dc, drafts = inner_spec_draft(dcache, vlen, state)
                    return _pin(dc, draft_sh), drafts

                inner_spec_verify = spec_verify_fn

                def spec_verify_fn(cache, state, drafts):
                    with activation_mesh(mesh):
                        c, st, g, m, n_acc, done = inner_spec_verify(
                            cache, state, drafts)
                    return (_pin(c, cache_sh), _pin(st, state_sh), g, m,
                            n_acc, done)

        # ONE jit per role; the chunk jits specialize per bucket width (the
        # buckets bound how many widths ever occur).  Mid-prompt chunks use
        # the logits-free variant — only a prompt's FINAL chunk pays the
        # final-norm + vocab-projection matmul
        chunk_donate = (1,) if draft_model is None else (1, 2)
        self._chunk_last = jax.jit(
            lambda *a: chunk_fn(True, *a), donate_argnums=chunk_donate)
        self._chunk_mid = jax.jit(
            lambda *a: chunk_fn(False, *a), donate_argnums=chunk_donate)
        self._bind = jax.jit(bind_fn, donate_argnums=(0,))
        self._decode = jax.jit(decode_fn, donate_argnums=(0, 1))
        if draft_model is not None:
            self._spec_draft = jax.jit(spec_draft_fn, donate_argnums=(0,))
            self._spec_verify = jax.jit(spec_verify_fn,
                                        donate_argnums=(0, 1))

    # -- request intake ------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: Optional[int] = None,
               temperature: float = 0.0,
               stop_ids: Sequence[int] = (), priority: int = 1,
               timeout_s: Optional[float] = None) -> int:
        """Queue one request; returns its uid (priority-class admission,
        FIFO within a class — see :class:`repro.serve.scheduler`).

        ``prompt`` is either a token-id sequence (with ``max_new_tokens``
        etc. given here) or a prebuilt :class:`Request` — both go through
        the same engine-limit validation.  ``priority`` is the admission
        class (0 = most urgent, default 1); ``timeout_s`` a deadline the
        engine enforces while the request is still QUEUED (a request that
        cannot start in time finishes ``"cancelled"``)."""
        if isinstance(prompt, Request):
            req = prompt
        else:
            if max_new_tokens is None:
                raise ValueError("max_new_tokens is required")
            req = Request(prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens,
                          temperature=temperature, stop_ids=tuple(stop_ids),
                          priority=priority, timeout_s=timeout_s)
        if req.prompt.size > self.max_prompt_len:
            raise ValueError(
                f"prompt length {req.prompt.size} > max_prompt_len "
                f"{self.max_prompt_len}")
        if len(req.stop_ids) > self.max_stop_ids:
            raise ValueError(f"more than {self.max_stop_ids} stop ids")
        if self.spec_k and req.temperature != 0.0:
            raise ValueError(
                "speculative decoding is greedy-only: the accepted-prefix "
                "argument needs deterministic argmax on both models "
                "(temperature must be 0)")
        if self.manager is not None:
            need = self.manager.blocks_needed(self._total_tokens(req))
            if need > self.n_blocks:
                raise ValueError(
                    f"request needs {need} KV blocks but the pool has only "
                    f"{self.n_blocks}; raise n_blocks or lower "
                    "max_new_tokens")
        return self.scheduler.submit(req)

    def _total_tokens(self, req: Request) -> int:
        """Worst-case cache positions a request can occupy (reservation)."""
        return min(int(req.prompt.size) + int(req.max_new_tokens),
                   self.max_len)

    def _next_key(self) -> jax.Array:
        self._tick += 1
        return jax.random.fold_in(self._base_key, self._tick)

    # -- serving loop --------------------------------------------------------

    def _next_admission(self):
        """Priority-class head-of-line admission (FIFO within a class);
        the paged layout additionally gates on the chosen head's block
        reservation fitting the free pool."""
        if self.manager is None:
            return self.scheduler.next_admission()
        return self.scheduler.next_admission(
            admissible=lambda r: self.manager.can_admit(
                r.prompt, self._total_tokens(r)))

    def _finish(self, slot: int, cache_pos: int,
                reason: Optional[str] = None) -> Completion:
        """Evict a finished slot: classify, release its KV blocks (paged),
        and hand the slot back to the scheduler.  ``reason`` overrides the
        classifier (cancellation — a cancelled request must never be
        reported as a natural ``length``/``stop`` finish, even when the
        cancel lands on the same step its limit would have)."""
        if reason is None:
            reason = self.scheduler.finish_reason(slot, cache_pos,
                                                  self.max_len)
        self._release_slot(slot)
        return self.scheduler.finish(slot, reason)

    def _release_slot(self, slot: int) -> None:
        """Return a slot's paged blocks and rewind any dependents its
        orphaned (registered-but-unwritten) prefix blocks would strand."""
        if self.manager is not None:
            orphans = self.manager.release(slot)
            self._table_dirty = True
            if orphans:
                self._rewind_dependents(orphans)

    def _flush_table(self) -> None:
        if self.manager is not None and self._table_dirty:
            self.cache = self.cache._replace(
                table=self._put_table(self.manager.tables,
                                      draft=False))
            if self.draft_cache is not None:
                # materialized separately on purpose: the two caches must
                # never share a device buffer (both are donated to jits)
                self.draft_cache = self.draft_cache._replace(
                    table=self._put_table(self.manager.tables, draft=True))
            self._table_dirty = False

    def _put_table(self, tables: np.ndarray, *, draft: bool) -> jax.Array:
        """Upload the host block tables; on a mesh the batch dim lands
        sharded over "data" so each data shard only holds its slots'
        rows."""
        if self.mesh is None:
            return jnp.asarray(tables)
        sh = (self._draft_sh if draft else self._cache_sh).table
        return jax.device_put(np.asarray(tables), sh)

    # -- cancellation --------------------------------------------------------

    def cancel(self, uid: int) -> bool:
        """Request cancellation of a submitted request.

        Thread-safe: may be called from any thread while ``step()`` runs
        (the HTTP front door calls it from the event loop on client
        disconnect and deadline expiry).  The cancel takes effect at the
        START of the next ``step()``, which returns the request's
        ``finish_reason="cancelled"`` :class:`Completion` alongside any
        natural finishes — a pending request leaves the queue, a
        prefilling or running request releases its slot, parked write
        frontier, and paged blocks.  Returns whether the uid *looked*
        live at call time (best-effort — the request may finish naturally
        before the cancel drains, in which case the cancel is a no-op);
        cancelling an unknown or finished uid is harmless."""
        with self._cancel_lock:
            self._cancel_uids.add(uid)
        try:
            state, _ = self.scheduler.find(uid)
        except RuntimeError:  # scheduler deques mutating under step()
            return True
        return state is not None

    def _drain_cancels(self) -> list:
        """Apply every cancel() recorded since the last step (host-order
        deterministic: sorted by uid)."""
        with self._cancel_lock:
            if not self._cancel_uids:
                return []
            uids, self._cancel_uids = self._cancel_uids, set()
        out = []
        for uid in sorted(uids):
            comp = self._cancel_now(uid)
            if comp is not None:
                out.append(comp)
        return out

    def _cancel_now(self, uid: int) -> Optional[Completion]:
        state, slot = self.scheduler.find(uid)
        if state == "pending":
            return self.scheduler.cancel_pending(uid)
        if state == "prefilling":
            # drop the host task; the slot's write frontier is already
            # parked out of range (since _begin_prefill), so no decode
            # write can land anywhere — just return the blocks
            del self._prefills[slot]
            self._release_slot(slot)
            return self.scheduler.cancel_prefilling(slot)
        if state == "running":
            # freeze the lane exactly like a natural in-graph finish
            # (inactive slots' tokens/positions stop advancing; paged
            # writes drop into the sentinel row once the table clears),
            # then evict with the explicit reason
            self.state = self.state._replace(
                active=self.state.active.at[slot].set(False))
            pos = int(np.asarray(self.cache.length)[0, slot])
            return self._finish(slot, pos, reason="cancelled")
        return None  # unknown uid or already finished: no-op

    def _rewind_dependents(self, orphans: Tuple[int, ...]) -> None:
        """Un-strand prefills whose prefix-hit chain includes ``orphans``
        — blocks a cancelled provider registered but never wrote.  Such a
        task would wait in ``blocks_ready`` forever; instead its hit
        boundary is rewound to the first orphan in its chain and it
        recomputes the tail of the prefix itself — writing the SAME bytes
        (the sha256 chain matched, so the tokens match and prefill is
        deterministic) and publishing the blocks for anyone behind it.

        Safe by construction: ``blocks_ready`` gates all-or-nothing, so a
        task with ANY unpublished hit block has run zero chunks — nothing
        was computed from the orphaned content, and ``consumed`` still
        sits at the admission-time skip point.  Writing a shared pending
        block here is the one sanctioned exception to the shared-blocks-
        are-immutable rule: every reader is gated until publish, and the
        rewritten content is bit-identical."""
        orphans = set(orphans)
        for task in self._prefills.values():
            idx = next((i for i, b in enumerate(task.hit_bids)
                        if b in orphans), None)
            if idx is None:
                continue
            assert task.chunks == 0, "rewind of a started prefill"
            new_cached = idx * self.block_size
            new_start = min(new_cached, task.plen - 1)
            # give back the skip accounting the rewound span claimed
            self._prefix_skipped_tokens -= task.consumed - new_start
            self.manager.prefix_hit_tokens -= task.cached - new_cached
            task.cached = new_cached
            task.consumed = new_start
            task.hit_bids = task.hit_bids[:idx]

    # -- preemption ----------------------------------------------------------

    def _maybe_preempt(self) -> None:
        """Evict running decodes so a blocked higher-priority pending head
        can start.  A victim's priority must be STRICTLY worse (larger)
        than the head's — equal-priority traffic never preempts, so a
        priority-free workload behaves exactly as before.  Victims are
        the worst-priority running slots, youngest first; prefilling
        slots are never preempted (their compute is the very thing
        preemption tries to reallocate).  Bounded by the batch size per
        step."""
        sched = self.scheduler
        for _ in range(self.batch):
            head = sched.peek_next()
            if head is None:
                return
            fits = (self.manager is None
                    or self.manager.can_admit(head.prompt,
                                              self._total_tokens(head)))
            if sched.free_slot() is not None and fits:
                return  # head is admissible as-is
            victims = [(s.request.priority, s.request.uid, slot)
                       for slot, s in enumerate(sched.slots)
                       if s is not None
                       and s.request.priority > head.priority]
            if not victims:
                return
            prio, _, slot = max(victims)
            if prio <= head.priority:
                # unreachable by construction (the filter above is strict);
                # counted defensively — the loadgen --strict gate and the
                # /metrics scrape assert this stays 0
                self._preempt_violations += 1
                return
            self._preempt_slot(slot)

    def _preempt_slot(self, slot: int) -> None:
        """Park a running decode and requeue its remainder.

        The victim's lane freezes exactly like a cancel; its cache holds
        ``prompt ++ tokens[:-1]`` (the last sampled token was still
        waiting in ``state.tok`` for the next decode).  On the paged
        layout those committed full blocks are registered under their
        chain keys and parked on the retention LRU at release, so the
        resume — a re-submission of ``prompt ++ tokens`` with the
        remaining token budget, under the SAME uid — comes back as a
        prefix hit that recomputes only the partial last block.  Greedy
        decoding is deterministic, so the resumed stream is bit-identical
        to the unpreempted replay; a sampled (temperature > 0) request
        resumes with fresh randomness."""
        self.state = self.state._replace(
            active=self.state.active.at[slot].set(False))
        pos = int(np.asarray(self.cache.length)[0, slot])
        req, tokens, first_at = self.scheduler.preempt(slot)
        k = len(tokens)
        assert pos == req.prompt.size + k - 1, \
            f"preempt pos {pos} != plen {req.prompt.size} + {k} - 1"
        if self.manager is not None:
            committed = np.concatenate(
                [req.prompt, np.asarray(tokens[:-1], np.int32)])
            self.manager.register_chain(slot, committed)
        self._release_slot(slot)
        prior = self._resume_state.get(req.uid)
        self._resume_state[req.uid] = {
            "tokens": (prior["tokens"] if prior else []) + list(tokens),
            "prompt_len": (prior["prompt_len"] if prior
                           else int(req.prompt.size)),
            "first_token_at": (prior["first_token_at"] if prior
                               else first_at),
            "preemptions": (prior["preemptions"] if prior else 0) + 1,
        }
        resume = dataclasses.replace(
            req,
            prompt=np.concatenate([req.prompt,
                                   np.asarray(tokens, np.int32)]),
            max_new_tokens=req.max_new_tokens - k)
        self.scheduler.requeue(resume)
        self._preemptions += 1

    def _merge_resume(self, comp: Completion) -> Completion:
        """Fold a resumed request's earlier lives into its final
        Completion: the client sees the original prompt_len, the full
        token stream, the true first-token time, and how many times the
        request was preempted along the way."""
        st = self._resume_state.pop(comp.uid, None)
        if st is None:
            return comp
        comp.tokens = st["tokens"] + comp.tokens
        comp.prompt_len = st["prompt_len"]
        comp.first_token_at = st["first_token_at"]
        comp.preemptions = st["preemptions"]
        return comp

    def _emit(self, uid: int, token: int) -> None:
        self._step_events.append((uid, int(token)))
        if self.on_token is not None:
            try:
                self.on_token(uid, int(token))
            except Exception as exc:
                # a consumer bug must not desync host bookkeeping from
                # device state (leaked slots/blocks, missing step_log):
                # record and keep stepping
                self.on_token_errors.append((uid, int(token), repr(exc)))

    def _bucket_width(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _begin_prefill(self, slot: int, req: Request) -> None:
        """Reserve the slot (and, paged, its blocks) for a request; chunks
        run later under the step budget."""
        plen = int(req.prompt.size)
        if self.manager is not None:
            cached, hit_bids = self.manager.admit(slot, req.prompt,
                                                  self._total_tokens(req))
            self._table_dirty = True
        else:
            cached, hit_bids = 0, ()
        # start AFTER the resident prefix — but always recompute at least
        # the final token: its logits seed the first sample
        start = min(cached, plen - 1)
        self._admit_seq += 1
        self._prefills[slot] = _PrefillTask(
            req=req, slot=slot, seq=self._admit_seq, plen=plen,
            cached=cached, consumed=start, hit_bids=hit_bids)
        self.scheduler.begin_prefill(slot, req)
        self._prompt_tokens_admitted += plen
        self._prefix_skipped_tokens += start
        # park the slot's write frontier out of range: the batched decode
        # step still scatters a K/V row for every slot, and a prefilling
        # slot's stale position could point anywhere — including, in the
        # paged layout, INSIDE a shared prefix block it just mapped
        self.cache = self.cache._replace(
            length=self.cache.length.at[:, slot].set(self._park_pos))
        if self.draft_cache is not None:
            self.draft_cache = self.draft_cache._replace(
                length=self.draft_cache.length.at[:, slot].set(
                    self._park_pos))

    def _chunk_extent(self, task: _PrefillTask) -> Tuple[int, int]:
        """(true length, padded bucket width) of the task's next chunk —
        the ONE sizing formula both the budget check and the chunk run
        consult."""
        l = min(self.chunk_size, task.plen - task.consumed)
        return l, self._bucket_width(l)

    def _run_chunk(self, task: _PrefillTask, l: int, w: int) -> int:
        """Feed one bucket-padded chunk of extent ``(l, w)`` (from
        :meth:`_chunk_extent`); returns the padded width spent."""
        toks = np.zeros((1, w), np.int32)
        toks[0, :l] = task.req.prompt[task.consumed:task.consumed + l]
        final = task.consumed + l >= task.plen
        run = self._chunk_last if final else self._chunk_mid
        caches = ((self.cache,) if self.draft_cache is None
                  else (self.cache, self.draft_cache))
        args = (self._put_host(toks), *caches,
                jnp.asarray(task.slot, jnp.int32),
                jnp.asarray(task.consumed, jnp.int32),
                jnp.asarray(l, jnp.int32))
        if self.manager is not None:
            dst = self.manager.scatter_rows(task.slot, task.consumed, w,
                                            lo=task.cached, hi=task.plen)
            out = run(*args, self._put_host(dst))
        else:
            out = run(*args)
        if self.draft_cache is None:
            logits, self.cache = out
        else:
            logits, self.cache, self.draft_cache = out
        if final:
            task.logits = logits
        task.consumed += l
        task.chunks += 1
        if self.manager is not None:
            self.manager.publish(task.slot, task.consumed)
        self._prefill_tokens_computed += l
        self._prefill_tokens_padded += w
        self._prefill_chunks += 1
        return w

    def _put_host(self, arr) -> jax.Array:
        """Upload one admitted host array.  On a mesh this commits the
        chunk onto the data axis (a single prompt's chunk has batch 1, so
        the placement resolves to replication across the data shards);
        off-mesh it is a plain transfer."""
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(np.asarray(arr),
                              data_sharding(self.mesh, np.shape(arr)))

    def _complete_prefill(self, task: _PrefillTask) -> list:
        """Sample the first token from the final chunk's logits and move
        the slot into the decode batch (possibly finishing immediately)."""
        req = task.req
        stop_row = np.full((self.max_stop_ids,), -1, np.int32)
        stop_row[:len(req.stop_ids)] = req.stop_ids
        self.state, first, done0 = self._bind(
            self.state, jnp.asarray(task.slot, jnp.int32), task.logits,
            jnp.asarray(task.plen, jnp.int32),
            jnp.asarray(req.temperature, jnp.float32),
            jnp.asarray(req.max_new_tokens, jnp.int32),
            jnp.asarray(stop_row), self._next_key())
        del self._prefills[task.slot]
        self.scheduler.bind(task.slot, req, int(first))
        if req.uid in self._resume_state:
            self._resumes += 1  # resumed life: its bind is not a real TTFT
        else:
            self.recent_ttfts.append(time.monotonic() - req.submitted_at)
        self._emit(req.uid, int(first))
        if bool(done0):
            return [self._finish(task.slot, task.plen)]
        return []

    def _advance_prefills(self) -> Tuple[list, int]:
        """Run chunks round-robin under the step budget, ROTATING the
        starting task across steps: the service order picks up after the
        last task that got a chunk, so when the per-step budget only
        covers one chunk, a short prompt admitted behind a long one still
        gets its turn on the next step instead of waiting out the long
        prompt's whole prefill (the head-of-line stall chunking exists to
        remove).  Always makes progress when any prefill is runnable — a
        budget smaller than the smallest bucket still advances one chunk
        per step.  A task whose prefix-hit blocks are still being written
        by an earlier prefill is skipped until they publish."""
        finished: list = []
        spent = 0
        progressed = True
        while self._prefills and progressed:
            progressed = False
            tasks = sorted(self._prefills.values(), key=lambda t: t.seq)
            pivot = next((i for i, t in enumerate(tasks)
                          if t.seq > self._rr_seq), 0)
            for task in tasks[pivot:] + tasks[:pivot]:
                if self.manager is not None and not \
                        self.manager.blocks_ready(task.hit_bids):
                    continue
                l, w = self._chunk_extent(task)
                if spent and spent + w > self.prefill_chunk_budget:
                    return finished, spent
                spent += self._run_chunk(task, l, w)
                self._rr_seq = task.seq
                progressed = True
                if task.consumed >= task.plen:
                    finished.extend(self._complete_prefill(task))
        return finished, spent

    def step(self) -> list:
        """One scheduling round: apply cancels, admit, chunk prefills
        under the budget, bind finished prefills, then one batched decode
        step.  Returns the :class:`Completion`s finished this step
        (cancelled ones included)."""
        t0 = time.monotonic()
        self._step_events = []
        if self.prefill_budget_hook is not None:
            try:
                budget = self.prefill_budget_hook(self)
                if budget is not None:
                    self.prefill_chunk_budget = max(1, int(budget))
            except Exception as exc:
                # an operator hook bug must not take serving down
                self.hook_errors.append(repr(exc))
        finished = self._drain_cancels()
        finished.extend(self.scheduler.expire_pending())
        if self.preemption:
            self._maybe_preempt()
        while (adm := self._next_admission()) is not None:
            self._begin_prefill(*adm)
        prefill_spent = 0
        if self._prefills:
            self._flush_table()
            done, prefill_spent = self._advance_prefills()
            finished.extend(done)
            self._max_step_prefill_tokens = max(
                self._max_step_prefill_tokens, prefill_spent)

        running = self.scheduler.running_slots()
        if running and self.spec_k:
            self._flush_table()
            self.draft_cache, drafts = self._spec_draft(
                self.draft_cache, self.cache.length, self.state)
            self.cache, self.state, g, m, n_acc, done = self._spec_verify(
                self.cache, self.state, drafts)
            g_np, m_np = np.asarray(g), np.asarray(m)
            done_np = np.asarray(done)
            pos_np = np.asarray(self.cache.length[0])
            self._spec_rounds += 1
            self._spec_drafted += self.spec_k * len(running)
            self._spec_accepted += int(np.asarray(n_acc).sum())
            for slot in running:
                uid = self.scheduler.slots[slot].request.uid
                for tok in g_np[slot, :m_np[slot]]:
                    self.scheduler.append_token(slot, tok)
                    self._emit(uid, tok)
                if done_np[slot]:
                    finished.append(self._finish(slot, int(pos_np[slot])))
        elif running:
            self._flush_table()
            self.cache, self.state, nxt, done = self._decode(
                self.cache, self.state, self._next_key())
            nxt_np, done_np = np.asarray(nxt), np.asarray(done)
            pos_np = np.asarray(self.cache.length[0])
            for slot in running:
                self.scheduler.append_token(slot, nxt_np[slot])
                self._emit(self.scheduler.slots[slot].request.uid,
                           nxt_np[slot])
                if done_np[slot]:
                    finished.append(self._finish(slot, int(pos_np[slot])))
        self.step_log.append({
            "wall_s": time.monotonic() - t0,
            "prefill_tokens": prefill_spent,
            "decoded": bool(running),
        })
        return [self._merge_resume(c) for c in finished]

    # -- introspection -------------------------------------------------------

    @property
    def step_events(self) -> Tuple[Tuple[int, int], ...]:
        """``(uid, token)`` pairs emitted by the most recent ``step()`` —
        the pull half of streaming for drivers that call ``step()``
        directly (the HTTP pump) instead of iterating ``stream()``."""
        return tuple(self._step_events)

    def kv_stats(self) -> dict:
        """HBM accounting for the KV cache (bytes, both layouts).

        ``kv_allocated_bytes`` is what the layout reserves up front;
        ``kv_peak_resident_bytes`` is the high-water mark of bytes holding
        live tokens — for the dense layout the two coincide (every slot
        pins a ``max_len`` lane), for the paged layout the peak tracks
        blocks actually in use, which is what a right-sized pool would
        need.  Parked (LRU-retained) prefix blocks are reclaimable warm
        capacity and excluded from the in-use numbers.  For the stateful
        kinds (ring / ssm / hybrid) the accounting covers every state
        leaf (KV lanes + conv/ssm buffers), and ``kv_lane_tokens``
        reports the per-slot lane length — ``window`` for ring lanes (the
        O(window)-not-O(max_len) bound the benchmark asserts), absent for
        pure-SSM state.

        With speculative decoding on, the draft model's mirror cache is
        real HBM too: ``draft_kv_allocated_bytes`` splits it out and
        every aggregate number includes it.  In the paged layout the
        draft shares the verifier's block tables, so one block 'in use'
        pins rows in BOTH pools — per-block bytes cover the two pools
        together."""

        def _leaf_bytes(cache):
            return sum(a.size * a.dtype.itemsize
                       for f, a in zip(cache._fields, cache)
                       if f not in ("length", "table"))

        if self.manager is None:
            leaves = {f: a for f, a in zip(self.cache._fields, self.cache)
                      if f not in ("length", "table")}
            alloc = sum(a.size * a.dtype.itemsize for a in leaves.values())
            stats = {"kv_layout": self.kv_layout,
                     "cache_kind": self.cache_kind,
                     "kv_allocated_bytes": alloc,
                     "kv_peak_resident_bytes": alloc}
            if self.draft_cache is not None:
                dalloc = _leaf_bytes(self.draft_cache)
                stats["draft_kv_allocated_bytes"] = dalloc
                stats["kv_allocated_bytes"] += dalloc
                stats["kv_peak_resident_bytes"] += dalloc
            if "k" in leaves:  # per-slot KV lanes (dense or ring)
                k = leaves["k"]
                stats["kv_lane_tokens"] = k.shape[2]
                if self.cache_kind in ("ring", "hybrid"):
                    stats["kv_ring_bytes"] = 2 * k.size * k.dtype.itemsize
            return stats
        alloc = 2 * self.cache.k.size * self.cache.k.dtype.itemsize
        block_bytes = 2 * (self.cache.k.size // self.n_blocks
                           ) * self.cache.k.dtype.itemsize
        stats = {"kv_layout": "paged", "cache_kind": self.cache_kind}
        if self.draft_cache is not None:
            dalloc = (2 * self.draft_cache.k.size
                      * self.draft_cache.k.dtype.itemsize)
            stats["draft_kv_allocated_bytes"] = dalloc
            alloc += dalloc
            block_bytes += 2 * (self.draft_cache.k.size // self.n_blocks
                                ) * self.draft_cache.k.dtype.itemsize
        a = self.manager.allocator
        stats.update({
            "kv_allocated_bytes": alloc,
            "kv_peak_resident_bytes": a.peak_in_use * block_bytes,
            "kv_block_bytes": block_bytes,
            "block_size": self.block_size, "n_blocks": self.n_blocks,
            "peak_blocks_in_use": a.peak_in_use,
            "blocks_in_use": a.n_in_use,
            "blocks_retained": len(self.manager.retained),
            "prefix_hit_tokens": self.manager.prefix_hit_tokens,
            "decode_kernel": self.decode_kernel,
            "prefill_kernel": self.prefill_kernel})
        return stats

    def prefill_stats(self) -> dict:
        """Admission-path accounting: how much prompt compute actually ran
        (vs was skipped via prefix hits) and how bursty it was per step."""
        admitted = self._prompt_tokens_admitted
        return {
            "chunk_size": self.chunk_size,
            "buckets": list(self.buckets),
            "prefill_chunk_budget": self.prefill_chunk_budget,
            "prompt_tokens_admitted": admitted,
            "prefill_tokens_computed": self._prefill_tokens_computed,
            "prefill_tokens_padded": self._prefill_tokens_padded,
            "prefix_skipped_tokens": self._prefix_skipped_tokens,
            "prefix_hit_rate": (self._prefix_skipped_tokens / admitted
                                if admitted else 0.0),
            "prefill_chunks": self._prefill_chunks,
            "max_step_prefill_tokens": self._max_step_prefill_tokens,
            "prefill_kernel": self.prefill_kernel,
        }

    def spec_stats(self) -> dict:
        """Speculative-decoding accounting.  ``spec_acceptance_rate`` =
        accepted drafted tokens / drafted tokens; the correction token each
        round emits on top of the accepted prefix is not a draft and counts
        in neither number (so rate 1.0 means every draft verified and each
        round advanced ``spec_k`` tokens per slot)."""
        drafted = self._spec_drafted
        return {
            "spec_k": self.spec_k,
            "spec_rounds": self._spec_rounds,
            "spec_drafted_tokens": drafted,
            "spec_accepted_tokens": self._spec_accepted,
            "spec_acceptance_rate": (self._spec_accepted / drafted
                                     if drafted else 0.0),
        }

    def preempt_stats(self) -> dict:
        """Preemption accounting.  ``preempt_violations`` counts evictions
        where the victim did not outrank the preemptor's class — the
        policy guarantees 0 and the loadgen/CI gates assert it;
        ``preempted_in_flight`` is how many preempted requests currently
        await (or are mid-) resume."""
        return {
            "preemption": self.preemption,
            "preemptions": self._preemptions,
            "resumes": self._resumes,
            "preempt_violations": self._preempt_violations,
            "preempted_in_flight": len(self._resume_state),
        }

    def reset_stats(self) -> None:
        """Zero the prefill/step accounting (e.g. after a compile warmup)
        without touching the serving state.  The KV peak rebases to the
        blocks currently in use, so ``kv_peak_resident_bytes`` reflects the
        profiled traffic, not the warmup's high-water mark."""
        self.step_log = deque(maxlen=65536)
        self._preemptions = 0
        self._resumes = 0
        self._preempt_violations = 0
        self.recent_ttfts.clear()
        self._prompt_tokens_admitted = 0
        self._prefill_tokens_computed = 0
        self._prefill_tokens_padded = 0
        self._prefix_skipped_tokens = 0
        self._prefill_chunks = 0
        self._max_step_prefill_tokens = 0
        self._spec_rounds = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        if self.manager is not None:
            self.manager.prefix_hit_tokens = 0
            a = self.manager.allocator
            a.peak_in_use = a.n_in_use

    # -- drivers -------------------------------------------------------------

    def run(self, max_steps: Optional[int] = None) -> list:
        """Step until every submitted request has finished."""
        out, steps = [], 0
        while not self.scheduler.idle:
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return sorted(out, key=lambda c: c.uid)

    def stream(self, max_steps: Optional[int] = None,
               on_step: Optional[Callable[["ContinuousEngine"], None]] = None
               ) -> Iterator[Tuple[int, int, Optional[Completion]]]:
        """Drive the engine and yield ``(uid, token, completion)`` as
        tokens land — ``completion`` rides with a request's LAST token (and
        is ``None`` before that).  A request that finishes a step WITHOUT
        emitting a token — cancelled, or cut off by ``max_steps`` — still
        surfaces: its completion is yielded as ``(uid, None, completion)``
        after the step's token events, so no Completion is ever silently
        dropped.  Submit more requests between yields, or from ``on_step``
        (called after EVERY engine step) — a step may yield no token at
        all while prompts are mid-chunked-prefill, so a driver feeding
        timed arrivals must use the hook, not the yield points, or a long
        prefill starves the queue.  The stream drains when the scheduler
        goes idle."""
        steps = 0
        while not self.scheduler.idle:
            done = {c.uid: c for c in self.step()}
            events = self._step_events
            if on_step is not None:
                on_step(self)
            last = {uid: i for i, (uid, _) in enumerate(events)}
            for i, (uid, tok) in enumerate(events):
                comp = done.pop(uid, None) if last[uid] == i else None
                yield uid, tok, comp
            for uid, comp in done.items():  # completion-only events
                yield uid, None, comp
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break


__all__ = ["generate", "Engine", "ContinuousEngine", "Request", "Completion",
           "UnsupportedCacheError"]
