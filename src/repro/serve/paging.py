"""Paged KV-cache management: block allocator, prefix cache, block tables.

Host-side bookkeeping for the paged KV layout (pure Python/numpy — no jax
here, mirroring the engine/scheduler split).  The device side is a shared
pool of ``n_blocks`` fixed-size KV blocks per layer
(:class:`repro.nn.attention.PagedKVCache`); this module decides which pool
blocks each request owns:

* :class:`BlockAllocator` — free-list + per-block refcounts.  ``alloc``
  hands out an exclusively-owned block, ``fork`` adds a reader to a shared
  block, ``free`` drops one reference and returns the block to the free
  list when the count hits zero — unless the caller asks for
  ``recycle=False``, which *parks* the block instead: refcount zero, off
  the free list, content preserved.  ``adopt`` revives a parked block as
  exclusively owned again; ``reclaim`` pushes it onto the free list.
* :class:`PrefixCache` — hash-chained keys over *full* prompt blocks
  (``key_i = sha256(key_{i-1} || tokens[i*bs:(i+1)*bs])``) mapped to pool
  block ids, so requests sharing a system prompt reuse the same physical
  prefill blocks.  Only immutable full blocks are ever shared: a prompt's
  partial last block and all decode-time blocks are freshly allocated, so
  a cache hit can never alias a block that a live writer mutates
  (copy-on-extend by construction — extension always lands in a fresh
  block at a block boundary, no copy needed).
* :class:`PagedCacheManager` — ties both to per-slot block tables
  (``(batch, max_blocks_per_seq)`` int32, device sentinel ``n_blocks`` for
  unmapped entries so stale scatters drop and stale gathers clip into
  masked lanes) and to admission: a request reserves
  ``ceil(min(prompt_len + max_new, max_len) / block_size)`` blocks up
  front (minus prefix hits), so decode can never run out of blocks
  mid-request and FIFO admission defers — never skips — when the pool is
  exhausted.  Capacity is checked BEFORE any state mutates, so a refused
  admission leaves the allocator, tables, and prefix cache untouched.

Two chunked-prefill-era responsibilities live here as well:

* **Compute-aware prefix hits.**  ``admit`` returns how many leading
  prompt tokens are already *resident* in shared blocks; the engine then
  starts chunked prefill at that offset instead of recomputing the prefix
  (the pre-chunking engine shared the memory but re-ran the compute).
  Because shared blocks are registered at admission but only *written* as
  the owning prefill progresses, each admission also reports which hit
  blocks it depends on; ``blocks_ready`` gates a dependent prefill until
  its provider's chunks have covered them (``publish``), so a same-step
  prefix hit can never read a block before it holds real K/V.
* **LRU retention of freed prefix blocks.**  When the last reference to a
  prefix block drops, the block is parked on an LRU list (up to
  ``retain_blocks``) instead of recycled, keeping its K/V warm so a hit
  can survive an idle period with no live requests.  A new hit adopts the
  parked block (moving it back to refcounted life); pool pressure
  reclaims from the LRU tail, evicting the prefix entry with it.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Set, Tuple

import numpy as np


def chain_keys(tokens: np.ndarray, block_size: int) -> List[bytes]:
    """Hash-chained prefix keys, one per *full* block of ``tokens``.

    ``keys[i]`` commits to tokens ``[0, (i+1)*block_size)``, so equal keys
    imply equal full prefixes and a block is only ever hit together with
    every block before it."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    keys, h = [], b""
    for i in range(len(tokens) // block_size):
        h = hashlib.sha256(
            h + tokens[i * block_size:(i + 1) * block_size].tobytes()).digest()
        keys.append(h)
    return keys


class BlockAllocator:
    """Refcounted free-list over a fixed pool of KV blocks."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1 or block_size < 1:
            raise ValueError("need n_blocks >= 1 and block_size >= 1")
        self.n_blocks, self.block_size = n_blocks, block_size
        self._free = list(range(n_blocks - 1, -1, -1))  # stack; pops 0,1,2,..
        self._parked: Set[int] = set()  # refcount 0, off the free list
        self.refcount = np.zeros(n_blocks, np.int32)
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_parked(self) -> int:
        return len(self._parked)

    @property
    def n_in_use(self) -> int:
        """Blocks some live request references (parked blocks excluded —
        they hold reclaimable warm content, not live tokens)."""
        return self.n_blocks - len(self._free) - len(self._parked)

    def alloc(self) -> int:
        """Take an exclusively-owned block (refcount 1) off the free list."""
        if not self._free:
            raise RuntimeError("out of KV blocks")
        bid = self._free.pop()
        self.refcount[bid] = 1
        self.peak_in_use = max(self.peak_in_use, self.n_in_use)
        return bid

    def fork(self, bid: int) -> None:
        """Add a reader to a live block (prefix sharing)."""
        if self.refcount[bid] <= 0:
            raise RuntimeError(f"fork of free block {bid}")
        self.refcount[bid] += 1

    def free(self, bid: int, *, recycle: bool = True) -> int:
        """Drop one reference; returns the remaining count.  At zero the
        block is recycled onto the free list, or — with ``recycle=False``
        — parked: content preserved, eligible for ``adopt``/``reclaim``."""
        if self.refcount[bid] <= 0:
            raise RuntimeError(f"double free of block {bid}")
        self.refcount[bid] -= 1
        rc = int(self.refcount[bid])
        if rc == 0:
            if recycle:
                self._free.append(bid)
            else:
                self._parked.add(bid)
        return rc

    def adopt(self, bid: int) -> None:
        """Revive a parked block as exclusively owned (prefix-hit on a
        retained block)."""
        if bid not in self._parked:
            raise RuntimeError(f"adopt of non-parked block {bid}")
        self._parked.discard(bid)
        self.refcount[bid] = 1
        self.peak_in_use = max(self.peak_in_use, self.n_in_use)

    def reclaim(self, bid: int) -> None:
        """Push a parked block onto the free list (LRU eviction)."""
        if bid not in self._parked:
            raise RuntimeError(f"reclaim of non-parked block {bid}")
        self._parked.discard(bid)
        self._free.append(bid)


class PrefixCache:
    """chain-key -> block id map with reverse lookup for eviction."""

    def __init__(self):
        self._by_key: Dict[bytes, int] = {}
        self._by_block: Dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def get(self, key: bytes) -> Optional[int]:
        return self._by_key.get(key)

    def put(self, key: bytes, bid: int) -> None:
        """Callers must evict any previous holder of ``key`` first (see
        the chain-broken-duplicate handling in ``PagedCacheManager.admit``
        — the one place that can re-register a live key)."""
        assert self._by_key.get(key) in (None, bid), "key already held"
        self._by_key[key] = bid
        self._by_block[bid] = key

    def has_block(self, bid: int) -> bool:
        return bid in self._by_block

    def drop_block(self, bid: int) -> None:
        """Evict the entry for a block returning to the free list."""
        key = self._by_block.pop(bid, None)
        if key is not None:
            del self._by_key[key]


class PagedCacheManager:
    """Block tables + reservation-based admission over one allocator.

    Owns the host mirror of the per-slot block tables the jitted decode
    gathers through; the engine re-uploads it whenever a slot is admitted
    or released.  ``retain_blocks`` bounds the LRU of parked prefix blocks
    (0 disables retention: freed prefix blocks recycle immediately, the
    pre-retention behaviour); ``prefix_reuse=False`` disables prefix
    sharing entirely — every admission allocates and computes its whole
    prompt (the baseline the prefix-skip benchmark compares against)."""

    def __init__(self, *, n_blocks: int, block_size: int, batch: int,
                 max_len: int, retain_blocks: int = 0,
                 prefix_reuse: bool = True):
        if retain_blocks < 0:
            raise ValueError("need retain_blocks >= 0")
        self.allocator = BlockAllocator(n_blocks, block_size)
        self.prefix = PrefixCache()
        self.block_size = block_size
        self.max_table = -(-max_len // block_size)
        self.sentinel = n_blocks  # out-of-range block id => unmapped
        self.tables = np.full((batch, self.max_table), self.sentinel,
                              np.int32)
        self._owned: Dict[int, List[int]] = {}  # slot -> owned block ids
        self.prefix_reuse = prefix_reuse
        self.retain_blocks = retain_blocks
        self.retained: "OrderedDict[int, None]" = OrderedDict()  # LRU parked
        self._pending: Set[int] = set()  # registered but not yet written
        self.prefix_hit_tokens = 0  # prompt tokens served from shared blocks

    def blocks_needed(self, total_tokens: int) -> int:
        return -(-total_tokens // self.block_size)

    def _plan(self, prompt: np.ndarray, total_tokens: int
              ) -> Tuple[List[bytes], List[int], int]:
        """(chain keys over full prompt blocks, longest-cached-chain block
        ids, #blocks the reservation needs)."""
        keys = chain_keys(prompt, self.block_size)
        hit_bids: List[int] = []
        if self.prefix_reuse:
            for k in keys:
                bid = self.prefix.get(k)
                if bid is None:
                    break
                hit_bids.append(bid)
        return keys, hit_bids, self.blocks_needed(total_tokens)

    def _fits(self, hit_bids: List[int], n_need: int) -> bool:
        """The ONE capacity formula both can_admit and admit consult:
        fresh blocks needed vs free list + parked blocks this admission
        would not itself hit (those are reclaimable supply)."""
        hits = set(hit_bids)
        reclaimable = sum(1 for b in self.retained if b not in hits)
        return n_need - len(hit_bids) <= self.allocator.n_free + reclaimable

    def can_admit(self, prompt: np.ndarray, total_tokens: int) -> bool:
        _, hit_bids, n_need = self._plan(prompt, total_tokens)
        return self._fits(hit_bids, n_need)

    def _alloc(self) -> int:
        """Allocate a fresh block, reclaiming the LRU-parked prefix block
        when the free list runs dry (hits were adopted first, so the LRU
        can never evict a block the in-flight admission depends on)."""
        if not self.allocator.n_free and self.retained:
            bid, _ = self.retained.popitem(last=False)
            self.prefix.drop_block(bid)
            self.allocator.reclaim(bid)
        return self.allocator.alloc()

    def admit(self, slot: int, prompt: np.ndarray,
              total_tokens: int) -> Tuple[int, Tuple[int, ...]]:
        """Reserve blocks for one request and map them into ``slot``.

        Returns ``(n_cached_tokens, hit_bids)``: the number of leading
        prompt tokens already RESIDENT in shared blocks — the engine starts
        chunked prefill after them (recomputing at most the prompt's final
        token when the whole prompt hits, since something must produce the
        first-sample logits) — and the hit block ids the prefill depends
        on, to be polled through :meth:`blocks_ready` before the slot's
        first chunk may run (a same-step provider may not have written
        them yet).  Capacity is validated before any mutation: a raising
        ``admit`` leaves every structure untouched."""
        assert slot not in self._owned, f"slot {slot} already mapped"
        keys, plan_hits, n_need = self._plan(prompt, total_tokens)
        hit_bids = tuple(plan_hits)
        n_hit = len(hit_bids)
        if not self._fits(plan_hits, n_need):
            raise RuntimeError("admit() without free blocks; call can_admit")
        blocks = []
        for bid in hit_bids:
            if bid in self.retained:  # revive a warm parked block
                del self.retained[bid]
                self.allocator.adopt(bid)
            else:
                self.allocator.fork(bid)
            blocks.append(bid)
        blocks += [self._alloc() for _ in range(n_need - n_hit)]
        if self.prefix_reuse:
            # freshly-allocated full prompt blocks become hittable for later
            # requests the moment they are registered; they stay `pending`
            # (gating dependents via blocks_ready) until the owning prefill
            # publishes the positions that fill them
            for i in range(n_hit, len(keys)):
                old = self.prefix.get(keys[i])
                if old is not None and old != blocks[i]:
                    # chain-broken duplicate: an earlier eviction removed a
                    # key BELOW this one, so the old holder can never be hit
                    # again (hits walk the chain from key 0).  Re-registering
                    # steals the key; a parked holder is dead weight and is
                    # reclaimed outright, a live holder just loses its entry
                    self.prefix.drop_block(old)
                    if old in self.retained:
                        del self.retained[old]
                        self.allocator.reclaim(old)
                self.prefix.put(keys[i], blocks[i])
                self._pending.add(blocks[i])
        self.tables[slot] = self.sentinel
        self.tables[slot, :n_need] = blocks
        self._owned[slot] = blocks
        cached = n_hit * self.block_size
        self.prefix_hit_tokens += cached
        return cached, hit_bids

    # -- chunked-prefill support ---------------------------------------------

    def scatter_rows(self, slot: int, start: int, width: int, *,
                     lo: int, hi: int) -> np.ndarray:
        """Flat pool rows for chunk positions ``[start, start + width)``.

        Positions outside ``[lo, hi)`` — bucket padding past the prompt and
        cached-prefix positions below the write floor — are pointed at the
        out-of-range sentinel row so the jitted ``mode='drop'`` scatter
        skips them (shared blocks are never written, even with identical
        bytes)."""
        p = np.arange(start, start + width)
        bs = self.block_size
        rows = np.full((width,), self.sentinel * bs, np.int32)
        w = (p >= lo) & (p < hi)
        if w.any():
            blocks = np.asarray(self._owned[slot], np.int32)
            rows[w] = blocks[p[w] // bs] * bs + p[w] % bs
        return rows

    def publish(self, slot: int, upto: int) -> None:
        """Mark ``slot``'s registered prefix blocks fully covered by
        prefill positions ``[0, upto)`` as written — dependents waiting in
        :meth:`blocks_ready` may now read them."""
        bs = self.block_size
        for i, bid in enumerate(self._owned.get(slot, ())):
            if (i + 1) * bs > upto:
                break
            self._pending.discard(bid)

    def blocks_ready(self, bids) -> bool:
        """True once every hit block holds real K/V (its provider's prefill
        chunks have covered it)."""
        return all(b not in self._pending for b in bids)

    def register_chain(self, slot: int, committed: np.ndarray) -> int:
        """Register ``slot``'s blocks holding ``committed`` (the tokens its
        cache rows actually contain — prompt plus generated-so-far) under
        their chain keys, so they become prefix-hittable.  Preemption
        calls this right before ``release``: the victim's full blocks park
        on the retention LRU and its resume re-admission hits them,
        recomputing nothing already written.

        Only FULL blocks are keyed (the partial last block is recomputed
        on resume, like any prompt tail), and every keyed block is fully
        written — so nothing here joins ``_pending``.  A key already held
        by another block is left alone: that holder has identical content
        (equal chain keys imply equal prefixes), so the resume hits it
        instead.  Returns the number of newly registered blocks."""
        if not self.prefix_reuse:
            return 0
        keys = chain_keys(committed, self.block_size)
        blocks = self._owned[slot]
        added = 0
        for i, key in enumerate(keys[:len(blocks)]):
            bid = blocks[i]
            held = self.prefix.get(key)
            if held is not None:
                continue  # ours (no-op) or an equal-content block: hittable
            if self.prefix.has_block(bid):
                self.prefix.drop_block(bid)  # stale key from a prior life
            self.prefix.put(key, bid)
            added += 1
        return added

    # -- release --------------------------------------------------------------

    def release(self, slot: int) -> Tuple[int, ...]:
        """Return a finished slot's references.  A prefix block whose last
        reference drops is parked on the retention LRU (content kept warm
        for future hits) while the budget allows; everything else — and the
        LRU overflow — recycles to the free list, evicting dead prefix
        entries.

        Returns the slot's **orphaned pending blocks**: blocks this slot
        registered but never wrote (still ``_pending``) that other slots
        still reference.  For a normally-finished slot this is always
        empty (a slot binds only after publishing every registered block),
        but a prefill **cancelled** mid-flight can strand dependents that
        forked its registered-but-unwritten blocks — if nothing rewinds
        them, ``blocks_ready`` never turns true and they wait forever.
        The engine hands orphans to the waiting tasks, which adopt the
        writer role (the prefix tokens are identical, so the rewritten
        bytes are too)."""
        orphans = []
        for bid in self._owned.pop(slot):
            retain = (self.retain_blocks > 0
                      and self.allocator.refcount[bid] == 1
                      and self.prefix.has_block(bid)
                      and bid not in self._pending)
            if retain:
                self.allocator.free(bid, recycle=False)
                self.retained[bid] = None
                self.retained.move_to_end(bid)
                while len(self.retained) > self.retain_blocks:
                    old, _ = self.retained.popitem(last=False)
                    self.prefix.drop_block(old)
                    self.allocator.reclaim(old)
            elif self.allocator.free(bid) == 0:
                self.prefix.drop_block(bid)
                self._pending.discard(bid)
            elif bid in self._pending:
                orphans.append(bid)
        self.tables[slot] = self.sentinel
        return tuple(orphans)

    @property
    def fully_free(self) -> bool:
        """No live request references any block (parked warm blocks are
        reclaimable on demand, so they count as free capacity)."""
        return self.allocator.n_in_use == 0


__all__ = ["BlockAllocator", "PagedCacheManager", "PrefixCache",
           "chain_keys"]
