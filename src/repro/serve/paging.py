"""Paged KV-cache management: block allocator, prefix cache, block tables.

Host-side bookkeeping for the paged KV layout (pure Python/numpy — no jax
here, mirroring the engine/scheduler split).  The device side is a shared
pool of ``n_blocks`` fixed-size KV blocks per layer
(:class:`repro.nn.attention.PagedKVCache`); this module decides which pool
blocks each request owns:

* :class:`BlockAllocator` — free-list + per-block refcounts.  ``alloc``
  hands out an exclusively-owned block, ``fork`` adds a reader to a shared
  block, ``free`` drops one reference and returns the block to the free
  list when the count hits zero.
* :class:`PrefixCache` — hash-chained keys over *full* prompt blocks
  (``key_i = sha256(key_{i-1} || tokens[i*bs:(i+1)*bs])``) mapped to pool
  block ids, so requests sharing a system prompt reuse the same physical
  prefill blocks.  Only immutable full blocks are ever shared: a prompt's
  partial last block and all decode-time blocks are freshly allocated, so
  a cache hit can never alias a block that a live writer mutates
  (copy-on-extend by construction — extension always lands in a fresh
  block at a block boundary, no copy needed).  Entries are evicted the
  moment their block's refcount reaches zero; keeping freed blocks warm
  under an LRU budget is a ROADMAP follow-on.
* :class:`PagedCacheManager` — ties both to per-slot block tables
  (``(batch, max_blocks_per_seq)`` int32, device sentinel ``n_blocks`` for
  unmapped entries so stale scatters drop and stale gathers clip into
  masked lanes) and to admission: a request reserves
  ``ceil(min(prompt_len + max_new, max_len) / block_size)`` blocks up
  front (minus prefix hits), so decode can never run out of blocks
  mid-request and FIFO admission defers — never skips — when the pool is
  exhausted.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np


def chain_keys(tokens: np.ndarray, block_size: int) -> List[bytes]:
    """Hash-chained prefix keys, one per *full* block of ``tokens``.

    ``keys[i]`` commits to tokens ``[0, (i+1)*block_size)``, so equal keys
    imply equal full prefixes and a block is only ever hit together with
    every block before it."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    keys, h = [], b""
    for i in range(len(tokens) // block_size):
        h = hashlib.sha256(
            h + tokens[i * block_size:(i + 1) * block_size].tobytes()).digest()
        keys.append(h)
    return keys


class BlockAllocator:
    """Refcounted free-list over a fixed pool of KV blocks."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1 or block_size < 1:
            raise ValueError("need n_blocks >= 1 and block_size >= 1")
        self.n_blocks, self.block_size = n_blocks, block_size
        self._free = list(range(n_blocks - 1, -1, -1))  # stack; pops 0,1,2,..
        self.refcount = np.zeros(n_blocks, np.int32)
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def alloc(self) -> int:
        """Take an exclusively-owned block (refcount 1) off the free list."""
        if not self._free:
            raise RuntimeError("out of KV blocks")
        bid = self._free.pop()
        self.refcount[bid] = 1
        self.peak_in_use = max(self.peak_in_use, self.n_in_use)
        return bid

    def fork(self, bid: int) -> None:
        """Add a reader to a live block (prefix sharing)."""
        if self.refcount[bid] <= 0:
            raise RuntimeError(f"fork of free block {bid}")
        self.refcount[bid] += 1

    def free(self, bid: int) -> int:
        """Drop one reference; returns the remaining count (0 => recycled)."""
        if self.refcount[bid] <= 0:
            raise RuntimeError(f"double free of block {bid}")
        self.refcount[bid] -= 1
        rc = int(self.refcount[bid])
        if rc == 0:
            self._free.append(bid)
        return rc


class PrefixCache:
    """chain-key -> block id map with reverse lookup for eviction."""

    def __init__(self):
        self._by_key: Dict[bytes, int] = {}
        self._by_block: Dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def get(self, key: bytes) -> Optional[int]:
        return self._by_key.get(key)

    def put(self, key: bytes, bid: int) -> None:
        self._by_key[key] = bid
        self._by_block[bid] = key

    def drop_block(self, bid: int) -> None:
        """Evict the entry for a block returning to the free list."""
        key = self._by_block.pop(bid, None)
        if key is not None:
            del self._by_key[key]


class PagedCacheManager:
    """Block tables + reservation-based admission over one allocator.

    Owns the host mirror of the per-slot block tables the jitted decode
    gathers through; the engine re-uploads it whenever a slot is admitted
    or released."""

    def __init__(self, *, n_blocks: int, block_size: int, batch: int,
                 max_len: int):
        self.allocator = BlockAllocator(n_blocks, block_size)
        self.prefix = PrefixCache()
        self.block_size = block_size
        self.max_table = -(-max_len // block_size)
        self.sentinel = n_blocks  # out-of-range block id => unmapped
        self.tables = np.full((batch, self.max_table), self.sentinel,
                              np.int32)
        self._owned: Dict[int, List[int]] = {}  # slot -> owned block ids
        self.prefix_hit_tokens = 0  # prompt tokens served from shared blocks

    def blocks_needed(self, total_tokens: int) -> int:
        return -(-total_tokens // self.block_size)

    def _plan(self, prompt: np.ndarray,
              total_tokens: int) -> Tuple[List[bytes], int, int]:
        """(chain keys over full prompt blocks, #prefix hits, #blocks)."""
        keys = chain_keys(prompt, self.block_size)
        n_hit = 0
        for k in keys:
            if self.prefix.get(k) is None:
                break
            n_hit += 1
        return keys, n_hit, self.blocks_needed(total_tokens)

    def can_admit(self, prompt: np.ndarray, total_tokens: int) -> bool:
        keys, n_hit, n_need = self._plan(prompt, total_tokens)
        return n_need - n_hit <= self.allocator.n_free

    def admit(self, slot: int, prompt: np.ndarray, total_tokens: int,
              max_prompt_len: int) -> Tuple[int, np.ndarray]:
        """Reserve blocks for one request and map them into ``slot``.

        Returns ``(n_cached_tokens, dst_rows)``: the number of leading
        prompt tokens already resident in shared blocks, and a
        ``(max_prompt_len,)`` int32 array of flat pool rows for the prefill
        scatter — cached and padding positions point at the out-of-range
        sentinel row so the jitted ``mode='drop'`` scatter skips them (a
        hit block is never written, even with identical bytes)."""
        assert slot not in self._owned, f"slot {slot} already mapped"
        keys, n_hit, n_need = self._plan(prompt, total_tokens)
        if n_need - n_hit > self.allocator.n_free:
            raise RuntimeError("admit() without free blocks; call can_admit")
        blocks = []
        for k in keys[:n_hit]:
            bid = self.prefix.get(k)
            self.allocator.fork(bid)
            blocks.append(bid)
        blocks += [self.allocator.alloc() for _ in range(n_need - n_hit)]
        # freshly-filled full prompt blocks become hittable for later
        # requests; their content is immutable once the prefill commits
        for i in range(n_hit, len(keys)):
            self.prefix.put(keys[i], blocks[i])
        self.tables[slot] = self.sentinel
        self.tables[slot, :n_need] = blocks
        self._owned[slot] = blocks
        cached = n_hit * self.block_size
        self.prefix_hit_tokens += cached
        bs = self.block_size
        dst = np.full((max_prompt_len,), self.sentinel * bs, np.int32)
        p = np.arange(cached, len(prompt))
        if p.size:
            dst[p] = np.asarray(blocks, np.int32)[p // bs] * bs + p % bs
        return cached, dst

    def release(self, slot: int) -> None:
        """Return a finished slot's references; evict dead prefix entries."""
        for bid in self._owned.pop(slot):
            if self.allocator.free(bid) == 0:
                self.prefix.drop_block(bid)
        self.tables[slot] = self.sentinel

    @property
    def fully_free(self) -> bool:
        return self.allocator.n_free == self.allocator.n_blocks


__all__ = ["BlockAllocator", "PagedCacheManager", "PrefixCache",
           "chain_keys"]
