"""Async HTTP front door for :class:`~repro.serve.engine.ContinuousEngine`.

Pure stdlib (asyncio + json): the serving container needs no web
framework.  One event loop owns three things:

* **The pump** — a background task that runs the engine's blocking
  ``step()`` in the default thread-pool executor (the loop stays
  responsive while the device computes) and routes the step's
  ``(uid, token)`` events and :class:`Completion`s to per-request asyncio
  queues.  ``ContinuousEngine.submit`` / ``cancel`` are thread-safe
  against a concurrently running ``step()``, which is exactly the
  property this split leans on.
* **The HTTP server** — ``asyncio.start_server`` with a minimal
  HTTP/1.1 parser (request line, headers, ``Content-Length`` body; every
  response closes the connection).  Endpoints:

  - ``POST /v1/generate`` — body ``{"prompt": [ids...],
    "max_new_tokens": N, "temperature": T, "stop_ids": [...],
    "priority": P, "timeout_s": S, "stream": true|false}``.
    ``priority`` (0 = most urgent, default 1) and ``timeout_s`` ride
    into the engine, so admission is priority-class aware and a request
    still QUEUED past its deadline is dropped engine-side (the route
    deadline below covers it once running).  Streams tokens as
    Server-Sent Events (``data: {"id": uid, "token": t}`` per token,
    then ``event: done`` with the finish reason and counts), or — with
    ``"stream": false`` — returns one JSON object after the request
    finishes.  A full admission queue (``max_pending``) answers **429**
    with ``Retry-After`` before touching the engine: backpressure, not
    unbounded buffering.
  - ``GET /metrics`` — Prometheus text exposition of the server counters
    plus the engine's ``kv_stats()`` / ``prefill_stats()`` /
    ``spec_stats()`` (TTFT/latency quantiles, prefix-hit rate, blocks in
    use — see :class:`ServeMetrics`).
  - ``GET /healthz`` — liveness + a small JSON status.

* **Cancellation** — the server is the reason
  :meth:`ContinuousEngine.cancel` exists.  A client that disconnects
  mid-stream (detected by a concurrent read on the socket) and a request
  that overruns its deadline (``timeout_s``, default
  ``default_timeout_s``) are both cancelled *into* the engine, which
  releases the slot, parked write frontier, and refcounted paged blocks
  and returns a ``finish_reason="cancelled"`` completion through the
  normal path.  Deadline expiry is fired by the pump between steps, so an
  expired request is reported ``cancelled`` even if its token budget
  would have ended it the same step.

:class:`BackgroundServer` wraps the whole thing in a context manager
running the event loop on a daemon thread, for synchronous callers
(benchmarks, tests)::

    with BackgroundServer(engine, max_pending=32) as bg:
        r = requests_like_client(bg.host, bg.port)  # e.g. launch.loadgen

``repro.launch.serve --http`` boots the blocking variant (:func:`serve`),
and ``repro.launch.loadgen`` is the matching closed-/open-loop client.
"""

from __future__ import annotations

import asyncio
import json
import threading
import time
from collections import deque
from typing import Optional

import numpy as np


def _quantile(values, q: float) -> float:
    vals = list(values)
    return float(np.percentile(np.asarray(vals), q * 100)) if vals else 0.0


class ServeMetrics:
    """Server-side counters + latency reservoirs, rendered as Prometheus
    text exposition (the ``repro_serve_*`` family).

    TTFT/latency are bounded reservoirs (last ``maxlen`` completions), so
    the quantiles are over recent traffic and a long-lived server never
    grows.  Completions cancelled before their first token carry no TTFT
    sample (``first_token_at == 0``), and cancelled completions land in
    their OWN latency reservoir (``repro_serve_cancelled_latency_seconds``)
    — a storm of instantly-cancelled requests must not drag the served
    p50/p95 down.  TTFT is additionally bucketed per priority class."""

    def __init__(self, maxlen: int = 4096):
        self.maxlen = maxlen
        self.http_requests: dict = {}   # (route, code) -> count
        self.completions: dict = {}     # finish_reason -> count
        self.tokens_streamed = 0
        self.rejected_backpressure = 0
        self.cancelled = {"disconnect": 0, "deadline": 0}
        self.ttft_s: deque = deque(maxlen=maxlen)
        self.ttft_by_priority: dict = {}  # priority -> deque of ttfts
        self.latency_s: deque = deque(maxlen=maxlen)          # served only
        self.cancelled_latency_s: deque = deque(maxlen=maxlen)

    def count_request(self, route: str, code: int) -> None:
        key = (route, code)
        self.http_requests[key] = self.http_requests.get(key, 0) + 1

    def observe(self, completion) -> None:
        r = completion.finish_reason
        self.completions[r] = self.completions.get(r, 0) + 1
        if completion.first_token_at > 0:
            self.ttft_s.append(completion.ttft)
            prio = getattr(completion, "priority", 1)
            self.ttft_by_priority.setdefault(
                prio, deque(maxlen=self.maxlen)).append(completion.ttft)
        if r == "cancelled":
            self.cancelled_latency_s.append(completion.latency)
        else:
            self.latency_s.append(completion.latency)

    def render(self, engine) -> str:
        """Prometheus text format; merges the engine's own stats so one
        scrape covers the whole serving stack."""
        lines = []

        def metric(name, value, help_=None, type_="gauge", labels=""):
            if help_ is not None:
                lines.append(f"# HELP {name} {help_}")
                lines.append(f"# TYPE {name} {type_}")
            lines.append(f"{name}{labels} {value}")

        for (route, code), n in sorted(self.http_requests.items()):
            lines.append(
                f'repro_serve_http_requests_total'
                f'{{route="{route}",code="{code}"}} {n}')
        for reason, n in sorted(self.completions.items()):
            lines.append(
                f'repro_serve_completions_total{{reason="{reason}"}} {n}')
        for cause, n in sorted(self.cancelled.items()):
            lines.append(
                f'repro_serve_cancelled_total{{cause="{cause}"}} {n}')
        metric("repro_serve_tokens_streamed_total", self.tokens_streamed,
               "Tokens written to SSE streams", "counter")
        metric("repro_serve_rejected_backpressure_total",
               self.rejected_backpressure,
               "Requests answered 429 by the bounded admission queue",
               "counter")
        for q in (0.5, 0.95):
            metric("repro_serve_ttft_seconds", _quantile(self.ttft_s, q),
                   labels=f'{{quantile="{q}"}}')
            metric("repro_serve_latency_seconds",
                   _quantile(self.latency_s, q),
                   labels=f'{{quantile="{q}"}}')
            metric("repro_serve_cancelled_latency_seconds",
                   _quantile(self.cancelled_latency_s, q),
                   labels=f'{{quantile="{q}"}}')
        for prio in sorted(self.ttft_by_priority):
            for q in (0.5, 0.95):
                metric("repro_serve_ttft_seconds",
                       _quantile(self.ttft_by_priority[prio], q),
                       labels=f'{{quantile="{q}",priority="{prio}"}}')

        pe = engine.preempt_stats()
        metric("repro_serve_preemptions_total", pe["preemptions"],
               "Running decodes preempted for a higher-priority admission",
               "counter")
        metric("repro_serve_preempt_resumes_total", pe["resumes"],
               "Preempted requests whose resume re-bound", "counter")
        metric("repro_serve_preempt_violations_total",
               pe["preempt_violations"],
               "Preemptions whose victim did not outrank the preemptor "
               "(must be 0)", "counter")

        sched = engine.scheduler
        metric("repro_serve_queue_pending", sched.n_pending,
               "Requests waiting for a slot")
        metric("repro_serve_slots_running", sched.n_running)
        metric("repro_serve_slots_prefilling", sched.n_prefilling)

        kv = engine.kv_stats()
        metric("repro_serve_kv_allocated_bytes", kv["kv_allocated_bytes"])
        metric("repro_serve_kv_peak_resident_bytes",
               kv["kv_peak_resident_bytes"])
        if "blocks_in_use" in kv:
            metric("repro_serve_kv_blocks_in_use", kv["blocks_in_use"],
                   "Paged KV blocks referenced by live requests")
            metric("repro_serve_kv_blocks_peak", kv["peak_blocks_in_use"])
            metric("repro_serve_kv_blocks_total", kv["n_blocks"])
        if "draft_kv_allocated_bytes" in kv:
            metric("repro_serve_draft_kv_allocated_bytes",
                   kv["draft_kv_allocated_bytes"])

        pf = engine.prefill_stats()
        metric("repro_serve_prefix_hit_rate", pf["prefix_hit_rate"],
               "Fraction of admitted prompt tokens served from the "
               "prefix cache")
        metric("repro_serve_prefill_tokens_computed_total",
               pf["prefill_tokens_computed"], type_="counter")
        metric("repro_serve_prompt_tokens_admitted_total",
               pf["prompt_tokens_admitted"], type_="counter")

        if engine.spec_k:
            sp = engine.spec_stats()
            metric("repro_serve_spec_acceptance_rate",
                   sp["spec_acceptance_rate"])
        return "\n".join(lines) + "\n"


class _Route:
    """Per-request delivery: a queue the pump feeds, plus the deadline."""

    __slots__ = ("queue", "deadline", "expired")

    def __init__(self, deadline: Optional[float]):
        self.queue: asyncio.Queue = asyncio.Queue()
        self.deadline = deadline
        self.expired = False


class HttpServer:
    """The asyncio server; see the module docstring for the protocol.

    ``port=0`` binds an ephemeral port (read ``self.port`` after
    :meth:`start`).  ``max_pending`` bounds the engine's admission queue:
    a POST arriving with ``scheduler.n_pending >= max_pending`` is
    rejected 429 without submitting.  ``default_timeout_s`` is the
    per-request deadline when the body names none (``None`` disables)."""

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0,
                 max_pending: int = 64,
                 default_timeout_s: Optional[float] = None):
        if max_pending < 1:
            raise ValueError("need max_pending >= 1")
        self.engine = engine
        self.host, self.port = host, port
        self.max_pending = max_pending
        self.default_timeout_s = default_timeout_s
        self.metrics = ServeMetrics()
        self._routes: dict = {}  # uid -> _Route
        self._wake = asyncio.Event()
        self._stopping = False
        self._server = None
        self._pump_task = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> None:
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._pump_task = asyncio.ensure_future(self._pump())

    async def stop(self) -> None:
        self._stopping = True
        self._wake.set()
        self._server.close()
        await self._server.wait_closed()
        if self._pump_task is not None:
            await self._pump_task

    # -- the pump ------------------------------------------------------------

    def _fire_deadlines(self) -> None:
        now = time.monotonic()
        for uid, route in list(self._routes.items()):
            if (route.deadline is not None and now >= route.deadline
                    and not route.expired):
                route.expired = True
                self.metrics.cancelled["deadline"] += 1
                self.engine.cancel(uid)

    async def _pump(self) -> None:
        """Drive ``engine.step()`` in the executor while work exists and
        fan its events out to the per-request routes.  Everything that
        mutates the engine beyond thread-safe ``submit``/``cancel``
        happens here, on one task — handlers only enqueue."""
        loop = asyncio.get_running_loop()
        while not self._stopping:
            self._fire_deadlines()
            if self.engine.scheduler.idle:
                self._wake.clear()
                if self.engine.scheduler.idle:  # re-check: lost-wakeup guard
                    try:
                        await asyncio.wait_for(self._wake.wait(),
                                               timeout=0.05)
                    except asyncio.TimeoutError:
                        pass
                    continue
            done = await loop.run_in_executor(None, self.engine.step)
            for uid, tok in self.engine.step_events:
                route = self._routes.get(uid)
                if route is not None:
                    route.queue.put_nowait(("token", tok))
            for comp in done:
                self.metrics.observe(comp)
                route = self._routes.pop(comp.uid, None)
                if route is not None:
                    route.queue.put_nowait(("done", comp))

    # -- HTTP ----------------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            if not line:
                return
            parts = line.decode("latin1").split()
            if len(parts) < 2:
                return
            method, path = parts[0].upper(), parts[1]
            headers = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", "0") or 0)
            if n:
                body = await reader.readexactly(n)

            if method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            elif method == "GET" and path == "/metrics":
                self._respond(writer, path, 200,
                              self.metrics.render(self.engine).encode(),
                              ctype="text/plain; version=0.0.4")
            elif method == "GET" and path == "/healthz":
                sched = self.engine.scheduler
                self._respond(writer, path, 200, json.dumps({
                    "status": "ok",
                    "pending": sched.n_pending,
                    "running": sched.n_running,
                    "prefilling": sched.n_prefilling,
                }).encode())
            else:
                self._respond(writer, path, 404,
                              json.dumps({"error": "not found"}).encode())
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    def _respond(self, writer, route: str, code: int, body: bytes, *,
                 ctype: str = "application/json",
                 extra_headers: str = "") -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  429: "Too Many Requests"}.get(code, "OK")
        writer.write(
            f"HTTP/1.1 {code} {reason}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"{extra_headers}"
            f"Connection: close\r\n\r\n".encode() + body)
        self.metrics.count_request(route, code)

    async def _generate(self, reader, writer, body: bytes) -> None:
        route = "/v1/generate"
        try:
            payload = json.loads(body or b"{}")
            prompt = payload["prompt"]
        except (ValueError, KeyError) as exc:
            self._respond(writer, route, 400,
                          json.dumps({"error": f"bad request: {exc}"}
                                     ).encode())
            return
        # backpressure BEFORE the engine sees the request: the queue is a
        # hard bound, the client owns the retry
        if self.engine.scheduler.n_pending >= self.max_pending:
            self.metrics.rejected_backpressure += 1
            self._respond(writer, route, 429,
                          json.dumps({"error": "admission queue full",
                                      "pending": self.engine.scheduler
                                      .n_pending}).encode(),
                          extra_headers="Retry-After: 1\r\n")
            return
        timeout_s = payload.get("timeout_s", self.default_timeout_s)
        try:
            uid = self.engine.submit(
                np.asarray(prompt, np.int32),
                max_new_tokens=int(payload.get("max_new_tokens", 16)),
                temperature=float(payload.get("temperature", 0.0)),
                stop_ids=tuple(payload.get("stop_ids", ())),
                priority=int(payload.get("priority", 1)),
                # the engine enforces this while the request is QUEUED;
                # the route deadline below covers it once running (and
                # owns non-positive timeouts = already expired, which
                # the engine's Request validation does not admit)
                timeout_s=(float(timeout_s)
                           if timeout_s is not None and float(timeout_s) > 0
                           else None))
        except (ValueError, TypeError) as exc:
            self._respond(writer, route, 400,
                          json.dumps({"error": str(exc)}).encode())
            return
        deadline = (time.monotonic() + float(timeout_s)
                    if timeout_s is not None else None)
        rt = self._routes[uid] = _Route(deadline)
        self._wake.set()
        if payload.get("stream", True):
            await self._stream_sse(reader, writer, uid, rt)
        else:
            await self._respond_json(writer, uid, rt)

    async def _stream_sse(self, reader, writer, uid: int,
                          rt: _Route) -> None:
        route = "/v1/generate"
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n")
        self.metrics.count_request(route, 200)
        # the only bytes a well-behaved client sends after the body is
        # EOF on disconnect — a concurrent read is our disconnect signal
        disc = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                get = asyncio.ensure_future(rt.queue.get())
                await asyncio.wait({get, disc},
                                   return_when=asyncio.FIRST_COMPLETED)
                if not get.done():
                    get.cancel()
                    # client went away: cancel into the engine and stop
                    # streaming; the pump still observes the completion
                    self._routes.pop(uid, None)
                    self.metrics.cancelled["disconnect"] += 1
                    self.engine.cancel(uid)
                    return
                kind, val = get.result()
                if kind == "token":
                    self.metrics.tokens_streamed += 1
                    writer.write(
                        f'data: {{"id": {uid}, "token": {int(val)}}}\n\n'
                        .encode())
                    await writer.drain()
                else:  # done
                    comp = val
                    writer.write(
                        b"event: done\ndata: " + json.dumps({
                            "id": uid,
                            "finish_reason": comp.finish_reason,
                            "n_tokens": len(comp.tokens),
                            "prompt_len": comp.prompt_len,
                        }).encode() + b"\n\n")
                    await writer.drain()
                    return
        except (ConnectionError, OSError):
            self._routes.pop(uid, None)
            self.metrics.cancelled["disconnect"] += 1
            self.engine.cancel(uid)
        finally:
            disc.cancel()

    async def _respond_json(self, writer, uid: int, rt: _Route) -> None:
        tokens = []
        while True:
            kind, val = await rt.queue.get()
            if kind == "token":
                tokens.append(int(val))
            else:
                comp = val
                self._respond(writer, "/v1/generate", 200, json.dumps({
                    "id": uid,
                    "tokens": tokens,
                    "finish_reason": comp.finish_reason,
                    "prompt_len": comp.prompt_len,
                }).encode())
                return


def serve(engine, *, host: str = "127.0.0.1", port: int = 8000,
          max_pending: int = 64,
          default_timeout_s: Optional[float] = None) -> None:
    """Blocking entry point (``repro.launch.serve --http``): boot the
    server and run until interrupted."""

    async def main():
        srv = HttpServer(engine, host=host, port=port,
                         max_pending=max_pending,
                         default_timeout_s=default_timeout_s)
        await srv.start()
        print(f"serving on http://{srv.host}:{srv.port}  "
              f"(POST /v1/generate, GET /metrics, GET /healthz)",
              flush=True)
        try:
            await asyncio.Event().wait()
        finally:
            await srv.stop()

    try:
        asyncio.run(main())
    except KeyboardInterrupt:
        pass


class BackgroundServer:
    """Run :class:`HttpServer` on a daemon thread — the synchronous
    harness for benchmarks and tests::

        with BackgroundServer(engine, max_pending=8) as bg:
            ...drive http://{bg.host}:{bg.port} from this thread...

    The engine must not be stepped by anyone else while the server owns
    it (the pump is the single driver)."""

    def __init__(self, engine, **kwargs):
        self.engine = engine
        self.kwargs = kwargs
        self.server: Optional[HttpServer] = None
        self._loop = None
        self._thread = None

    @property
    def host(self) -> str:
        return self.server.host

    @property
    def port(self) -> int:
        return self.server.port

    def __enter__(self) -> "BackgroundServer":
        self._loop = asyncio.new_event_loop()
        ready = threading.Event()
        err: list = []

        def run():
            asyncio.set_event_loop(self._loop)
            self.server = HttpServer(self.engine, **self.kwargs)
            try:
                self._loop.run_until_complete(self.server.start())
            except Exception as exc:  # surface bind errors to the caller
                err.append(exc)
                ready.set()
                return
            ready.set()
            self._loop.run_forever()

        self._thread = threading.Thread(target=run, daemon=True,
                                        name="repro-http-serve")
        self._thread.start()
        if not ready.wait(timeout=30):
            raise RuntimeError("HTTP server failed to start in 30s")
        if err:
            raise err[0]
        return self

    def __exit__(self, *exc) -> None:
        asyncio.run_coroutine_threadsafe(self.server.stop(),
                                         self._loop).result(timeout=30)
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=30)
        self._loop.close()


__all__ = ["HttpServer", "BackgroundServer", "ServeMetrics", "serve"]
