"""Fault-tolerant checkpointing: atomic, manifest-driven, keep-last-k.

Layout:
  <dir>/step_000123/
      manifest.json    {step, leaf paths, shapes, dtypes, treedef-hash}
      arrays.npz       every array leaf, keyed by flattened path
  <dir>/LATEST         text file naming the newest *complete* step dir

Writes go to ``step_X.tmp`` and are renamed only after fsync — a process
killed mid-write never corrupts the latest checkpoint, so crash/preempt →
relaunch → ``restore_latest`` always resumes from a consistent state.  On a
multi-host pod each process writes ``arrays.p<proc>.npz`` for its addressable
shards (single-process here: one file).
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)[0]
    out = {}
    for key_path, leaf in leaves:
        out[jax.tree_util.keystr(key_path)] = leaf
    return out


def _treedef_hash(tree) -> str:
    treedef = jax.tree_util.tree_structure(tree, is_leaf=lambda x: x is None)
    return hex(zlib.crc32(str(treedef).encode()))


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any) -> str:
        name = f"step_{step:08d}"
        final = os.path.join(self.dir, name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat = _flatten(tree)
        arrays = {k: np.asarray(v) for k, v in flat.items() if v is not None}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "treedef": _treedef_hash(tree),
            "leaves": {k: (None if v is None else
                           [list(np.shape(v)), str(np.asarray(v).dtype)])
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)  # atomic publish
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(name)
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            path = os.path.join(self.dir, name, "manifest.json")
            if os.path.exists(path):
                return int(name[len("step_"):])
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any) -> Any:
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest["treedef"] != _treedef_hash(template):
            raise ValueError(
                "checkpoint treedef mismatch — template structure changed")
        data = np.load(os.path.join(path, "arrays.npz"))

        flat_template = jax.tree_util.tree_flatten_with_path(
            template, is_leaf=lambda x: x is None)
        leaves = []
        for key_path, leaf in flat_template[0]:
            name = jax.tree_util.keystr(key_path)
            if leaf is None:
                leaves.append(None)
            else:
                arr = data[name]
                leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(flat_template[1], leaves)

    def restore_latest(self, template: Any) -> tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, template
        return step, self.restore(step, template)
