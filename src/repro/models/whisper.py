"""Whisper-style encoder-decoder transformer (audio backbone).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()`` feeds
precomputed frame embeddings ``(batch, frames, d_model)`` straight into the
encoder.  (A reference ``Conv1D`` frontend is still provided — it is the one
in-model consumer of the paper's CED factorization — but the launch shapes
bypass it.)  Pre-norm LayerNorm + GeLU, learned positions, MHA.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain_acts
from repro.nn.attention import Attention, KVCache
from repro.nn.conv import Conv1D
from repro.nn.embedding import Embedding
from repro.nn.linear import Linear
from repro.nn.mlp import GeluMLP
from repro.nn.module import Module, static_field
from repro.nn.norm import LayerNorm


class EncoderBlock(Module):
    attn_norm: LayerNorm
    attn: Attention
    mlp_norm: LayerNorm
    mlp: GeluMLP

    @staticmethod
    def create(key, cfg: ArchConfig) -> "EncoderBlock":
        ka, km = jax.random.split(key)
        dt = jnp.dtype(cfg.dtype)
        return EncoderBlock(
            attn_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            attn=Attention.create(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  head_dim=cfg.resolved_head_dim, rope=False,
                                  causal=False, qkv_bias=True, dtype=dt),
            mlp_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            mlp=GeluMLP.create(km, cfg.d_model, cfg.d_ff, dtype=dt),
        )

    def __call__(self, x):
        x = x + self.attn(self.attn_norm(x))
        return x + self.mlp(self.mlp_norm(x))


class DecoderBlock(Module):
    self_norm: LayerNorm
    self_attn: Attention
    cross_norm: LayerNorm
    cross_attn: Attention
    mlp_norm: LayerNorm
    mlp: GeluMLP

    @staticmethod
    def create(key, cfg: ArchConfig) -> "DecoderBlock":
        ks, kc, km = jax.random.split(key, 3)
        dt = jnp.dtype(cfg.dtype)
        mk_attn = lambda k, causal: Attention.create(
            k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope=False, causal=causal,
            qkv_bias=True, chunk=cfg.attn_chunk if causal else 0, dtype=dt)
        return DecoderBlock(
            self_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            self_attn=mk_attn(ks, True),
            cross_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            cross_attn=mk_attn(kc, False),
            mlp_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            mlp=GeluMLP.create(km, cfg.d_model, cfg.d_ff, dtype=dt),
        )

    def __call__(self, x, enc):
        x = x + self.self_attn(self.self_norm(x))
        x = x + self.cross_attn(self.cross_norm(x), context=enc)
        return x + self.mlp(self.mlp_norm(x)), jnp.zeros((), jnp.float32)

    def prefill(self, x, cache: "WhisperLayerCache"):
        a, kv = self.self_attn.prefill(self.self_norm(x), cache.self_kv)
        x = x + a
        x = x + self.cross_attn.attend_kv(self.cross_norm(x),
                                          cache.cross_k, cache.cross_v)
        return x + self.mlp(self.mlp_norm(x)), cache._replace(self_kv=kv)

    def decode(self, x, cache: "WhisperLayerCache"):
        a, kv = self.self_attn.decode(self.self_norm(x), cache.self_kv)
        x = x + a
        x = x + self.cross_attn.attend_kv(self.cross_norm(x),
                                          cache.cross_k, cache.cross_v)
        return x + self.mlp(self.mlp_norm(x)), cache._replace(self_kv=kv)


class WhisperLayerCache(NamedTuple):
    self_kv: KVCache
    cross_k: jax.Array  # (batch, enc_len, kv_heads, head_dim)
    cross_v: jax.Array


class WhisperModel(Module):
    frontend: Conv1D  # reference frontend (bypassed by launch stubs)
    enc_pos: Embedding
    enc_blocks: EncoderBlock  # stacked
    enc_norm: LayerNorm
    dec_embed: Embedding
    dec_pos: Embedding
    dec_blocks: DecoderBlock  # stacked
    dec_norm: LayerNorm
    lm_head: Optional[Linear]
    n_layers: int = static_field(default=1)
    n_enc_layers: int = static_field(default=1)
    remat: bool = static_field(default=False)

    @staticmethod
    def create(key, cfg: ArchConfig, *, remat: bool = False) -> "WhisperModel":
        keys = jax.random.split(key, 7)
        dt = jnp.dtype(cfg.dtype)
        enc_blocks = jax.vmap(lambda k: EncoderBlock.create(k, cfg))(
            jax.random.split(keys[0], cfg.n_enc_layers))
        dec_blocks = jax.vmap(lambda k: DecoderBlock.create(k, cfg))(
            jax.random.split(keys[1], cfg.n_layers))
        return WhisperModel(
            frontend=Conv1D.create(keys[2], 80, cfg.d_model, 3, dtype=dt),
            enc_pos=Embedding.create(keys[3], cfg.max_positions, cfg.d_model, dtype=dt),
            enc_blocks=enc_blocks,
            enc_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            dec_embed=Embedding.create(keys[4], cfg.vocab, cfg.d_model, dtype=dt),
            dec_pos=Embedding.create(keys[5], cfg.max_positions, cfg.d_model, dtype=dt),
            dec_blocks=dec_blocks,
            dec_norm=LayerNorm.create(cfg.d_model, dtype=dt),
            lm_head=Linear.create(keys[6], cfg.d_model, cfg.vocab, dtype=dt),
            n_layers=cfg.n_layers, n_enc_layers=cfg.n_enc_layers, remat=remat,
        )

    # -- encoder --------------------------------------------------------------

    def encode(self, frames: jax.Array) -> jax.Array:
        """frames: (batch, enc_len, d_model) precomputed embeddings (stub)."""
        t = frames.shape[1]
        x = frames + self.enc_pos.weight[None, :t].astype(frames.dtype)

        def body(x, blk):
            fn = (lambda b, xx: b(xx))
            if self.remat:
                fn = jax.checkpoint(fn)
            return constrain_acts(fn(blk, x)), None

        x, _ = jax.lax.scan(body, constrain_acts(x), self.enc_blocks)
        return self.enc_norm(x)

    # -- decoder --------------------------------------------------------------

    def _head(self, x):
        return self.lm_head(x)

    def __call__(self, frames: jax.Array, tokens: jax.Array):
        """Teacher-forced training forward. Returns (logits, aux=0)."""
        enc = self.encode(frames)
        s = tokens.shape[1]
        x = self.dec_embed(tokens) + self.dec_pos.weight[None, :s].astype(
            self.dec_embed.weight.dtype)

        def body(x, blk):
            fn = (lambda b, xx: b(xx, enc)[0])
            if self.remat:
                fn = jax.checkpoint(fn)
            return constrain_acts(fn(blk, x)), None

        x, _ = jax.lax.scan(body, x, self.dec_blocks)
        return self._head(self.dec_norm(x)), jnp.zeros((), jnp.float32)

    # -- serving ----------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, cfg: ArchConfig,
                   enc_len: int = 1500, dtype=jnp.bfloat16) -> WhisperLayerCache:
        L, kvh, hd = self.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
        return WhisperLayerCache(
            self_kv=KVCache(
                k=jnp.zeros((L, batch, max_len, kvh, hd), dtype),
                v=jnp.zeros((L, batch, max_len, kvh, hd), dtype),
                length=jnp.zeros((L,), jnp.int32)),
            cross_k=jnp.zeros((L, batch, enc_len, kvh, hd), dtype),
            cross_v=jnp.zeros((L, batch, enc_len, kvh, hd), dtype),
        )

    def prefill(self, frames: jax.Array, tokens: jax.Array,
                cache: WhisperLayerCache):
        """Encode audio, project cross-KV, prefill decoder self-attention."""
        enc = self.encode(frames)

        def proj(blk):
            return blk.cross_attn.project_kv(enc)

        cross_k, cross_v = jax.vmap(proj)(self.dec_blocks)
        cache = cache._replace(cross_k=cross_k.astype(cache.cross_k.dtype),
                               cross_v=cross_v.astype(cache.cross_v.dtype))
        s = tokens.shape[1]
        x = self.dec_embed(tokens) + self.dec_pos.weight[None, :s].astype(
            self.dec_embed.weight.dtype)

        def body(x, xs):
            blk, c = xs
            y, c2 = blk.prefill(x, c)
            return constrain_acts(y), c2

        x, new_cache = jax.lax.scan(body, x, (self.dec_blocks, cache))
        return self._head(self.dec_norm(x[:, -1:])), new_cache

    def decode(self, token: jax.Array, cache: WhisperLayerCache):
        pos = cache.self_kv.length[0]
        x = self.dec_embed(token) + self.dec_pos.weight[pos][None, None].astype(
            self.dec_embed.weight.dtype)

        def body(x, xs):
            blk, c = xs
            return blk.decode(x, c)

        x, new_cache = jax.lax.scan(body, x, (self.dec_blocks, cache))
        return self._head(self.dec_norm(x)), new_cache
