"""Hymba-style hybrid LM: parallel attention + SSM heads per layer + MLP."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain_acts
from repro.nn.attention import KVCache
from repro.nn.embedding import Embedding
from repro.nn.hybrid import HybridCache, HybridMixer, HybridState
from repro.nn.linear import Linear
from repro.nn.mlp import SwiGLU
from repro.nn.module import Module, static_field
from repro.nn.norm import RMSNorm


class HymbaBlock(Module):
    mixer_norm: RMSNorm
    mixer: HybridMixer
    mlp_norm: RMSNorm
    mlp: SwiGLU

    @staticmethod
    def create(key, cfg: ArchConfig) -> "HymbaBlock":
        km, kf = jax.random.split(key)
        dt = jnp.dtype(cfg.dtype)
        return HymbaBlock(
            mixer_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            mixer=HybridMixer.create(
                km, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, window=cfg.window,
                ssm_state=cfg.ssm_state, ssm_head_dim=cfg.ssm_head_dim,
                chunk=cfg.attn_chunk, dtype=dt),
            mlp_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            mlp=SwiGLU.create(kf, cfg.d_model, cfg.d_ff, dtype=dt),
        )

    def __call__(self, x):
        x = x + self.mixer(self.mixer_norm(x))
        x = x + self.mlp(self.mlp_norm(x))
        return x, jnp.zeros((), jnp.float32)

    def prefill(self, x, state: HybridState):
        m, state = self.mixer.prefill(self.mixer_norm(x), state)
        x = x + m
        x = x + self.mlp(self.mlp_norm(x))
        return x, state

    def decode(self, x, state: HybridState):
        m, state = self.mixer.decode(self.mixer_norm(x), state)
        x = x + m
        x = x + self.mlp(self.mlp_norm(x))
        return x, state

    def prefill_chunk(self, x, state: HybridState, **kw):
        m, state = self.mixer.prefill_chunk(self.mixer_norm(x), state, **kw)
        x = x + m
        x = x + self.mlp(self.mlp_norm(x))
        return x, state


class HymbaLM(Module):
    embed: Embedding
    blocks: HymbaBlock  # layer-stacked
    final_norm: RMSNorm
    lm_head: Optional[Linear]
    n_layers: int = static_field(default=1)
    remat: bool = static_field(default=False)

    @staticmethod
    def create(key, cfg: ArchConfig, *, remat: bool = False) -> "HymbaLM":
        ke, kb, kh = jax.random.split(key, 3)
        dt = jnp.dtype(cfg.dtype)
        blocks = jax.vmap(lambda k: HymbaBlock.create(k, cfg))(
            jax.random.split(kb, cfg.n_layers))
        return HymbaLM(
            embed=Embedding.create(ke, cfg.vocab, cfg.d_model, dtype=dt),
            blocks=blocks,
            final_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            lm_head=Linear.create(kh, cfg.d_model, cfg.vocab, dtype=dt),
            n_layers=cfg.n_layers, remat=remat,
        )

    def _head(self, x):
        return self.embed.attend(x) if self.lm_head is None else self.lm_head(x)

    def __call__(self, tokens):
        x = constrain_acts(self.embed(tokens))

        def body(carry, blk):
            x, aux = carry
            fn = (lambda b, xx: b(xx))
            if self.remat:
                fn = jax.checkpoint(fn)
            y, a = fn(blk, x)
            return (constrain_acts(y), aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   self.blocks)
        return self._head(self.final_norm(x)), aux

    def cache_kind(self, cfg: ArchConfig) -> str:
        """Capability probe for ``repro.serve.ContinuousEngine``: hybrid
        per-slot state — ring-buffer KV lanes (O(window) per slot) for
        the sliding-window attention path plus O(1) conv/ssm state for
        the SSM path.  Ring lanes cannot be paged or prefix-cached."""
        return "hybrid"

    def init_cache(self, batch: int, max_len: int, cfg: ArchConfig,
                   dtype=jnp.bfloat16, per_slot: bool = False):
        L = self.n_layers
        slots = min(max_len, cfg.window) if cfg.window else max_len
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        d_inner = cfg.ssm_expand * cfg.d_model
        n_heads_ssm = d_inner // cfg.ssm_head_dim
        conv_dim = d_inner + 2 * cfg.ssm_state  # n_groups = 1
        from repro.nn.ssm import SSMState
        if per_slot:
            return HybridCache(
                k=jnp.zeros((L, batch, slots, kvh, hd), dtype),
                v=jnp.zeros((L, batch, slots, kvh, hd), dtype),
                conv=jnp.zeros((L, batch, 3, conv_dim), dtype),
                ssm=jnp.zeros((L, batch, n_heads_ssm, cfg.ssm_head_dim,
                               cfg.ssm_state), dtype),
                length=jnp.zeros((L, batch), jnp.int32))
        return HybridState(
            kv=KVCache(
                k=jnp.zeros((L, batch, slots, kvh, hd), dtype),
                v=jnp.zeros((L, batch, slots, kvh, hd), dtype),
                length=jnp.zeros((L,), jnp.int32)),
            ssm=SSMState(
                conv=jnp.zeros((L, batch, 3, conv_dim), dtype),
                ssm=jnp.zeros((L, batch, n_heads_ssm, cfg.ssm_head_dim,
                               cfg.ssm_state), dtype)),
        )

    def prefill(self, tokens, cache: HybridState):
        x = constrain_acts(self.embed(tokens))

        def body(x, xs):
            blk, c = xs
            fn = (lambda b, xx, cc: b.prefill(xx, cc))
            if self.remat:
                fn = jax.checkpoint(fn)
            y, c2 = fn(blk, x, c)
            return constrain_acts(y), c2

        x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        return self._head(self.final_norm(x[:, -1:])), new_cache

    def prefill_chunk(self, tokens, cache: HybridCache, *, slot, offset,
                      n_valid, need_logits: bool = True):
        """Consume one bucket-padded prompt chunk for slot ``slot`` of the
        per-slot serving cache: the attention path writes the slot's ring
        (or dense) KV lane, the SSM path scans into the slot's carried
        conv/ssm state (see :meth:`TransformerLM.prefill_chunk` for the
        engine-side contract)."""
        x = constrain_acts(self.embed(tokens))
        from repro.nn.ssm import SSMState

        def body(x, xs):
            blk, (k, v, cv, sm, ln) = xs
            st = HybridState(kv=KVCache(k, v, ln), ssm=SSMState(cv, sm))
            y, st2 = blk.prefill_chunk(x, st, slot=slot, offset=offset,
                                       n_valid=n_valid)
            return constrain_acts(y), (st2.kv.k, st2.kv.v, st2.ssm.conv,
                                       st2.ssm.ssm, st2.kv.length)

        x, (k, v, cv, sm, ln) = jax.lax.scan(
            body, x, (self.blocks, (cache.k, cache.v, cache.conv,
                                    cache.ssm, cache.length)))
        new_cache = HybridCache(k, v, cv, sm, ln)
        if not need_logits:
            return None, new_cache
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        return self._head(self.final_norm(last))[:, 0], new_cache

    def decode(self, token, cache):
        x = self.embed(token)

        if isinstance(cache, HybridCache):
            from repro.nn.ssm import SSMState

            def body(x, xs):
                blk, (k, v, cv, sm, ln) = xs
                st = HybridState(kv=KVCache(k, v, ln), ssm=SSMState(cv, sm))
                y, st2 = blk.decode(x, st)
                return y, (st2.kv.k, st2.kv.v, st2.ssm.conv, st2.ssm.ssm,
                           st2.kv.length)

            x, (k, v, cv, sm, ln) = jax.lax.scan(
                body, x, (self.blocks, (cache.k, cache.v, cache.conv,
                                        cache.ssm, cache.length)))
            return self._head(self.final_norm(x)), HybridCache(k, v, cv, sm,
                                                               ln)

        def body(x, xs):
            blk, c = xs
            return blk.decode(x, c)

        x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        return self._head(self.final_norm(x)), new_cache
