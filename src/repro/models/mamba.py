"""Mamba-2 language model (attention-free SSD stack)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain_acts
from repro.nn.embedding import Embedding
from repro.nn.linear import Linear
from repro.nn.module import Module, static_field
from repro.nn.norm import RMSNorm
from repro.nn.ssm import Mamba2Mixer, SSMCache, SSMState


class MambaBlock(Module):
    norm: RMSNorm
    mixer: Mamba2Mixer

    @staticmethod
    def create(key, cfg: ArchConfig) -> "MambaBlock":
        dt = jnp.dtype(cfg.dtype)
        return MambaBlock(
            norm=RMSNorm.create(cfg.d_model, dtype=dt),
            mixer=Mamba2Mixer.create(
                key, cfg.d_model, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state, dtype=dt),
        )

    def __call__(self, x):
        return x + self.mixer(self.norm(x)), jnp.zeros((), jnp.float32)

    def prefill(self, x, state: SSMState):
        xin = self.norm(x)
        z, xbc, dt = self.mixer._split(self.mixer.in_proj(xin))
        xbc_c = self.mixer._conv(xbc)
        xi, B, C = self.mixer._split_xbc(xbc_c)
        y, final = self.mixer._ssd(xi, dt, B, C)
        y = y.reshape(x.shape[0], x.shape[1], self.mixer.d_inner)
        y = self.mixer.gate_norm(y) * jax.nn.silu(z)
        out = x + self.mixer.out_proj(y)
        w = self.mixer.conv_width - 1
        conv_tail = xbc[:, -w:, :] if x.shape[1] >= w else jnp.pad(
            xbc, ((0, 0), (w - x.shape[1], 0), (0, 0)))
        return out, SSMState(conv=conv_tail, ssm=final)

    def decode(self, x, state: SSMState):
        y, state = self.mixer.decode(self.norm(x), state)
        return x + y, state

    def prefill_chunk(self, x, conv, ssm, *, slot, offset, n_valid):
        """One prompt chunk for slot ``slot`` of the batched serving
        state (``conv``: (B, cw-1, c); ``ssm``: (B, h, p, n)).  The first
        chunk of a request (``offset == 0``) zeros the slot's lanes
        in-graph — the per-slot reset that makes slot recycling safe."""
        fresh = offset == 0
        conv0 = jnp.where(fresh, 0.0, conv[slot][None])
        ssm0 = jnp.where(fresh, 0.0, ssm[slot][None])
        y, st = self.mixer.prefill_chunk(self.norm(x), SSMState(conv0, ssm0),
                                         n_valid=n_valid)
        new_conv = conv.at[slot].set(st.conv[0].astype(conv.dtype))
        new_ssm = ssm.at[slot].set(st.ssm[0].astype(ssm.dtype))
        return x + y, new_conv, new_ssm


class MambaLM(Module):
    embed: Embedding
    blocks: MambaBlock  # layer-stacked
    final_norm: RMSNorm
    lm_head: Optional[Linear]
    n_layers: int = static_field(default=1)
    remat: bool = static_field(default=False)

    @staticmethod
    def create(key, cfg: ArchConfig, *, remat: bool = False) -> "MambaLM":
        ke, kb, kh = jax.random.split(key, 3)
        dt = jnp.dtype(cfg.dtype)
        blocks = jax.vmap(lambda k: MambaBlock.create(k, cfg))(
            jax.random.split(kb, cfg.n_layers))
        return MambaLM(
            embed=Embedding.create(ke, cfg.vocab, cfg.d_model, dtype=dt),
            blocks=blocks,
            final_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            lm_head=Linear.create(kh, cfg.d_model, cfg.vocab, dtype=dt),
            n_layers=cfg.n_layers, remat=remat,
        )

    def _head(self, x):
        return self.embed.attend(x) if self.lm_head is None else self.lm_head(x)

    def __call__(self, tokens):
        x = constrain_acts(self.embed(tokens))

        def body(carry, blk):
            x, aux = carry
            fn = (lambda b, xx: b(xx))
            if self.remat:
                fn = jax.checkpoint(fn)
            y, a = fn(blk, x)
            return (constrain_acts(y), aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   self.blocks)
        return self._head(self.final_norm(x)), aux

    def cache_kind(self, cfg: ArchConfig) -> str:
        """Capability probe for ``repro.serve.ContinuousEngine``: pure-SSM
        per-slot state (O(1) decode memory per slot; no paged / prefix
        machinery applies — there is nothing position-addressable to
        page or share)."""
        return "ssm"

    def init_cache(self, batch: int, max_len: int, cfg: ArchConfig,
                   dtype=jnp.bfloat16, per_slot: bool = False):
        del max_len  # O(1) state — the whole point
        mixer = Mamba2Mixer.create(  # shape-only template
            jax.random.PRNGKey(0), cfg.d_model, expand=cfg.ssm_expand,
            head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state, dtype=dtype)
        s = mixer.init_state(batch, dtype=dtype)
        L = self.n_layers
        if per_slot:
            return SSMCache(
                conv=jnp.zeros((L, *s.conv.shape), dtype),
                ssm=jnp.zeros((L, *s.ssm.shape), dtype),
                length=jnp.zeros((L, batch), jnp.int32))
        return SSMState(
            conv=jnp.zeros((L, *s.conv.shape), dtype),
            ssm=jnp.zeros((L, *s.ssm.shape), dtype))

    def prefill(self, tokens, cache: SSMState):
        x = constrain_acts(self.embed(tokens))

        def body(x, xs):
            blk, c = xs
            fn = (lambda b, xx, cc: b.prefill(xx, cc))
            if self.remat:
                fn = jax.checkpoint(fn)
            y, c2 = fn(blk, x, c)
            return constrain_acts(y), c2

        x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        return self._head(self.final_norm(x[:, -1:])), new_cache

    def prefill_chunk(self, tokens, cache: SSMCache, *, slot, offset,
                      n_valid, need_logits: bool = True):
        """Consume one bucket-padded prompt chunk for slot ``slot`` of the
        per-slot serving cache (see :meth:`TransformerLM.prefill_chunk`
        for the contract; here the carried state is the slot's conv/ssm
        lanes instead of KV rows, and ``offset`` only advances the
        position counter — the recurrence itself is position-free)."""
        x = constrain_acts(self.embed(tokens))

        def body(x, xs):
            blk, (cv, sm) = xs
            y, cv2, sm2 = blk.prefill_chunk(x, cv, sm, slot=slot,
                                            offset=offset, n_valid=n_valid)
            return constrain_acts(y), (cv2, sm2)

        x, (cv, sm) = jax.lax.scan(body, x, (self.blocks,
                                             (cache.conv, cache.ssm)))
        length = cache.length.at[:, slot].set(offset + n_valid)
        new_cache = SSMCache(cv, sm, length)
        if not need_logits:
            return None, new_cache
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        return self._head(self.final_norm(last))[:, 0], new_cache

    def decode(self, token, cache):
        x = self.embed(token)

        if isinstance(cache, SSMCache):
            def body(x, xs):
                blk, (cv, sm) = xs
                y, st = blk.decode(x, SSMState(cv, sm))
                return y, (st.conv, st.ssm)

            x, (cv, sm) = jax.lax.scan(body, x, (self.blocks,
                                                 (cache.conv, cache.ssm)))
            return self._head(self.final_norm(x)), SSMCache(
                cv, sm, cache.length + 1)

        def body(x, xs):
            blk, c = xs
            return blk.decode(x, c)

        x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        return self._head(self.final_norm(x)), new_cache
