"""Model factory: family string -> model class."""

from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.models.lm import TransformerLM, DenseBlock, MoEBlock
from repro.models.mamba import MambaLM, MambaBlock
from repro.models.hymba import HymbaLM, HymbaBlock
from repro.models.whisper import WhisperModel, WhisperLayerCache

_FAMILIES = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "ssm": MambaLM,
    "hybrid": HymbaLM,
    "encdec": WhisperModel,
}


def model_class(cfg: ArchConfig):
    return _FAMILIES[cfg.family]


def build_model(key: jax.Array, cfg: ArchConfig, *, remat: bool = False):
    return model_class(cfg).create(key, cfg, remat=remat)


__all__ = ["TransformerLM", "MambaLM", "HymbaLM", "WhisperModel",
           "WhisperLayerCache", "DenseBlock", "MoEBlock", "MambaBlock",
           "HymbaBlock", "build_model", "model_class"]
