"""Decoder-only transformer LM covering the dense / GQA / MoE / VLM archs.

Layers are weight-stacked and executed with ``jax.lax.scan`` so the HLO is
O(1) in depth (critical for 88-layer granite at compile time) and activation
rematerialization applies per-layer.  The VLM arch (chameleon) is early
fusion: VQ image tokens are ordinary vocabulary ids, so the backbone is this
same class (frontend stubbed per the assignment).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain_acts
from repro.nn.attention import (Attention, KVCache, PagedKVCache,
                                UnsupportedCacheError)
from repro.nn.embedding import Embedding
from repro.nn.linear import Linear
from repro.nn.mlp import SwiGLU
from repro.nn.moe import MoE
from repro.nn.module import Module, static_field
from repro.nn.norm import RMSNorm


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


class DenseBlock(Module):
    attn_norm: RMSNorm
    attn: Attention
    mlp_norm: RMSNorm
    mlp: SwiGLU

    @staticmethod
    def create(key, cfg: ArchConfig) -> "DenseBlock":
        ka, km = jax.random.split(key)
        dt = _dtype(cfg)
        return DenseBlock(
            attn_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            attn=Attention.create(
                ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias,
                rope_theta=cfg.rope_theta, window=cfg.window,
                chunk=cfg.attn_chunk, dtype=dt),
            mlp_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            mlp=SwiGLU.create(km, cfg.d_model, cfg.d_ff, dtype=dt),
        )

    def __call__(self, x):
        x = x + self.attn(self.attn_norm(x))
        x = x + self.mlp(self.mlp_norm(x))
        return x, jnp.zeros((), jnp.float32)

    def prefill(self, x, cache: KVCache):
        a, cache = self.attn.prefill(self.attn_norm(x), cache)
        x = x + a
        x = x + self.mlp(self.mlp_norm(x))
        return x, cache

    def decode(self, x, cache: KVCache, decode_kernel: str = "reference"):
        a, cache = self.attn.decode(self.attn_norm(x), cache,
                                    decode_kernel=decode_kernel)
        x = x + a
        x = x + self.mlp(self.mlp_norm(x))
        return x, cache

    def prefill_chunk(self, x, cache, **kw):
        a, cache = self.attn.prefill_chunk(self.attn_norm(x), cache, **kw)
        x = x + a
        x = x + self.mlp(self.mlp_norm(x))
        return x, cache


class MoEBlock(Module):
    attn_norm: RMSNorm
    attn: Attention
    mlp_norm: RMSNorm
    mlp: MoE

    @staticmethod
    def create(key, cfg: ArchConfig) -> "MoEBlock":
        ka, km = jax.random.split(key)
        dt = _dtype(cfg)
        return MoEBlock(
            attn_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            attn=Attention.create(
                ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias,
                rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk, dtype=dt),
            mlp_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            mlp=MoE.create(km, cfg.d_model, cfg.d_ff, cfg.n_experts,
                           cfg.top_k, n_shared=cfg.n_shared,
                           capacity_factor=cfg.capacity_factor, dtype=dt),
        )

    def __call__(self, x):
        x = x + self.attn(self.attn_norm(x))
        out = self.mlp(self.mlp_norm(x))
        return x + out.y, out.aux_loss

    def prefill(self, x, cache: KVCache):
        a, cache = self.attn.prefill(self.attn_norm(x), cache)
        x = x + a
        x = x + self.mlp(self.mlp_norm(x)).y
        return x, cache

    def decode(self, x, cache: KVCache, decode_kernel: str = "reference"):
        a, cache = self.attn.decode(self.attn_norm(x), cache,
                                    decode_kernel=decode_kernel)
        x = x + a
        x = x + self.mlp(self.mlp_norm(x)).y
        return x, cache

    def prefill_chunk(self, x, cache, **kw):
        # capacity-factor routing sees one chunk of tokens at a time here,
        # so expert-capacity dropping can differ from a monolithic prefill
        # of the same prompt; exact-capacity configs are unaffected
        a, cache = self.attn.prefill_chunk(self.attn_norm(x), cache, **kw)
        x = x + a
        x = x + self.mlp(self.mlp_norm(x)).y
        return x, cache


class TransformerLM(Module):
    embed: Embedding
    blocks: Module  # layer-stacked DenseBlock | MoEBlock
    final_norm: RMSNorm
    lm_head: Optional[Linear]  # None => tied embeddings
    n_layers: int = static_field(default=1)
    remat: bool = static_field(default=False)

    @staticmethod
    def create(key, cfg: ArchConfig, *, remat: bool = False) -> "TransformerLM":
        ke, kb, kh = jax.random.split(key, 3)
        dt = _dtype(cfg)
        block_cls = MoEBlock if cfg.n_experts else DenseBlock
        layer_keys = jax.random.split(kb, cfg.n_layers)
        blocks = jax.vmap(lambda k: block_cls.create(k, cfg))(layer_keys)
        lm_head = (None if cfg.tie_embeddings else
                   Linear.create(kh, cfg.d_model, cfg.vocab, dtype=dt))
        return TransformerLM(
            embed=Embedding.create(ke, cfg.vocab, cfg.d_model, dtype=dt),
            blocks=blocks,
            final_norm=RMSNorm.create(cfg.d_model, dtype=dt),
            lm_head=lm_head,
            n_layers=cfg.n_layers,
            remat=remat,
        )

    # -- forward --------------------------------------------------------------

    def _head(self, x):
        return self.embed.attend(x) if self.lm_head is None else self.lm_head(x)

    def __call__(self, tokens: jax.Array):
        """tokens: (batch, seq) -> logits (batch, seq, vocab), aux loss."""
        x = constrain_acts(self.embed(tokens))

        def body(carry, blk):
            x, aux = carry
            fn = (lambda b, xx: b(xx))
            if self.remat:
                fn = jax.checkpoint(fn)
            y, a = fn(blk, x)
            return (constrain_acts(y), aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   self.blocks)
        return self._head(self.final_norm(x)), aux / self.n_layers

    # -- serving --------------------------------------------------------------

    def cache_kind(self, cfg: ArchConfig) -> str:
        """Capability probe for ``repro.serve.ContinuousEngine``: which
        per-slot state family this model serves with.  Global-attention
        configs are ``"kv"`` (paged or dense per-slot lanes); sliding-
        window configs are ``"ring"`` (per-slot ring lanes — O(window)
        decode memory, cannot be paged or prefix-cached)."""
        return "ring" if cfg.window else "kv"

    def init_cache(self, batch: int, max_len: int, cfg: ArchConfig,
                   dtype=jnp.bfloat16, per_slot: bool = False) -> KVCache:
        """``per_slot=True`` gives each batch row its own length counter
        (shape ``(n_layers, batch)``) so rows decode at independent
        positions — the continuous-batching cache layout."""
        w = cfg.window
        slots = min(max_len, w) if w else max_len
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        lshape = (self.n_layers, batch) if per_slot else (self.n_layers,)
        return KVCache(
            k=jnp.zeros((self.n_layers, batch, slots, kvh, hd), dtype),
            v=jnp.zeros((self.n_layers, batch, slots, kvh, hd), dtype),
            length=jnp.zeros(lshape, jnp.int32),
        )

    def init_paged_cache(self, batch: int, max_len: int, cfg: ArchConfig, *,
                         n_blocks: int, block_size: int,
                         dtype=jnp.bfloat16) -> PagedKVCache:
        """Shared KV block pool + per-slot block tables.

        Pool k/v carry a leading layer dim (``(n_layers, n_blocks,
        block_size, kvh, hd)``) and per-layer lengths scan with the blocks;
        the block table is layer-invariant (every layer mirrors the same
        allocation) so it is stored once and closed over by the decode
        scan.  Unmapped table entries hold the sentinel ``n_blocks``."""
        if cfg.window:
            raise UnsupportedCacheError(
                "paged KV cache requires global attention (cfg.window == 0)",
                roadmap_item="ring-buffer (sliding-window) caches in "
                "per-slot mode so hymba-family models can serve "
                "continuously")
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        max_table = -(-max_len // block_size)
        return PagedKVCache(
            k=jnp.zeros((self.n_layers, n_blocks, block_size, kvh, hd),
                        dtype),
            v=jnp.zeros((self.n_layers, n_blocks, block_size, kvh, hd),
                        dtype),
            table=jnp.full((batch, max_table), n_blocks, jnp.int32),
            length=jnp.zeros((self.n_layers, batch), jnp.int32),
        )

    def prefill(self, tokens: jax.Array, cache: KVCache, *,
                length: Optional[jax.Array] = None):
        """Returns logits for the LAST position + the filled cache.

        ``length`` (scalar or ``(batch,)`` int32) marks the true prompt
        length of right-padded prompts: logits are taken at ``length - 1``
        and the returned cache's counters are set to ``length`` so decode
        resumes there.  Sound for causal self-attention — padded positions
        never influence positions ``< length``, and decode overwrites each
        padded cache row before it becomes visible."""
        x = constrain_acts(self.embed(tokens))

        def body(x, xs):
            blk, c = xs
            fn = (lambda b, xx, cc: b.prefill(xx, cc))
            if self.remat:
                fn = jax.checkpoint(fn)
            y, c2 = fn(blk, x, c)
            return constrain_acts(y), c2

        x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        # per-layer Attention.prefill emits scalar lengths; restore the INPUT
        # cache's layout (per-slot caches keep their (n_layers, batch) shape)
        if length is None:
            if cache.length.ndim == 2:
                new_cache = new_cache._replace(length=jnp.broadcast_to(
                    new_cache.length[:, None], cache.length.shape))
            return self._head(self.final_norm(x[:, -1:])), new_cache
        idx = jnp.asarray(length, jnp.int32)
        if idx.ndim == 1 and cache.length.ndim != 2:
            raise ValueError(
                "(batch,) prefill length requires a per_slot=True cache "
                f"(cache.length is {cache.length.shape})")
        rows = idx if idx.ndim else jnp.full((tokens.shape[0],), idx)
        last = jnp.take_along_axis(x, (rows - 1)[:, None, None], axis=1)
        logits = self._head(self.final_norm(last))
        # scalar broadcasts over any layout; (batch,) fans out over layers
        new_len = jnp.broadcast_to(idx if idx.ndim == 0 else idx[None, :],
                                   cache.length.shape)
        return logits, new_cache._replace(length=new_len)

    def prefill_chunk(self, tokens: jax.Array, cache, *, slot: jax.Array,
                      offset: jax.Array, n_valid: jax.Array,
                      dst: Optional[jax.Array] = None,
                      need_logits: bool = True,
                      prefill_kernel: str = "reference"):
        """Consume one bucket-padded prompt chunk for slot ``slot``.

        ``tokens``: (1, W) int32 — ``n_valid`` real tokens starting at
        absolute position ``offset``, right-padded to the bucket width W.
        Works on both serving cache layouts (per-slot dense
        :class:`KVCache` and :class:`PagedKVCache`; for the paged layout
        ``dst`` carries the flat pool row per chunk position, sentinel for
        padding/cached-prefix positions — see
        :meth:`repro.nn.attention.Attention.prefill_chunk`).
        ``prefill_kernel`` picks the chunk attention implementation per
        layer (``"reference"`` dense gather vs ``"pallas"`` flash
        prefill-chunk kernel — see the same method).

        Returns ``(logits (1, vocab) at the chunk's LAST valid position,
        updated cache)`` — the engine only samples from the logits of a
        prompt's FINAL chunk, so it traces earlier chunks with
        ``need_logits=False`` (trace-time constant) and the final-norm +
        vocab-projection matmul drops out of the mid-prompt chunks
        entirely; those calls return ``(None, cache)``.
        """
        x = constrain_acts(self.embed(tokens))
        kw = dict(slot=slot, offset=offset, n_valid=n_valid,
                  prefill_kernel=prefill_kernel)

        if isinstance(cache, PagedKVCache):
            table = cache.table

            def body(x, xs):
                blk, (k, v, ln) = xs
                y, c2 = blk.prefill_chunk(x, PagedKVCache(k, v, table, ln),
                                          dst=dst, **kw)
                return constrain_acts(y), (c2.k, c2.v, c2.length)

            x, (k, v, ln) = jax.lax.scan(
                body, x, (self.blocks, (cache.k, cache.v, cache.length)))
            new_cache = PagedKVCache(k, v, table, ln)
        else:
            def body(x, xs):
                blk, c = xs
                y, c2 = blk.prefill_chunk(x, c, **kw)
                return constrain_acts(y), c2

            x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        if not need_logits:
            return None, new_cache
        last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
        return self._head(self.final_norm(last))[:, 0], new_cache

    def decode(self, token: jax.Array, cache, *,
               decode_kernel: str = "reference"):
        """token: (batch, s) -> logits (batch, s, vocab) + updated cache.

        ``s == 1`` is the ordinary autoregressive step; ``s > 1`` is the
        multi-token step speculative verification uses (position ``j``
        attends rows ``<= pos + j``, so the logits equal a sequential
        ``s``-step decode's — see :meth:`repro.nn.attention.Attention.
        decode`).

        Accepts a dense :class:`KVCache` or a :class:`PagedKVCache`; for the
        paged layout the block table is shared across layers, so only the
        pool k/v and per-layer lengths ride through the layer scan, and
        ``decode_kernel`` picks the paged attention implementation
        (``"reference"`` dense gather vs ``"pallas"`` fused kernel — see
        :meth:`repro.nn.attention.Attention.decode`)."""
        x = constrain_acts(self.embed(token))

        if isinstance(cache, PagedKVCache):
            table = cache.table

            def body(x, xs):
                blk, (k, v, ln) = xs
                y, c2 = blk.decode(x, PagedKVCache(k, v, table, ln),
                                   decode_kernel=decode_kernel)
                return constrain_acts(y), (c2.k, c2.v, c2.length)

            x, (k, v, ln) = jax.lax.scan(
                body, x, (self.blocks, (cache.k, cache.v, cache.length)))
            return self._head(self.final_norm(x)), PagedKVCache(k, v, table,
                                                                ln)

        def body(x, xs):
            blk, c = xs
            y, c2 = blk.decode(x, c)
            return constrain_acts(y), c2

        x, new_cache = jax.lax.scan(body, x, (self.blocks, cache))
        return self._head(self.final_norm(x)), new_cache
