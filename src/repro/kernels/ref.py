"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def led_matmul_ref(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """y = (x @ A) @ B with fp32 accumulation.

    x: (..., K); a: (K, R); b: (R, N) -> y: (..., N) in x.dtype.
    """
    t = jnp.dot(x.astype(jnp.float32), a.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = jnp.dot(t, b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return y.astype(x.dtype)
