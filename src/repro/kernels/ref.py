"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


NEG_INF = -1e30  # must stay equal to repro.nn.attention.NEG_INF (see there)


def led_matmul_ref(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """y = (x @ A) @ B with fp32 accumulation.

    x: (..., K); a: (..., K, R); b: (..., R, N) -> y: (..., N) in x.dtype.
    a/b may carry leading stack axes (the shapes auto_fact emits for
    layer-scanned or expert-stacked weights); ``matmul`` broadcasting pairs
    them with x's leading axes, exactly like the ``(x @ A) @ B`` the LED
    layer computes.
    """
    t = jnp.matmul(x.astype(jnp.float32), a.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    y = jnp.matmul(t, b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        table: jax.Array, pos: jax.Array) -> jax.Array:
    """Pure-jnp oracle for :func:`repro.kernels.paged_attention`.

    Materializes the dense gather the fused kernel avoids, then runs
    masked single-query attention with the exact kernel semantics: fp32
    accumulation, ``kpos <= pos`` and sentinel-block masking, and a
    guarded division so a fully-masked slot yields zeros (``jax.nn.
    softmax`` would yield uniform weights there instead).

    q: (batch, heads, head_dim); k/v_pool: (n_blocks, block_size,
    kv_heads, head_dim); table: (batch, max_table) int32 with sentinel
    ``n_blocks``; pos: (batch,) int32 -> (batch, heads, head_dim).
    """
    batch, heads, hd = q.shape
    n_blocks, bs, kvh, _ = k_pool.shape
    group = heads // kvh
    n_table = table.shape[1]
    kpos = jnp.arange(n_table * bs)
    safe = jnp.minimum(table, n_blocks - 1)  # clamp sentinel for the gather
    rows = safe[:, kpos // bs] * bs + (kpos % bs)[None, :]
    gk = k_pool.reshape(n_blocks * bs, kvh, hd)[rows].astype(jnp.float32)
    gv = v_pool.reshape(n_blocks * bs, kvh, hd)[rows].astype(jnp.float32)
    valid = ((kpos[None, :] <= pos[:, None])
             & (table[:, kpos // bs] != n_blocks))  # (batch, S)
    qf = q.astype(jnp.float32).reshape(batch, kvh, group, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, gk) / jnp.sqrt(
        jnp.float32(hd))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(logits - m), 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", p, gv) / jnp.maximum(
        p.sum(-1, keepdims=True), 1e-30)
    return out.reshape(batch, heads, hd).astype(q.dtype)
