"""Pure-jnp oracles for the Pallas kernels (the correctness reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


NEG_INF = -1e30  # must stay equal to repro.nn.attention.NEG_INF (see there)


def led_matmul_ref(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """y = (x @ A) @ B with fp32 accumulation.

    x: (..., K); a: (..., K, R); b: (..., R, N) -> y: (..., N) in x.dtype.
    a/b may carry leading stack axes (the shapes auto_fact emits for
    layer-scanned or expert-stacked weights); ``matmul`` broadcasting pairs
    them with x's leading axes, exactly like the ``(x @ A) @ B`` the LED
    layer computes.
    """
    t = jnp.matmul(x.astype(jnp.float32), a.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    y = jnp.matmul(t, b.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        table: jax.Array, pos: jax.Array) -> jax.Array:
    """Pure-jnp oracle for :func:`repro.kernels.paged_attention`.

    Materializes the dense gather the fused kernel avoids, then runs
    masked single-query attention with the exact kernel semantics: fp32
    accumulation, ``kpos <= pos`` and sentinel-block masking, and a
    guarded division so a fully-masked slot yields zeros (``jax.nn.
    softmax`` would yield uniform weights there instead).

    q: (batch, heads, head_dim); k/v_pool: (n_blocks, block_size,
    kv_heads, head_dim); table: (batch, max_table) int32 with sentinel
    ``n_blocks``; pos: (batch,) int32 -> (batch, heads, head_dim).
    """
    batch, heads, hd = q.shape
    n_blocks, bs, kvh, _ = k_pool.shape
    group = heads // kvh
    n_table = table.shape[1]
    kpos = jnp.arange(n_table * bs)
    safe = jnp.minimum(table, n_blocks - 1)  # clamp sentinel for the gather
    rows = safe[:, kpos // bs] * bs + (kpos % bs)[None, :]
    gk = k_pool.reshape(n_blocks * bs, kvh, hd)[rows].astype(jnp.float32)
    gv = v_pool.reshape(n_blocks * bs, kvh, hd)[rows].astype(jnp.float32)
    valid = ((kpos[None, :] <= pos[:, None])
             & (table[:, kpos // bs] != n_blocks))  # (batch, S)
    qf = q.astype(jnp.float32).reshape(batch, kvh, group, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, gk) / jnp.sqrt(
        jnp.float32(hd))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(logits - m), 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", p, gv) / jnp.maximum(
        p.sum(-1, keepdims=True), 1e-30)
    return out.reshape(batch, heads, hd).astype(q.dtype)


def chunk_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        table_row: jax.Array, k_chunk: jax.Array,
                        v_chunk: jax.Array, offset: jax.Array,
                        n_valid: jax.Array) -> jax.Array:
    """Pure-jnp oracle for :func:`repro.kernels.chunk_attention`.

    One slot's bucket-padded prompt chunk attends (a) the resident paged
    prefix — dense gather of the slot's logical lane through its block
    table, masked ``kpos < offset`` plus sentinel-block masking — and
    (b) the chunk's own fresh K/V under the in-chunk causal + padding
    mask ``(j <= r) & (j < n_valid)`` (query row ``r`` sits at absolute
    position ``offset + r``, so together the two halves reproduce the
    ``kpos <= qpos`` masking of the dense ``prefill_chunk`` gather for
    every valid row).  fp32 accumulation, guarded division.

    q: (W, heads, head_dim); k/v_pool: (n_blocks, block_size, kv_heads,
    head_dim); table_row: (max_table,) int32 with sentinel ``n_blocks``;
    k/v_chunk: (W, kv_heads, head_dim); offset/n_valid: () int32
    -> (W, heads, head_dim).
    """
    w, heads, hd = q.shape
    n_blocks, bs, kvh, _ = k_pool.shape
    group = heads // kvh
    n_table = table_row.shape[0]
    kpos = jnp.arange(n_table * bs)
    safe = jnp.minimum(table_row, n_blocks - 1)  # clamp sentinel for gather
    rows = safe[kpos // bs] * bs + kpos % bs
    gk = k_pool.reshape(n_blocks * bs, kvh, hd)[rows].astype(jnp.float32)
    gv = v_pool.reshape(n_blocks * bs, kvh, hd)[rows].astype(jnp.float32)
    prefix_valid = (kpos < offset) & (table_row[kpos // bs] != n_blocks)
    j = jnp.arange(w)
    chunk_valid = (j[None, :] <= j[:, None]) & (j[None, :] < n_valid)
    qf = q.astype(jnp.float32).reshape(w, kvh, group, hd)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    lp = jnp.einsum("wkgd,skd->wkgs", qf, gk) * scale
    lc = jnp.einsum("wkgd,jkd->wkgj", qf,
                    k_chunk.astype(jnp.float32)) * scale
    valid = jnp.concatenate(
        [jnp.broadcast_to(prefix_valid[None, :], (w, n_table * bs)),
         chunk_valid], axis=-1)[:, None, None, :]  # (W, 1, 1, S+W)
    logits = jnp.where(valid, jnp.concatenate([lp, lc], axis=-1), NEG_INF)
    m = logits.max(-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(logits - m), 0.0)
    av = jnp.concatenate([gv, v_chunk.astype(jnp.float32)], axis=0)
    out = jnp.einsum("wkgs,skd->wkgd", p, av) / jnp.maximum(
        p.sum(-1, keepdims=True), 1e-30)
    return out.reshape(w, heads, hd).astype(q.dtype)
