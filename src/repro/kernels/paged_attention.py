"""Fused Pallas paged-attention decode kernel.

Single-query (decode-step) attention straight against the paged KV layout
of :class:`repro.nn.attention.PagedKVCache`: the shared block pool, the
per-slot block tables, and the per-slot positions.  The dense-gather
baseline first materializes a ``(batch, max_len, kv_heads, head_dim)``
view of every slot's cache (``pool[table[b, p // bs] * bs + p % bs]``)
and then runs masked attention over it — a full HBM round-trip of the
whole gathered cache per decode step.  This kernel fuses the gather into
a flash-style online-softmax loop: KV blocks stream from the pool into
VMEM one at a time (the block table is a scalar-prefetch operand, so each
grid step's DMA source is ``table[b, i]`` directly) and the dense view
never exists.

Grid layout: ``(b over slots, kh over KV heads, i over table entries)``,
all sequential ("arbitrary") so the per-(b, kh) running max / sum /
accumulator scratch persists across the ``i`` steps:

  * ``i == 0``: zero the online-softmax carry.
  * every ``i``: fetch pool block ``table[b, i]`` (clamped to a real row
    — the unmapped sentinel ``n_blocks`` is masked in-kernel instead),
    accumulate ``softmax(q k^T / sqrt(d)) v`` for the ``group =
    heads // kv_heads`` query heads that share KV head ``kh``.
  * ``i == last``: emit the normalized output block.

Masking happens in-kernel, mirroring the dense-gather semantics:
positions ``kpos > pos[b]`` (ragged per-slot lengths) and blocks whose
table entry is the sentinel (never mapped, or released after eviction)
contribute exactly zero.  A fully-masked slot (e.g. an idle decode slot
whose table was released) emits zeros via the guarded division rather
than NaN.

GQA/MQA fall out of the layout: ``q`` is reshaped to ``(batch, kv_heads,
group, head_dim)`` and each grid step attends one KV head's query group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.led_matmul import _CompilerParams
from repro.kernels.ops import default_interpret
from repro.kernels.ref import NEG_INF  # one mask fill value, kernel == oracle


def _paged_attn_kernel(table_ref, pos_ref,  # scalar prefetch
                       q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *,
                       block_size: int, n_blocks: int, n_table: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # (group, head_dim)
    k = k_ref[0, :, 0].astype(jnp.float32)     # (block_size, head_dim)
    v = v_ref[0, :, 0].astype(jnp.float32)
    logits = jax.lax.dot_general(               # (group, block_size)
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) / jnp.sqrt(
            jnp.float32(q.shape[-1]))
    pos = pos_ref[b]
    bid = table_ref[b, i]
    kpos = i * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = (kpos <= pos) & (bid != n_blocks)
    logits = jnp.where(valid, logits, NEG_INF)
    m_prev = m_ref[...]                         # (group, 1)
    m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
    # the explicit where (not just the NEG_INF fill) matters: while every
    # block so far is masked, m_new == NEG_INF and exp(logits - m_new)
    # would be exp(0) == 1 on the masked lanes
    p = jnp.where(valid, jnp.exp(logits - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(i == n_table - 1)
    def _emit():
        # guarded division: a fully-masked slot (all-sentinel table) has
        # l == 0 and must emit zeros, not NaN
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_attention_call(q4, k_pool, v_pool, table, pos, *,
                          interpret: bool):
    batch, kvh, group, hd = q4.shape
    n_blocks, bs = k_pool.shape[:2]
    n_table = table.shape[1]

    def kv_map(b, kh, i, table_ref, pos_ref):
        # sentinel entries (n_blocks, one past the pool) are clamped to a
        # real block for the fetch; the kernel masks their lanes to zero
        return (jnp.minimum(table_ref[b, i], n_blocks - 1), 0, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch, kvh, n_table),
        in_specs=[
            pl.BlockSpec((1, 1, group, hd),
                         lambda b, kh, i, t, p: (b, kh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b, kh, i, t, p: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),   # running max
            pltpu.VMEM((group, 1), jnp.float32),   # running sum
            pltpu.VMEM((group, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, block_size=bs,
                          n_blocks=n_blocks, n_table=n_table),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, kvh, group, hd), q4.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(table, pos, q4, k_pool, v_pool)


def paged_attention(
    q: jax.Array,       # (batch, heads, head_dim) — the one decode query
    k_pool: jax.Array,  # (n_blocks, block_size, kv_heads, head_dim)
    v_pool: jax.Array,  # (n_blocks, block_size, kv_heads, head_dim)
    table: jax.Array,   # (batch, max_table) int32; sentinel == n_blocks
    pos: jax.Array,     # (batch,) int32 — query position; attends kpos <= pos
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused single-query attention against the paged KV pool.

    Returns ``(batch, heads, head_dim)`` in ``q.dtype``.  Semantics match
    :func:`repro.kernels.ref.paged_attention_ref` (same masking, fp32
    accumulation); vs the dense-gather baseline the only difference is
    online-softmax float ordering.  ``interpret=None`` auto-selects
    interpret mode off-TPU (see :func:`repro.kernels.ops.default_interpret`).
    """
    if interpret is None:
        interpret = default_interpret()
    batch, heads, hd = q.shape
    n_blocks, bs, kvh, hd_k = k_pool.shape
    if hd_k != hd or v_pool.shape != k_pool.shape:
        raise ValueError(
            f"pool/query shape mismatch: q {q.shape}, k {k_pool.shape}, "
            f"v {v_pool.shape}")
    if heads % kvh:
        raise ValueError(f"heads {heads} not a multiple of kv_heads {kvh}")
    if table.shape[0] != batch or pos.shape != (batch,):
        raise ValueError(
            f"table {table.shape} / pos {pos.shape} do not match batch "
            f"{batch}")
    q4 = q.reshape(batch, kvh, heads // kvh, hd)
    out = _paged_attention_call(q4, k_pool, v_pool,
                                table.astype(jnp.int32),
                                pos.astype(jnp.int32), interpret=interpret)
    return out.reshape(batch, heads, hd)


__all__ = ["paged_attention"]
