"""jit'd public wrappers around the Pallas kernels.

``led_matmul`` accepts arbitrary leading batch axes, pads every matmul dim up
to the block grid, dispatches to the fused kernel, and slices the result
back.  On non-TPU backends (this container is CPU-only) it runs the kernel in
``interpret=True`` mode so tests exercise the *same* kernel body everywhere.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.led_matmul import led_matmul_2d
from repro.kernels.ref import led_matmul_ref


def default_interpret() -> bool:
    """Shared interpret-mode policy for every Pallas kernel in the repo.

    Off-TPU backends (this container is CPU-only) run the *same* kernel
    bodies in ``interpret=True`` mode so tests exercise them everywhere;
    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode even on TPU (the
    CI ``kernels-interpret`` job sets it so kernel regressions are caught
    without hardware)."""
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") not in ("", "0"):
        return True
    return jax.default_backend() != "tpu"


def _pad_to(v: int, b: int) -> int:
    return (-v) % b


def led_matmul(
    x: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused ``(x @ A) @ B``. x: (..., K); a: (..., K, R); b: (..., R, N).

    a/b may carry matching leading stack axes (layer-scanned or
    expert-stacked auto_fact weights); each stack slice must pair with the
    same-index leading axis of x, and the 2D kernel is vmapped over them.
    """
    if interpret is None:
        interpret = default_interpret()
    if a.ndim > 2:
        if a.shape[:-2] != b.shape[:-2]:
            raise ValueError(
                f"stack axes of a {a.shape} and b {b.shape} must match")
        if x.shape[: a.ndim - 2] != a.shape[:-2]:
            raise ValueError(
                f"x leading axes {x.shape} must match stack axes {a.shape}")
        return jax.vmap(
            lambda xx, aa, bb: led_matmul(
                xx, aa, bb, block_m=block_m, block_n=block_n,
                block_k=block_k, interpret=interpret))(x, a, b)
    *lead, kdim = x.shape
    r = a.shape[-1]
    n = b.shape[-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, kdim)

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, kdim)
    pm, pn, pk = _pad_to(m, bm), _pad_to(n, bn), _pad_to(kdim, bk)
    xp = jnp.pad(x2, ((0, pm), (0, pk))) if (pm or pk) else x2
    ap = jnp.pad(a, ((0, pk), (0, 0))) if pk else a
    bp = jnp.pad(b, ((0, 0), (0, pn))) if pn else b

    y = led_matmul_2d(xp, ap, bp, block_m=bm, block_n=bn, block_k=bk,
                      interpret=interpret)
    if pm or pn:
        y = y[:m, :n]
    return y.reshape(*lead, n)


# ---------------------------------------------------------------------------
# Differentiable wrapper: the Pallas kernel is forward-only, so training
# uses a custom VJP whose backward re-derives the three low-rank gradients —
# and dx = (dy @ Bᵀ) @ Aᵀ is itself a low-rank matmul, so it reuses the
# fused kernel.  dA/dB recompute the rank-r intermediate (cheap: M·R) rather
# than saving it (the kernel's whole point is never materializing it in HBM).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def led_matmul_trainable(x, a, b):
    return led_matmul(x, a, b)


def _led_fwd(x, a, b):
    return led_matmul(x, a, b), (x, a, b)


def _led_bwd(res, dy):
    x, a, b = res
    if a.ndim > 2:
        # stacked factors: the hand-derived gradients below are 2D-only, so
        # fall back to autodiff through the (stack-aware) jnp oracle
        _, vjp = jax.vjp(led_matmul_ref, x, a, b)
        return vjp(dy)
    *lead, kdim = x.shape
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, kdim).astype(jnp.float32)
    dy2 = dy.reshape(m, b.shape[-1]).astype(jnp.float32)
    dt = dy2 @ b.astype(jnp.float32).T  # (M, R)
    da = (x2.T @ dt).astype(a.dtype)
    t = x2 @ a.astype(jnp.float32)  # recomputed rank-r intermediate
    db = (t.T @ dy2).astype(b.dtype)
    dx = led_matmul(dy, jnp.swapaxes(b, -1, -2),
                    jnp.swapaxes(a, -1, -2))  # fused low-rank backward
    return dx.reshape(x.shape).astype(x.dtype), da, db


led_matmul_trainable.defvjp(_led_fwd, _led_bwd)

__all__ = ["default_interpret", "led_matmul", "led_matmul_ref",
           "led_matmul_trainable"]
