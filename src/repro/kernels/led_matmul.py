"""Fused LED (low-rank) matmul Pallas TPU kernel: ``y = (x @ A) @ B``.

TPU-native adaptation of the paper's LED layer (DESIGN.md §2): executed as
two back-to-back dense matmuls, the rank-``r`` intermediate ``t = x @ A``
round-trips through HBM (2·M·R·bytes of traffic) and the second matmul
launches from cold VMEM.  This kernel fuses both GEMMs so ``t`` lives in a
**VMEM scratch accumulator** and never touches HBM.

Grid layout: ``(i over M tiles, j over N tiles, k over K tiles)``, all
sequential ("arbitrary") so the scratch persists across steps:

  * ``j == 0``: accumulate ``t[i] += x[i,k] @ A[k]`` over the k-steps
    (fp32 accumulation on the MXU).
  * ``k == last``: emit ``y[i,j] = t[i] @ B[j]``.
  * ``j > 0``: the x/A index maps freeze at their last block, so Pallas'
    revisiting optimization skips the HBM→VMEM copies; only ``B[j]`` streams.

Block shapes default to MXU-aligned (multiples of 128 on the matmul dims);
``R`` (the rank, ≤ a few hundred by construction — Greenformer's r_max gate)
stays resident as a whole.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer jax; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))
if _CompilerParams is None:  # fail at import, not at first kernel call
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version")


def _led_kernel(x_ref, a_ref, b_ref, y_ref, t_ref, *, n_k: int):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    @pl.when(j == 0)
    def _accumulate():
        t_ref[...] += jnp.dot(
            x_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        y_ref[...] = jnp.dot(
            t_ref[...], b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32).astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def led_matmul_2d(
    x: jax.Array,  # (M, K)
    a: jax.Array,  # (K, R)
    b: jax.Array,  # (R, N)
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    m, kdim = x.shape
    _, r = a.shape
    _, n = b.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, kdim)
    if m % bm or n % bn or kdim % bk:
        raise ValueError(
            f"led_matmul_2d requires divisible dims, got M={m}%{bm} "
            f"N={n}%{bn} K={kdim}%{bk} (pad in ops.led_matmul)")
    n_i, n_j, n_k = m // bm, n // bn, kdim // bk

    def x_map(i, j, k):
        # freeze at the last k-block once j > 0 → revisiting skips the copy
        return (i, jnp.where(j == 0, k, n_k - 1))

    def a_map(i, j, k):
        return (jnp.where(j == 0, k, n_k - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(n_i, n_j, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk, r), a_map),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_led_kernel, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, a, b)
