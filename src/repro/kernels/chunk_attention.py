"""Flash-style Pallas prefill-chunk attention kernel.

One prompt chunk of ``W`` bucket-padded tokens for ONE serving slot
attends against (a) the slot's resident KV prefix — everything below
``offset``, streamed block-by-block from the paged pool through the
slot's block table — and (b) the chunk's own fresh K/V rows under an
in-chunk causal + padding mask.  This is exactly the attention
:meth:`repro.nn.attention.Attention.prefill_chunk` computes for its
valid rows, minus the dense gather: the reference path first
materializes the whole ``(max_table * block_size, kv_heads, head_dim)``
logical lane per chunk (a full HBM round-trip of the slot's cache for
every chunk of every prompt), while here prefix blocks stream through
VMEM inside an online-softmax loop and the gathered view never exists.

Grid layout: ``(kh over KV heads, i over table entries + 1)``, both
sequential ("arbitrary") so the per-``kh`` running max / sum /
accumulator scratch persists across the ``i`` steps:

  * ``i == 0``: zero the online-softmax carry.
  * ``i < n_table``: fetch pool block ``table[i]`` (sentinel entries are
    clamped to a real row for the DMA and masked in-kernel) and
    accumulate the prefix half under ``kpos < offset`` — strictly below
    the chunk, so the mask needs no per-query term (``kpos < offset <=
    qpos`` for every chunk row).  Blocks entirely at/past ``offset``
    are skipped (``pl.when``), so a short prefix pays for the blocks it
    has, not for ``max_table``.
  * ``i == n_table``: accumulate the chunk's fresh K/V under the
    offset-relative causal + padding mask ``(j <= r) & (j < n_valid)``
    (query row ``r`` sits at absolute position ``offset + r``), then
    emit the normalized output.

Padding rows (``r >= n_valid``) attend only the prefix and their own
in-chunk causal span — NOT whatever stale pool bytes the reference
gather happens to see past the write frontier — so their outputs differ
from the reference; they are discarded by construction (the engine
samples only the last *valid* row's logits, and padding rows' K/V
scatter to the drop sentinel).  Parity is asserted on rows
``< n_valid``, and a fully-masked row emits zeros via the guarded
division rather than NaN.

GQA/MQA fall out of the layout: ``q`` is reshaped to ``(kv_heads,
W * group, head_dim)`` (row ``r = w * group + g``) and each grid step
attends one KV head's query block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.led_matmul import _CompilerParams
from repro.kernels.ops import default_interpret
from repro.kernels.ref import NEG_INF  # one mask fill value, kernel == oracle


def _chunk_attn_kernel(table_ref, meta_ref,  # scalar prefetch
                       q_ref, kp_ref, vp_ref, kc_ref, vc_ref, o_ref,
                       m_ref, l_ref, acc_ref, *,
                       block_size: int, n_blocks: int, n_table: int,
                       group: int):
    i = pl.program_id(1)
    off = meta_ref[0]
    n_valid = meta_ref[1]
    q = q_ref[0].astype(jnp.float32)            # (W*group, head_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def accumulate(k, v, valid):
        """One online-softmax step over ``k``/``v``: (L, hd), valid (Wg, L)."""
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        logits = jnp.where(valid, logits, NEG_INF)
        m_prev = m_ref[...]                     # (Wg, 1)
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        # the explicit where (not just the NEG_INF fill) matters: while
        # every key so far is masked, m_new == NEG_INF and
        # exp(logits - m_new) would be exp(0) == 1 on the masked lanes
        p = jnp.where(valid, jnp.exp(logits - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # prefix half: resident pool blocks, strictly below the chunk.  Blocks
    # at/past the offset hold nothing this chunk may attend — skip them.
    @pl.when((i < n_table) & (i * block_size < off))
    def _prefix():
        k = kp_ref[0, :, 0].astype(jnp.float32)  # (block_size, head_dim)
        v = vp_ref[0, :, 0].astype(jnp.float32)
        bid = table_ref[jnp.minimum(i, n_table - 1)]
        kpos = i * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)
        valid = (kpos < off) & (bid != n_blocks)  # (1, bs) -> broadcast
        accumulate(k, v, jnp.broadcast_to(valid, (m_ref.shape[0],
                                                  block_size)))

    # chunk half: the fresh K/V under the in-chunk causal + padding mask,
    # then emit (last grid step, carry complete)
    @pl.when(i == n_table)
    def _chunk():
        k = kc_ref[0].astype(jnp.float32)        # (W, head_dim)
        v = vc_ref[0].astype(jnp.float32)
        wg, w = m_ref.shape[0], k.shape[0]
        r = jax.lax.broadcasted_iota(jnp.int32, (wg, w), 0) // group
        j = jax.lax.broadcasted_iota(jnp.int32, (wg, w), 1)
        accumulate(k, v, (j <= r) & (j < n_valid))
        # guarded division: a fully-masked row (offset == 0 padding row
        # attending nothing) emits zeros, not NaN
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _chunk_attention_call(q3, k_pool, v_pool, k_chunk3, v_chunk3, table_row,
                          meta, *, interpret: bool):
    kvh, wg, hd = q3.shape
    n_blocks, bs = k_pool.shape[:2]
    w = k_chunk3.shape[1]
    n_table = table_row.shape[0]

    def kv_map(kh, i, table_ref, meta_ref):
        # sentinel entries (n_blocks, one past the pool) are clamped to a
        # real block for the fetch; the kernel masks their lanes to zero.
        # The final grid step (the chunk half) never reads the pool refs —
        # clamp its index into range for the prefetch DMA.
        safe_i = jnp.minimum(i, n_table - 1)
        return (jnp.minimum(table_ref[safe_i], n_blocks - 1), 0, kh, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(kvh, n_table + 1),
        in_specs=[
            pl.BlockSpec((1, wg, hd), lambda kh, i, t, m: (kh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, w, hd), lambda kh, i, t, m: (kh, 0, 0)),
            pl.BlockSpec((1, w, hd), lambda kh, i, t, m: (kh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, wg, hd), lambda kh, i, t, m: (kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((wg, 1), jnp.float32),   # running max
            pltpu.VMEM((wg, 1), jnp.float32),   # running sum
            pltpu.VMEM((wg, hd), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_chunk_attn_kernel, block_size=bs,
                          n_blocks=n_blocks, n_table=n_table,
                          group=wg // w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kvh, wg, hd), q3.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(table_row, meta, q3, k_pool, v_pool, k_chunk3, v_chunk3)


def chunk_attention(
    q: jax.Array,          # (W, heads, head_dim) — one slot's chunk queries
    k_pool: jax.Array,     # (n_blocks, block_size, kv_heads, head_dim)
    v_pool: jax.Array,     # (n_blocks, block_size, kv_heads, head_dim)
    table_row: jax.Array,  # (max_table,) int32 — ONE slot's block table
    k_chunk: jax.Array,    # (W, kv_heads, head_dim) — the chunk's fresh K
    v_chunk: jax.Array,    # (W, kv_heads, head_dim)
    offset: jax.Array,     # () int32 — absolute position of chunk row 0
    n_valid: jax.Array,    # () int32 — real (non-padding) rows in the chunk
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused prefill-chunk attention against the paged KV pool.

    Returns ``(W, heads, head_dim)`` in ``q.dtype``.  Rows ``< n_valid``
    match :func:`repro.kernels.ref.chunk_attention_ref` and the dense
    gather in :meth:`repro.nn.attention.Attention.prefill_chunk` (same
    masking, fp32 accumulation; vs the gather the only difference is
    online-softmax float ordering).  Rows ``>= n_valid`` are padding and
    carry no contract.  ``interpret=None`` auto-selects interpret mode
    off-TPU (see :func:`repro.kernels.ops.default_interpret`).
    """
    if interpret is None:
        interpret = default_interpret()
    w, heads, hd = q.shape
    n_blocks, bs, kvh, hd_k = k_pool.shape
    if hd_k != hd or v_pool.shape != k_pool.shape:
        raise ValueError(
            f"pool/query shape mismatch: q {q.shape}, k {k_pool.shape}, "
            f"v {v_pool.shape}")
    if heads % kvh:
        raise ValueError(f"heads {heads} not a multiple of kv_heads {kvh}")
    if k_chunk.shape != (w, kvh, hd) or v_chunk.shape != (w, kvh, hd):
        raise ValueError(
            f"chunk K/V must be (W, kv_heads, head_dim) = {(w, kvh, hd)}; "
            f"got k {k_chunk.shape}, v {v_chunk.shape}")
    if table_row.ndim != 1:
        raise ValueError(
            f"table_row must be ONE slot's table (max_table,); got "
            f"{table_row.shape}")
    group = heads // kvh
    # (W, kvh, group, hd) -> (kvh, W*group, hd); row r = w_idx*group + g
    q3 = q.reshape(w, kvh, group, hd).transpose(1, 0, 2, 3).reshape(
        kvh, w * group, hd)
    kc3 = k_chunk.transpose(1, 0, 2)  # (kvh, W, hd)
    vc3 = v_chunk.transpose(1, 0, 2)
    meta = jnp.stack([jnp.asarray(offset, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32)])
    out = _chunk_attention_call(q3, k_pool, v_pool, kc3, vc3,
                                table_row.astype(jnp.int32), meta,
                                interpret=interpret)
    return out.reshape(kvh, w, group, hd).transpose(1, 0, 2, 3).reshape(
        w, heads, hd)


def chunk_attention_dense(
    q: jax.Array,       # (W, heads, head_dim)
    k_lane: jax.Array,  # (max_len, kv_heads, head_dim) — ONE slot's lane
    v_lane: jax.Array,
    k_chunk: jax.Array,  # (W, kv_heads, head_dim)
    v_chunk: jax.Array,
    offset: jax.Array,
    n_valid: jax.Array,
    *,
    block_size: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    """:func:`chunk_attention` over a dense per-slot lane.

    The lane is viewed as a single-slot pool with the identity block
    table (padded up to a ``block_size`` multiple; pad rows sit at
    ``kpos >= max_len > offset`` so the prefix mask drops them), which
    lets ONE kernel body serve both serving layouts — the dense/paged
    parity matrix pins the same code path on each.
    """
    max_len, kvh, hd = k_lane.shape
    bs = max(1, min(block_size, max_len))
    pad = (-max_len) % bs
    if pad:
        k_lane = jnp.pad(k_lane, ((0, pad), (0, 0), (0, 0)))
        v_lane = jnp.pad(v_lane, ((0, pad), (0, 0), (0, 0)))
    n_table = (max_len + pad) // bs
    k_pool = k_lane.reshape(n_table, bs, kvh, hd)
    v_pool = v_lane.reshape(n_table, bs, kvh, hd)
    table_row = jnp.arange(n_table, dtype=jnp.int32)
    return chunk_attention(q, k_pool, v_pool, table_row, k_chunk, v_chunk,
                           offset, n_valid, interpret=interpret)


__all__ = ["chunk_attention", "chunk_attention_dense"]
