"""Pallas TPU kernels for the serving/compute hot-spots.

* ``led_matmul`` — the paper's fused LED (low-rank) matmul ``(x @ A) @ B``
  (led_matmul.py kernel, ops.py jit wrappers + custom VJP).
* ``paged_attention`` — fused paged-attention decode: single-query
  attention streamed block-by-block from the shared KV pool through the
  per-slot block tables (paged_attention.py).
* ``chunk_attention`` — flash-style prefill-chunk attention: one slot's
  prompt chunk against its resident paged prefix + its own fresh K/V
  with offset-relative causal masking (chunk_attention.py;
  ``chunk_attention_dense`` serves the dense per-slot lane through the
  same kernel body via an identity block table).
* ``ref`` — pure-jnp oracles for all of them; the correctness references
  the interpret-mode CI matrix pins the kernels against (see README.md).
"""

from repro.kernels.chunk_attention import (chunk_attention,
                                           chunk_attention_dense)
from repro.kernels.ops import (default_interpret, led_matmul,
                               led_matmul_ref, led_matmul_trainable)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import chunk_attention_ref, paged_attention_ref

__all__ = ["chunk_attention", "chunk_attention_dense",
           "chunk_attention_ref", "default_interpret", "led_matmul",
           "led_matmul_ref", "led_matmul_trainable", "paged_attention",
           "paged_attention_ref"]
