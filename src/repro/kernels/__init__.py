"""Pallas TPU kernels for the serving/compute hot-spots.

* ``led_matmul`` — the paper's fused LED (low-rank) matmul ``(x @ A) @ B``
  (led_matmul.py kernel, ops.py jit wrappers + custom VJP).
* ``paged_attention`` — fused paged-attention decode: single-query
  attention streamed block-by-block from the shared KV pool through the
  per-slot block tables (paged_attention.py).
* ``ref`` — pure-jnp oracles for both; the correctness references the
  interpret-mode CI matrix pins the kernels against (see README.md).
"""

from repro.kernels.ops import (default_interpret, led_matmul,
                               led_matmul_ref, led_matmul_trainable)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref

__all__ = ["default_interpret", "led_matmul", "led_matmul_ref",
           "led_matmul_trainable", "paged_attention", "paged_attention_ref"]
