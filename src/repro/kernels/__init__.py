"""Pallas TPU kernels for the paper's compute hot-spot: the fused LED
(low-rank) matmul.  See led_matmul.py (kernel), ops.py (jit wrappers +
custom VJP), ref.py (pure-jnp oracle)."""

from repro.kernels.ops import led_matmul, led_matmul_ref, led_matmul_trainable

__all__ = ["led_matmul", "led_matmul_ref", "led_matmul_trainable"]
