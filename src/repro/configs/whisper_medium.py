"""whisper-medium [audio] — encoder-decoder, conv frontend stubbed:
input_specs() provides precomputed frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865, max_positions=65536,
    note="enc-dec; modality frontend is a stub (precomputed frame embeddings); "
         "LayerNorm+GeLU, learned positions",
)
