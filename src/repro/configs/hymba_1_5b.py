"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    ssm_state=16, ssm_head_dim=64, window=1024,
    supports_long_context=True,
    note="parallel attn+SSM heads; SWA ring-buffer KV (window=1024) + O(1) "
         "SSM state => long_500k applicable. Simplifications vs paper: no "
         "meta tokens, all layers SWA (global context via the SSM path)",
)
