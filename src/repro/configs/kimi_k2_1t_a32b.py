"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8.
[arXiv:2501.kimi2; unverified, paper-table]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840,
    n_experts=384, top_k=8, n_shared=1, capacity_factor=1.25,
    note="trillion-param MoE; d_ff is per-expert; 1 shared expert",
)
