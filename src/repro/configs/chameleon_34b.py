"""chameleon-34b [vlm] — early-fusion; VQ image tokens share the text
vocabulary, so the backbone is a dense decoder LM; the image tokenizer
frontend is a stub per the assignment. [arXiv:2405.09818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536,
    note="early-fusion VLM; VQ image tokens are ordinary vocab ids (stub)",
)
