"""Architecture + shape configuration system.

``ArchConfig`` is a plain frozen dataclass (NOT a pytree module — configs are
static).  Every assigned architecture registers itself via
``repro.configs.registry.register``; the CLI selects with ``--arch <id>``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class FactConfig:
    """Greenformer integration: factorization-by-design settings."""

    enabled: bool = False
    rank: float = 0.25  # int = absolute, float = ratio of r_max
    solver: str = "random"  # by-design default; 'svd'/'snmf' for post-training
    num_iter: int = 50
    submodules: Optional[Tuple[str, ...]] = None
    exclude: Optional[Tuple[str, ...]] = ("router", "lm_head", "embed")


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    window: int = 0  # sliding-window size for hybrid attn (0 = global)
    attn_chunk: int = 0  # >0: flash-style blockwise attention (O(chunk^2) temps)
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    max_positions: int = 4096  # learned-pos-embedding size (encdec only)
    # --- numerics / notes ---
    dtype: str = "bfloat16"
    supports_long_context: bool = False  # sub-quadratic decode path exists
    has_decode: bool = True
    note: str = ""
    # --- Greenformer ---
    fact: FactConfig = dataclasses.field(default_factory=FactConfig)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return self.replace(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared=min(self.n_shared, 1),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            window=min(self.window, 8) if self.window else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            max_positions=128,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decode:
        out.append("decode_32k")
        if cfg.supports_long_context:
            out.append("long_500k")
    return out
