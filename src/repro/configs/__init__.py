"""Config registry: ``get_config('<arch-id>')`` / ``--arch <arch-id>``."""

from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, FactConfig, ShapeConfig, SHAPES,
                                applicable_shapes)

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "yi-9b": "yi_9b",
    "granite-34b": "granite_34b",
    "glm4-9b": "glm4_9b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-medium": "whisper_medium",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "chameleon-34b": "chameleon_34b",
    "hymba-1.5b": "hymba_1_5b",
    "paper-tiny": "paper_tiny",
}

ARCH_IDS = [k for k in _MODULES if k != "paper-tiny"]


def get_config(name: str) -> ArchConfig:
    try:
        mod = _MODULES[name]
    except KeyError:
        raise ValueError(
            f"unknown arch {name!r}; available: {sorted(_MODULES)}") from None
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


__all__ = ["ArchConfig", "FactConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
           "applicable_shapes", "get_config"]
