"""The paper's own showcase scale: a small transformer for the Fig. 2
use-case benchmarks (trainable on CPU in minutes)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paper-tiny", family="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
    d_ff=1024, vocab=256, dtype="float32",
    note="paper Fig.2 reproduction scale",
)
