from repro.data.pipeline import DataLoader, place_batch
from repro.data.synthetic import (ClsBatch, ICLBatch, LMBatch,
                                  classification_batch, icl_batch,
                                  markov_entropy_floor, markov_lm_batch)

__all__ = ["DataLoader", "place_batch", "ClsBatch", "ICLBatch", "LMBatch",
           "classification_batch", "icl_batch", "markov_entropy_floor",
           "markov_lm_batch"]
