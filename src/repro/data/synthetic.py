"""Deterministic synthetic data pipelines.

Every batch is a pure function of ``(seed, step)`` — there is no iterator
state to checkpoint, so fault-tolerant resume and elastic re-sharding are
trivial: relaunch at step k and the pipeline reproduces batch k bit-exactly
on any mesh size.

Tasks:
  * ``markov_lm_batch``     — tokens from a fixed random bigram chain; a
    learnable LM task with a known entropy floor (paper Fig.2 perf axis).
  * ``classification_batch``— sequence classification: the label is a parity
    function of designated positions (text-classification stand-in).
  * ``icl_batch``           — induction task for the in-context-learning use
    case: `k1 v1 k2 v2 ... kq -> vq` with per-sequence random mappings.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class LMBatch(NamedTuple):
    tokens: jax.Array  # (batch, seq) int32 inputs
    labels: jax.Array  # (batch, seq) int32 next-token targets


def _batch_key(seed: int, step, salt: int = 0):
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, salt)
    return jax.random.fold_in(key, step)


def make_transition_logits(seed: int, vocab: int, concentration: float = 3.0):
    """A fixed bigram LM: row-stochastic transition logits (vocab, vocab)."""
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    return concentration * jax.random.normal(key, (vocab, vocab))


@partial(jax.jit, static_argnames=("batch", "seq", "vocab", "seed"))
def markov_lm_batch(step, *, batch: int, seq: int, vocab: int,
                    seed: int = 0) -> LMBatch:
    logits = make_transition_logits(seed, vocab)
    key = _batch_key(seed, step)
    k0, kc = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, vocab)

    def gen(tok, k):
        nxt = jax.random.categorical(k, logits[tok])
        return nxt, nxt

    keys = jax.random.split(kc, seq)
    _, rest = jax.lax.scan(lambda t, k: gen(t, k), first, keys)
    stream = jnp.concatenate([first[None], rest], axis=0).T  # (batch, seq+1)
    return LMBatch(tokens=stream[:, :-1].astype(jnp.int32),
                   labels=stream[:, 1:].astype(jnp.int32))


def markov_entropy_floor(seed: int, vocab: int) -> float:
    """Per-token conditional entropy of the generating chain (nats) — the
    Bayes-optimal LM loss on this task."""
    import numpy as np
    logits = np.asarray(make_transition_logits(seed, vocab))
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    h_row = -(p * np.log(p + 1e-12)).sum(-1)
    # stationary distribution via power iteration
    pi = np.full(vocab, 1.0 / vocab)
    for _ in range(200):
        pi = pi @ p
        pi /= pi.sum()
    return float((pi * h_row).sum())


class ClsBatch(NamedTuple):
    tokens: jax.Array  # (batch, seq)
    label: jax.Array  # (batch,)


@partial(jax.jit, static_argnames=("batch", "seq", "vocab", "n_classes", "seed"))
def classification_batch(step, *, batch: int, seq: int, vocab: int,
                         n_classes: int = 4, seed: int = 0) -> ClsBatch:
    key = _batch_key(seed, step, salt=1)
    toks = jax.random.randint(key, (batch, seq), 0, vocab)
    # label = (sum of tokens at 4 fixed probe positions) mod n_classes —
    # requires the model to attend to specific positions.
    probes = jnp.array([1, seq // 3, seq // 2, seq - 2])
    label = jnp.mod(toks[:, probes].sum(-1), n_classes)
    return ClsBatch(tokens=toks.astype(jnp.int32), label=label.astype(jnp.int32))


class ICLBatch(NamedTuple):
    tokens: jax.Array  # (batch, seq) the k/v pair stream
    labels: jax.Array  # (batch, seq) next-token targets
    query_pos: jax.Array  # (batch,) position whose NEXT token is the answer
    answer: jax.Array  # (batch,)


@partial(jax.jit, static_argnames=("batch", "n_pairs", "vocab", "seed"))
def icl_batch(step, *, batch: int, n_pairs: int = 8, vocab: int = 512,
              seed: int = 0) -> ICLBatch:
    """Induction task (repeated-block form): stream = B ++ B where
    B = k1 v1 k2 v2 ... kn with DISTINCT keys (lower vocab half) and random
    values (upper half), freshly mapped per sequence.  Every token of the
    second block is predictable only via in-context retrieval — the dense
    training signal under which induction heads emerge.  ``answer`` is the
    value paired with a random key queried in the second block."""
    key = _batch_key(seed, step, salt=2)
    kk, kv, kq = jax.random.split(key, 3)
    half = vocab // 2
    ks = jax.vmap(lambda k: jax.random.permutation(k, half)[:n_pairs])(
        jax.random.split(kk, batch))
    vs = half + jax.random.randint(kv, (batch, n_pairs), 0, half)
    block = jnp.stack([ks, vs], axis=-1).reshape(batch, 2 * n_pairs)
    stream = jnp.concatenate([block, block], axis=1)  # (batch, 4*n_pairs)
    qi = jax.random.randint(kq, (batch,), 0, n_pairs)
    # query key position inside the SECOND block; next token is its value
    query_pos = 2 * n_pairs + 2 * qi
    answer = jnp.take_along_axis(vs, qi[:, None], axis=1)[:, 0]
    return ICLBatch(tokens=stream[:, :-1].astype(jnp.int32),
                    labels=stream[:, 1:].astype(jnp.int32),
                    query_pos=query_pos.astype(jnp.int32),
                    answer=answer.astype(jnp.int32))
