"""Sharded batching: place a global synthetic batch on the mesh.

The generator is pure ``(seed, step) -> global batch``; this module only
handles device placement.  On a real multi-host pod each process would
generate its local shard directly (the generator is index-addressable), so
no host ever materializes the global array — here (single process) we place
the global batch with the batch-dim sharding from dist.sharding.
"""

from __future__ import annotations

import jax

from repro.dist.sharding import data_sharding


def place_batch(batch, mesh):
    def place(x):
        return jax.device_put(x, data_sharding(mesh, x.shape))

    return jax.tree_util.tree_map(place, batch)


class DataLoader:
    """Step-indexed loader: ``loader(step)`` returns the placed batch."""

    def __init__(self, gen_fn, mesh=None, **gen_kwargs):
        self.gen_fn = gen_fn
        self.mesh = mesh
        self.gen_kwargs = gen_kwargs

    def __call__(self, step):
        batch = self.gen_fn(step, **self.gen_kwargs)
        if self.mesh is not None:
            batch = place_batch(batch, self.mesh)
        return batch
