"""AdamW with fp32 moments + optional fp32 master weights (no optax dep)."""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object  # pytree, fp32
    v: object  # pytree, fp32
    master: object  # pytree fp32 master copy, or None


Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class AdamW:
    def __init__(self, lr: Schedule, *, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 master_fp32: bool = True):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.weight_decay = weight_decay
        self.master_fp32 = master_fp32

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros(p.shape, jnp.float32),
            params)
        master = (jax.tree_util.tree_map(
            lambda p: None if p is None else p.astype(jnp.float32), params)
            if self.master_fp32 else None)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree_util.tree_map(lambda x: x, zeros),
                          master=master)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state)."""
        step = state.step + 1
        lr = _lr_at(self.lr, step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        ref = state.master if self.master_fp32 else params

        def upd(g, m, v, p, p_ref):
            if g is None or p is None:
                return None, None, None, None
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            base = p_ref.astype(jnp.float32)
            new = base - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                               + self.weight_decay * base)
            return new.astype(p.dtype), m, v, new

        flat_p, treedef = jax.tree_util.tree_flatten(
            params, is_leaf=lambda x: x is None)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_r = (treedef.flatten_up_to(ref) if self.master_fp32 else flat_p)

        out = [upd(g, m, v, p, r) for g, m, v, p, r in
               zip(flat_g, flat_m, flat_v, flat_p, flat_r)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        new_master = (treedef.unflatten([o[3] for o in out])
                      if self.master_fp32 else None)
        return new_p, AdamWState(step=step, m=new_m, v=new_v,
                                 master=new_master)


class SGD:
    """Plain SGD with momentum (baseline optimizer for the paper benches)."""

    def __init__(self, lr: Schedule, *, momentum: float = 0.9):
        self.lr, self.momentum = lr, momentum

    def init(self, params) -> AdamWState:
        zeros = jax.tree_util.tree_map(
            lambda p: None if p is None else jnp.zeros(p.shape, jnp.float32),
            params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=None,
                          master=None)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = _lr_at(self.lr, step)

        def upd(g, m, p):
            if g is None or p is None:
                return None, None
            m = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_p, treedef = jax.tree_util.tree_flatten(
            params, is_leaf=lambda x: x is None)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=None, master=None)
