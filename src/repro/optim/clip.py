"""Gradient utilities: global-norm clipping and finiteness guards."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [l for l in jax.tree_util.tree_leaves(tree) if l is not None]
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree_util.tree_map(
        lambda l: None if l is None else (l * scale).astype(l.dtype), tree,
        is_leaf=lambda x: x is None), norm


def all_finite(tree) -> jax.Array:
    leaves = [l for l in jax.tree_util.tree_leaves(tree) if l is not None]
    return jnp.all(jnp.stack(
        [jnp.all(jnp.isfinite(l.astype(jnp.float32))) for l in leaves]))
