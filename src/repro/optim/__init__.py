from repro.optim.adamw import AdamW, AdamWState, SGD
from repro.optim.schedule import constant, inverse_sqrt, linear_warmup_cosine
from repro.optim.clip import all_finite, clip_by_global_norm, global_norm

__all__ = ["AdamW", "AdamWState", "SGD", "constant", "inverse_sqrt",
           "linear_warmup_cosine", "all_finite", "clip_by_global_norm",
           "global_norm"]
