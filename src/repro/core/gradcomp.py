"""Low-rank gradient compression (beyond-paper distributed optimization).

Greenformer's insight — a rank-r factorization carries most of a matrix's
information at a fraction of the cost — applies to *gradients* as well as
weights.  This module implements PowerSGD-style (Vogels et al., 2019)
compressed data-parallel gradient reduction with error feedback:

  per matrix-shaped gradient G (m×n), with a persistent right factor Q (n×r):
    1. G ← G + E              (error feedback)
    2. P = G Q                (m×r)   → all-reduce P   (r·m bytes vs m·n)
    3. P = orthonormalize(P)
    4. Q = Gᵀ P               (n×r)   → all-reduce Q
    5. Ĝ = P Qᵀ ; E = G − Ĝ

The all-reduce volume drops from ``m·n`` to ``r·(m+n)`` — the same ratio the
paper's Eq. 1 gives for weights.  Non-matrix leaves (biases, norms, scalars)
are reduced exactly.

Inside ``shard_map`` the reductions are ``jax.lax.psum`` over the data axis;
outside (single-device tests) they are identity.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class CompressorState(NamedTuple):
    q: dict  # path -> (n, r) right factors
    err: dict  # path -> (m, n) error-feedback buffers


def _is_matrix(x) -> bool:
    return hasattr(x, "ndim") and x.ndim >= 2 and min(x.shape[-2:]) > 1


def _flatten_to_mat(g):
    """(..., m, n) -> (m', n) folding leading axes into rows."""
    *lead, m, n = g.shape
    return g.reshape(-1, n), (*lead, m, n)


def _orthonormalize(p):
    """Gram-Schmidt via QR (fp32 for stability)."""
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q.astype(p.dtype)


def init_compressor(grads, rank: int, key: jax.Array) -> CompressorState:
    qs, errs = {}, {}
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    for key_path, leaf in flat:
        if leaf is None or not _is_matrix(leaf):
            continue
        name = jax.tree_util.keystr(key_path)
        mat, _ = _flatten_to_mat(leaf)
        n = mat.shape[1]
        key, sub = jax.random.split(key)
        qs[name] = jax.random.normal(sub, (n, rank), leaf.dtype)
        errs[name] = jnp.zeros_like(leaf)
    return CompressorState(q=qs, err=errs)


def compress_and_reduce(
    grads,
    state: CompressorState,
    *,
    axis_name: Optional[str] = None,
    mean: bool = True,
):
    """Reduce `grads` across `axis_name` with low-rank compression.

    Returns (reduced_grads, new_state).  Must be called inside shard_map /
    vmap with the given axis name; with ``axis_name=None`` the reduction is
    the identity (useful for tests — compression error still applies).
    """

    def reduce_exact(x):
        if axis_name is None:
            return x
        y = jax.lax.psum(x, axis_name)
        return y / jax.lax.psum(1, axis_name) if mean else y

    new_q, new_err, out = dict(state.q), dict(state.err), {}
    flat = jax.tree_util.tree_flatten_with_path(grads)
    leaves = {}
    for key_path, leaf in flat[0]:
        name = jax.tree_util.keystr(key_path)
        if leaf is None:
            leaves[name] = leaf
            continue
        if name not in state.q:  # exact reduction for non-matrix leaves
            leaves[name] = reduce_exact(leaf)
            continue
        g = leaf + state.err[name]
        mat, shape = _flatten_to_mat(g)
        q = state.q[name]
        p = reduce_exact(mat @ q)  # all-reduce #1: (m, r)
        p = _orthonormalize(p)
        q = reduce_exact(mat.T @ p)  # all-reduce #2: (n, r)
        ghat = (p @ q.T).reshape(shape)
        new_q[name] = q
        new_err[name] = g - ghat
        leaves[name] = ghat

    rebuilt = jax.tree_util.tree_unflatten(
        flat[1], [leaves[jax.tree_util.keystr(kp)] for kp, _ in flat[0]])
    return rebuilt, CompressorState(q=new_q, err=new_err)


def compression_ratio(grads, rank: int) -> float:
    """Bytes all-reduced with compression / bytes without."""
    dense = 0
    comp = 0
    for leaf in jax.tree_util.tree_leaves(grads):
        if leaf is None:
            continue
        if _is_matrix(leaf):
            mat, _ = _flatten_to_mat(leaf)
            m, n = mat.shape
            dense += m * n
            comp += rank * (m + n)
        else:
            dense += leaf.size
            comp += leaf.size
    return comp / max(dense, 1)
