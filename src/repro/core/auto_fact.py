"""``auto_fact`` — the paper's one-line automatic factorization API.

    from repro import auto_fact
    fact_model = auto_fact(model, rank=128, solver='svd', num_iter=50)

Walks the module tree, replaces every ``Linear`` with an ``LED`` and every
``Conv1D``/``Conv2D`` with a ``CED1D``/``CED2D`` whenever the resolved rank
passes the paper's ``r < r_max`` gate.  Supports:

* ``rank`` as an absolute int or a float ratio of each layer's ``r_max``
  (the paper's dynamic rank);
* ``solver`` ∈ {'random', 'svd', 'snmf'} (random = factorization-by-design);
* ``submodules`` / ``exclude`` path filters (the paper's filtering feature);
* stacked weights (layer-scanned or expert-stacked ``Linear``s) — solvers are
  batched over the leading axes, so e.g. all 384 experts of kimi-k2
  factorize in one call.

Being a pure pytree→pytree function it composes with jit/pjit sharding.
"""

from __future__ import annotations

import fnmatch
import zlib
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.rank import Rank, r_max, resolve_rank
from repro.core.solvers import get_solver
from repro.nn.conv import CED1D, CED2D, Conv1D, Conv2D
from repro.nn.linear import LED, Linear
from repro.nn.module import Module, map_modules


@dataclass
class FactReport:
    """What auto_fact did, layer by layer."""

    # (path, kind, m, n, r, rel_err) — rel_err is the relative Frobenius
    # reconstruction error ||W - A@B||_F / ||W||_F over the whole (possibly
    # stacked) weight, so a bad solve is localizable to its layer.
    entries: list = field(default_factory=list)
    skipped: list = field(default_factory=list)  # (path, reason)
    params_before: int = 0
    params_after: int = 0

    @property
    def compression(self) -> float:
        if self.params_after == 0:
            return 1.0  # nothing factorized → no compression, not 0x
        return self.params_before / self.params_after

    def summary(self) -> str:
        lines = [f"auto_fact: {len(self.entries)} layers factorized, "
                 f"{len(self.skipped)} skipped"]
        lines += [f"  [fact] {p} ({kind}) {m}x{n} -> r={r} rel_err={e:.4f}"
                  for p, kind, m, n, r, e in self.entries]
        lines += [f"  [skip] {p}: {why}" for p, why in self.skipped]
        if self.params_before:
            lines.append(
                f"  target params: {self.params_before:,} -> "
                f"{self.params_after:,} ({self.compression:.2f}x)")
        return "\n".join(lines)


def _matches(path: str, patterns: Optional[Sequence[str]]) -> bool:
    if patterns is None:
        return True
    return any(p in path or fnmatch.fnmatch(path, p) for p in patterns)


def _layer_key(base_key, path: str):
    return jax.random.fold_in(base_key, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def _rel_err(w, a, b) -> float:
    """Relative Frobenius reconstruction error of W ≈ A @ B (stack-aware)."""
    w32 = w.astype(jnp.float32)
    diff = a.astype(jnp.float32) @ b.astype(jnp.float32) - w32
    denom = jnp.maximum(jnp.linalg.norm(w32.reshape(-1)), 1e-30)
    return float(jnp.linalg.norm(diff.reshape(-1)) / denom)


def _resolve_ungated(rank: Rank, m: int, n: int) -> int:
    """Rank resolution when the r_max gate is off: float ratios scale
    min(m, n) (so ``rank=1.0`` is an exact full-rank factorization) and
    int ranks are clamped to min(m, n)."""
    if isinstance(rank, bool) or not isinstance(rank, (int, float)):
        raise TypeError(f"rank must be int or float, got {type(rank)}")
    if isinstance(rank, float):
        if not 0.0 < rank <= 1.0:
            raise ValueError(f"float rank must be in (0, 1], got {rank}")
        return max(1, int(rank * min(m, n)))
    if rank < 1:
        raise ValueError(f"int rank must be >= 1, got {rank}")
    return min(rank, min(m, n))


def auto_fact(
    module: Module,
    rank: Rank,
    *,
    solver: str = "svd",
    num_iter: int = 50,
    key: Optional[jax.Array] = None,
    submodules: Optional[Sequence[str]] = None,
    exclude: Optional[Sequence[str]] = None,
    factorize_linear: bool = True,
    factorize_conv: bool = True,
    fuse: str = "auto",
    gate: bool = True,
    return_report: bool = False,
):
    """Factorize a model. See module docstring. Returns the new model
    (and a :class:`FactReport` when ``return_report=True``).

    ``gate=False`` disables the paper's ``r < r_max`` break-even check and
    resolves float ranks against ``min(m, n)`` instead of ``r_max``, so
    ``rank=1.0, solver='svd'`` yields an exact (to fp) full-rank LED —
    useful for differential testing, never for compression."""
    solve = get_solver(solver)
    if solver == "random" and key is None:
        key = jax.random.PRNGKey(0)
    report = FactReport()

    def visit(path: str, node: Module):
        if not isinstance(node, (Linear, Conv1D, Conv2D)):
            return node  # keep recursing
        if not _matches(path, submodules) or (exclude and _matches(path, exclude)):
            report.skipped.append((path, "filtered"))
            return node

        if isinstance(node, Linear):
            if not factorize_linear:
                return node
            *stack, m, n = node.weight.shape
        else:
            if not factorize_conv:
                return node
            if isinstance(node, Conv1D):
                c_in, c_out, s = node.weight.shape
                m, n = c_in * s, c_out
            else:
                c_in, c_out, kh, kw = node.weight.shape
                m, n = c_in * kh * kw, c_out
            stack = []

        if gate:
            r = resolve_rank(rank, m, n)
            if r >= r_max(m, n):
                report.skipped.append(
                    (path, f"rank {r} >= r_max {r_max(m, n):.1f} ({m}x{n})"))
                return node
        else:
            r = _resolve_ungated(rank, m, n)

        lkey = _layer_key(key, path) if key is not None else None
        report.params_before += node.weight.size
        if isinstance(node, Linear):
            a, b = solve(node.weight, r, key=lkey, num_iter=num_iter)
            new = LED(A=a, B=b, bias=node.bias, fuse=fuse)
            report.entries.append(
                (path, "linear", m, n, r, _rel_err(node.weight, a, b)))
        elif isinstance(node, Conv1D):
            w_mat = jnp.transpose(node.weight, (0, 2, 1)).reshape(m, n)
            a_mat, b_mat = solve(w_mat, r, key=lkey, num_iter=num_iter)
            a = a_mat.reshape(c_in, s, r).transpose(0, 2, 1)  # (Cin, r, S)
            b = b_mat[:, :, None]  # (r, Cout, 1)
            new = CED1D(A=a, B=b, bias=node.bias, stride=node.stride,
                        padding=node.padding)
            report.entries.append(
                (path, "conv1d", m, n, r, _rel_err(w_mat, a_mat, b_mat)))
        else:
            w_mat = jnp.transpose(node.weight, (0, 2, 3, 1)).reshape(m, n)
            a_mat, b_mat = solve(w_mat, r, key=lkey, num_iter=num_iter)
            a = a_mat.reshape(c_in, kh, kw, r).transpose(0, 3, 1, 2)
            b = b_mat[:, :, None, None]
            new = CED2D(A=a, B=b, bias=node.bias, stride=node.stride,
                        padding=node.padding)
            report.entries.append(
                (path, "conv2d", m, n, r, _rel_err(w_mat, a_mat, b_mat)))
        report.params_after += a.size + b.size
        return new

    fact = map_modules(module, visit)
    return (fact, report) if return_report else fact


def defactorize(module: Module):
    """Inverse convenience: collapse every LED/CED back to a dense layer."""

    def visit(path: str, node: Module):
        if isinstance(node, (LED, CED1D, CED2D)):
            return node.materialize()
        return node

    return map_modules(module, visit)
