"""Factorization solvers: random, SVD, and semi-NMF (SNMF).

Every solver maps a weight matrix ``W ∈ R^{..., m, n}`` (arbitrary leading
*stack* axes — layer-stacked or expert-stacked weights are factorized in one
batched call) to a pair ``(A ∈ R^{..., m, r}, B ∈ R^{..., r, n})`` with
``W ≈ A @ B``.

* ``random`` — fresh initialization at the target rank; per the paper it is
  only suitable for *factorization-by-design* (it does not approximate W).
* ``svd``    — truncated SVD; the optimal rank-r approximation in Frobenius
  norm. The singular values are split symmetrically: ``A = U·√Σ, B = √Σ·Vᵀ``.
* ``snmf``   — semi-non-negative MF (Ding, Li & Jordan 2010): ``W ≈ A·B`` with
  ``B ≥ 0`` and ``A`` unconstrained, fitted by ``num_iter`` multiplicative
  updates.  Jittable (``lax.fori_loop``).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

EPS = 1e-8


def random_solver(w: jax.Array, rank: int, *, key: jax.Array,
                  num_iter: int = 0) -> tuple[jax.Array, jax.Array]:
    del num_iter
    *stack, m, n = w.shape
    ka, kb = jax.random.split(key)
    # lecun-style scaling so that var(A@B x) matches var(W x) at init
    a = jax.random.normal(ka, (*stack, m, rank), w.dtype) / jnp.sqrt(m).astype(w.dtype)
    b = jax.random.normal(kb, (*stack, rank, n), w.dtype) / jnp.sqrt(rank).astype(w.dtype)
    return a, b


def svd_solver(w: jax.Array, rank: int, *, key: Optional[jax.Array] = None,
               num_iter: int = 0) -> tuple[jax.Array, jax.Array]:
    del key, num_iter
    dtype = w.dtype
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    u, s, vt = u[..., :rank], s[..., :rank], vt[..., :rank, :]
    sq = jnp.sqrt(s)
    a = u * sq[..., None, :]
    b = sq[..., :, None] * vt
    return a.astype(dtype), b.astype(dtype)


def snmf_solver(w: jax.Array, rank: int, *, key: Optional[jax.Array] = None,
                num_iter: int = 50) -> tuple[jax.Array, jax.Array]:
    """Semi-NMF: W ≈ F·Gᵀ with G ≥ 0 (so A=F, B=Gᵀ ≥ 0).

    Multiplicative updates from Ding, Li & Jordan (2010), SVD-seeded for
    fast convergence.
    """
    dtype = w.dtype
    wf = w.astype(jnp.float32)
    *_, m, n = wf.shape

    # SVD-based seeding: G0 = |Vᵀ·√Σ|, strictly feasible (non-negative).
    a0, b0 = svd_solver(wf, rank)
    g = jnp.abs(jnp.swapaxes(b0, -1, -2)) + EPS  # (..., n, r)

    def pos(x):
        return (jnp.abs(x) + x) * 0.5

    def neg(x):
        return (jnp.abs(x) - x) * 0.5

    def body(_, g):
        # F = W G (Gᵀ G)⁻¹
        gtg = jnp.swapaxes(g, -1, -2) @ g  # (..., r, r)
        eye = jnp.eye(rank, dtype=jnp.float32)
        f = jnp.linalg.solve(gtg + EPS * eye, jnp.swapaxes(wf @ g, -1, -2))
        f = jnp.swapaxes(f, -1, -2)  # (..., m, r)
        # G <- G * sqrt( [ (WᵀF)+ + G (FᵀF)- ] / [ (WᵀF)- + G (FᵀF)+ ] )
        wtf = jnp.swapaxes(wf, -1, -2) @ f  # (..., n, r)
        ftf = jnp.swapaxes(f, -1, -2) @ f  # (..., r, r)
        num = pos(wtf) + g @ neg(ftf)
        den = neg(wtf) + g @ pos(ftf)
        g = g * jnp.sqrt((num + EPS) / (den + EPS))
        return g

    g = jax.lax.fori_loop(0, num_iter, body, g)
    gtg = jnp.swapaxes(g, -1, -2) @ g
    eye = jnp.eye(rank, dtype=jnp.float32)
    f = jnp.swapaxes(jnp.linalg.solve(gtg + EPS * eye,
                                      jnp.swapaxes(wf @ g, -1, -2)), -1, -2)
    return f.astype(dtype), jnp.swapaxes(g, -1, -2).astype(dtype)


SOLVERS: dict[str, Callable] = {
    "random": random_solver,
    "svd": svd_solver,
    "snmf": snmf_solver,
}


def get_solver(name: str) -> Callable:
    try:
        return SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; available: {sorted(SOLVERS)}") from None
