"""Rank policy: the paper's r_max gate and dynamic (ratio) ranks."""

from __future__ import annotations

from typing import Union

Rank = Union[int, float]


def r_max(m: int, n: int) -> float:
    """Paper Eq. 1: factorizing W∈R^{m×n} at rank r costs r·(m+n) instead of
    m·n, so the break-even rank is m·n/(m+n)."""
    return (m * n) / (m + n)


def resolve_rank(rank: Rank, m: int, n: int) -> int:
    """Resolve the user-facing rank spec for a given layer.

    * ``int``   — absolute rank, used as-is.
    * ``float`` — ratio of the layer's ``r_max`` (the paper's "dynamic rank
      across all layers"); must be in (0, 1].
    """
    if isinstance(rank, bool):  # guard: bool is an int subclass
        raise TypeError("rank must be int or float, got bool")
    if isinstance(rank, int):
        if rank < 1:
            raise ValueError(f"integer rank must be >= 1, got {rank}")
        return rank
    if isinstance(rank, float):
        if not 0.0 < rank <= 1.0:
            raise ValueError(f"ratio rank must be in (0, 1], got {rank}")
        return max(1, int(rank * r_max(m, n)))
    raise TypeError(f"rank must be int or float, got {type(rank)}")


def should_factorize(rank: Rank, m: int, n: int) -> bool:
    """The paper's gate: factorize only when the resolved rank is strictly
    below r_max, guaranteeing a theoretical FLOP/param reduction."""
    return resolve_rank(rank, m, n) < r_max(m, n)


def params_dense(m: int, n: int) -> int:
    return m * n


def params_factorized(m: int, n: int, r: int) -> int:
    return r * (m + n)
