from repro.core.auto_fact import auto_fact, defactorize, FactReport
from repro.core.rank import r_max, resolve_rank, should_factorize
from repro.core.spectral import decay_singular_values, spectral_decay
from repro.core.solvers import (SOLVERS, get_solver, random_solver, snmf_solver,
                                svd_solver)
from repro.core.gradcomp import (CompressorState, compress_and_reduce,
                                 compression_ratio, init_compressor)

__all__ = [
    "auto_fact", "defactorize", "FactReport",
    "r_max", "resolve_rank", "should_factorize",
    "SOLVERS", "get_solver", "random_solver", "svd_solver", "snmf_solver",
    "decay_singular_values", "spectral_decay",
    "CompressorState", "compress_and_reduce", "compression_ratio",
    "init_compressor",
]
