"""Trained-spectrum surrogate weights for factorization benchmarks.

Low-rank factorization only preserves quality when the weights HAVE
low-rank structure.  A randomly initialized ``Linear`` does not: its
singular spectrum follows the flat Marchenko–Pastur bulk, so truncating
to ``0.5 * r_max`` throws away ~60% of the Frobenius energy of EVERY
layer and greedy generation diverges after a token or two.  That is not
a bug in the solvers — it is benchmarking the paper's post-*training*
factorization recipe on noise (the 3% ``greedy_agreement_dense_vs_fact``
this module exists to kill; the SVD path itself reproduces dense logits
to ~1e-5 at full rank, see ``tests/test_fact_serving.py``).

Trained transformer weight matrices empirically show power-law singular
decay.  :func:`spectral_decay` imposes that structure on an untrained
model — singular *vectors* and per-matrix Frobenius norm are preserved,
only the singular *values* are reshaped to ``s_i ∝ s_i · (1 + i)^-alpha``
— giving serving benchmarks and differential tests a surrogate whose
rank-r truncation behaves like a trained checkpoint's instead of like
noise.  ``alpha >= 2.5`` makes rank-``0.5 * r_max`` SVD factorization
greedy-exact on the paper-tiny traces; smaller ``alpha`` flattens the
spectrum back toward the random-init regime (``alpha = 0`` is a no-op
up to fp error).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn.linear import Linear
from repro.nn.module import Module, map_modules


def decay_singular_values(w: jax.Array, alpha: float) -> jax.Array:
    """Reshape ``w``'s singular values to a power-law decay.

    ``w``: (..., m, n) with arbitrary leading stack axes (each stacked
    matrix is reshaped independently).  Singular vectors are kept; the
    spectrum becomes ``s_i * (1 + i)^-alpha`` renormalized so each
    matrix's Frobenius norm is unchanged.  Returns the reshaped weights
    in ``w.dtype``.
    """
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    i = jnp.arange(s.shape[-1], dtype=jnp.float32)
    s2 = s * (1.0 + i) ** (-alpha)
    norm0 = jnp.linalg.norm(s, axis=-1, keepdims=True)
    norm1 = jnp.linalg.norm(s2, axis=-1, keepdims=True)
    s2 = s2 * norm0 / jnp.maximum(norm1, 1e-30)
    return ((u * s2[..., None, :]) @ vt).astype(w.dtype)


def spectral_decay(module: Module, alpha: float = 2.5, *,
                   exclude: Optional[Sequence[str]] = None) -> Module:
    """Apply :func:`decay_singular_values` to every ``Linear`` weight.

    ``exclude`` path fragments (same matching as ``auto_fact``'s filter,
    e.g. ``["embed", "lm_head"]``) are left untouched.  Biases and all
    non-``Linear`` leaves are unchanged.
    """
    def visit(path: str, node: Module):
        if not isinstance(node, Linear):
            return node
        if exclude and any(p in path for p in exclude):
            return node
        return Linear(weight=decay_singular_values(node.weight, alpha),
                      bias=node.bias)

    return map_modules(module, visit)


__all__ = ["decay_singular_values", "spectral_decay"]
