"""Benchmark aggregator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, plus
the full per-figure tables.  Figures:
  fig2-left   factorization-by-design      (benchmarks/fig2_design.py)
  fig2-center post-training factorization  (benchmarks/fig2_posttrain.py)
  fig2-right  in-context-learning fact.    (benchmarks/fig2_icl.py)
  speed       LED vs dense micro-bench     (benchmarks/speed_led.py)
  microbench  kernel/decode/prefill sweep  (benchmarks/microbench_kernels.py)
  roofline    dry-run roofline table       (artifacts/dryrun/*.json)
"""

from __future__ import annotations

import argparse
import sys


def _section(title: str) -> None:
    print(f"\n### {title}", flush=True)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true",
                   help="fewer training steps (CI mode)")
    args = p.parse_args()
    fast = args.fast

    from benchmarks import fig2_design, fig2_icl, fig2_posttrain, speed_led

    csv_rows = []

    _section("fig2-left: factorization-by-design (train from scratch)")
    rows = fig2_design.run(steps=60 if fast else 150)
    for r in rows:
        print(r)
        csv_rows.append((f"fig2_design/{r['variant']}",
                         r["train_s_per_step"] * 1e6,
                         f"rel_perf={r['rel_perf']:.3f};"
                         f"speedup={r['speedup']:.2f}"))

    _section("fig2-center: post-training factorization (no retrain)")
    rows = fig2_posttrain.run(steps=80 if fast else 200)
    for r in rows:
        print(r)
        csv_rows.append((f"fig2_posttrain/{r['variant']}", 0.0,
                         f"rel_perf={r['rel_perf']:.3f};"
                         f"speedup={r['speedup']:.2f}"))

    _section("fig2-right: in-context-learning factorization")
    rows = fig2_icl.run(steps=150 if fast else 400)
    for r in rows:
        print(r)
        csv_rows.append((f"fig2_icl/{r['variant']}", 0.0,
                         f"icl_acc={r['icl_acc']:.3f};"
                         f"speedup={r['speedup']:.2f}"))

    _section("beyond-paper: factorize-then-finetune recovery")
    from benchmarks import posttrain_finetune

    rows = posttrain_finetune.run(steps=80 if fast else 200,
                                  ft_steps=30 if fast else 60)
    for r in rows:
        print(r)
        csv_rows.append((f"posttrain_ft/{r['variant']}", 0.0,
                         f"rel_perf={r['rel_perf']:.3f}"))

    _section("speed: LED vs dense linear")
    rows = speed_led.run()
    for r in rows:
        print(r)
        csv_rows.append((f"speed_led/{r['shape']}@r{r['rank']}",
                         r["led_us"],
                         f"speedup={r['speedup']:.2f};"
                         f"theory={r['theory_speedup']:.2f}"))

    _section("microbench: kernel / decode-step / prefill-chunk sweep")
    from repro.launch.microbench import cell_key, format_cell, run_sweep

    cells = run_sweep(smoke=fast, iters=5 if fast else 20)
    for c in cells:
        print(format_cell(c))
        if "mean_ms" in c["stats"]:
            csv_rows.append((f"microbench/{cell_key(c)}",
                             c["stats"]["mean_ms"] * 1e3,
                             f"compile_ms={c['stats']['compile_ms']:.0f};"
                             f"compiled_backend="
                             f"{c['provenance']['compiled_backend']}"))

    _section("roofline: dry-run artifacts (single-pod)")
    try:
        from repro.launch.roofline import HEADER, fmt_row, load_cells

        cells = load_cells("pod")
        if cells:
            print(HEADER)
            for d in cells:
                print(fmt_row(d))
                r = d["roofline"]
                csv_rows.append((
                    f"roofline/{d['arch']}/{d['shape']}",
                    r["compute_s"] * 1e6,
                    f"dominant={r['dominant']}"))
        else:
            print("(no dry-run artifacts; run python -m repro.launch.dryrun --all)")
    except Exception as e:  # roofline is optional when artifacts are absent
        print(f"(roofline skipped: {e})")

    _section("CSV")
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
