"""Kernel microbenchmark CLI — thin wrapper over
``repro.launch.microbench`` (kept in ``benchmarks/`` so the perf suite
lives in one place alongside its gate).

    PYTHONPATH=src python -m benchmarks.microbench_kernels --smoke \
        --history BENCH_history.jsonl
    PYTHONPATH=src python -m benchmarks.check_regression

Per-step decode and per-chunk prefill timings (compile/warmup separated
from steady state), raw kernel timings vs their jnp oracles, and
kernel-vs-oracle parity cells, swept over (batch, seq, block_size,
heads).  Every cell carries explicit ``compiled_backend`` /
``interpret_mode`` provenance; appended cells form the perf trajectory
``benchmarks/check_regression.py`` gates in CI.
"""

from repro.launch.microbench import main

if __name__ == "__main__":
    raise SystemExit(main())
