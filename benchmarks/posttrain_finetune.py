"""Beyond-paper extension: finetuning after post-training factorization.

The paper's conclusion suggests extending Greenformer to more training
regimes; the natural production workflow is *factorize-then-finetune*: SVD
compression at an aggressive ratio loses quality, but a SHORT finetune of
the factorized model (the LED factors are ordinary trainable params in this
framework) recovers most of it — at the compressed size and speed.

    PYTHONPATH=src:. python -m benchmarks.posttrain_finetune
"""

from __future__ import annotations

import jax

from benchmarks.common import eval_loss, param_millions, tiny_cfg, train_model
from repro.core import auto_fact
from repro.models import build_model

RATIOS = (0.5, 0.25)


def run(steps: int = 200, ft_steps: int = 60, seed: int = 0) -> list[dict]:
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(seed)
    dense = build_model(key, cfg)
    dense, _, _ = train_model(dense, cfg, steps=steps)
    dense_eval, _ = eval_loss(dense, cfg)
    rows = [{"variant": "dense", "ratio": 1.0, "eval_loss": dense_eval,
             "rel_perf": 1.0, "params_M": param_millions(dense)}]

    for ratio in RATIOS:
        fact = auto_fact(dense, ratio, solver="svd",
                         exclude=["embed", "lm_head"])
        ev_before, _ = eval_loss(fact, cfg)
        # short finetune of the FACTORIZED model (training steps continue
        # the same data stream past the dense model's last step)
        recovered, _, _ = train_model(fact, cfg, steps=ft_steps, lr=5e-4)
        ev_after, _ = eval_loss(recovered, cfg)
        rows.append({
            "variant": f"svd@{ratio}+ft{ft_steps}", "ratio": ratio,
            "eval_loss_before_ft": ev_before, "eval_loss": ev_after,
            "rel_perf_before_ft": dense_eval / ev_before,
            "rel_perf": dense_eval / ev_after,
            "params_M": param_millions(recovered),
        })
    return rows


def main() -> None:
    for row in run():
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items()))


if __name__ == "__main__":
    main()
