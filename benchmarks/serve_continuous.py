"""Continuous-batching serving benchmark: dense-slot vs paged KV layout,
dense-gather vs fused Pallas paged-attention decode.

    PYTHONPATH=src python benchmarks/serve_continuous.py            # full
    PYTHONPATH=src python benchmarks/serve_continuous.py --smoke    # CI
    PYTHONPATH=src python benchmarks/serve_continuous.py --smoke \
        --json BENCH_serve.json                                     # artifact

Replays one Poisson arrival trace of variable-length prompts through
``repro.serve.ContinuousEngine`` four times:

* ``dense`` — the per-slot KV layout: every decode slot pins a dense
  ``max_len`` KV lane for its whole lifetime, so HBM-resident KV bytes are
  ``batch * max_len`` lanes regardless of what the requests actually use.
* ``paged`` — the block-table layout: slots share a pool of
  ``block_size``-token KV blocks and each request reserves only
  ``ceil(min(prompt+max_new, max_len) / block_size)`` blocks, so the KV
  high-water mark tracks live tokens.  Greedy tokens are asserted
  bit-identical to the dense replay.
* ``paged+pallas`` — same paged layout, but decode attention runs the
  fused :func:`repro.kernels.paged_attention` kernel (interpret mode on
  CPU): the block gather streams through VMEM inside the online-softmax
  loop instead of materializing the dense ``(batch, max_len, kvh, hd)``
  view.  Greedy tokens are asserted bit-identical to the gather path.
* ``paged+fact`` — the paper's post-training use case on top: the model is
  SVD-factorized with ``auto_fact`` and served through the same paged
  engine.

Beyond the trace replays, a decode-step microbenchmark times the jitted
batched decode step alone (all slots live) for the dense-gather vs fused
kernel paths — the number ``BENCH_serve.json`` tracks across PRs.  On CPU
the fused kernel runs in interpret mode, so the timing there measures
overhead parity, not the TPU win; the benchmark records, it does not
assert an ordering.

Reports tokens/s + p50/p95 per-request latency, HBM-resident KV bytes
(dense allocation vs paged peak residency), and the decode-step times.
``run()`` returns (rows, summary); ``--smoke`` uses the reduced config +
a short trace (the CI gate) and ``--json`` writes the summary for the
workflow artifact / the committed ``BENCH_serve.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import (ContinuousEngine, bench_trace, format_kv_stats,
                         format_stats, greedy_agreement, make_trace)


def decode_step_ms(model, cfg, *, batch, max_len, max_prompt_len,
                   block_size, decode_kernel, iters=20, warmup=3) -> float:
    """Mean wall time of ONE jitted batched decode step with every slot
    live — isolates the attention-gather cost from scheduler/prefill
    overhead.  Submits ``batch`` max-budget requests, admits them all,
    then drives the jitted decode directly."""
    eng = ContinuousEngine(model, cfg, batch=batch, max_len=max_len,
                           max_prompt_len=max_prompt_len, kv_layout="paged",
                           block_size=block_size,
                           decode_kernel=decode_kernel)
    rng = np.random.default_rng(0)
    for _ in range(batch):
        eng.submit(rng.integers(0, cfg.vocab, max_prompt_len - 1)
                   .astype(np.int32), max_new_tokens=max_len)
    eng.step()  # admit every slot + compile the decode step
    key = eng._next_key()
    for _ in range(warmup):
        eng.cache, eng.state, nxt, _ = eng._decode(eng.cache, eng.state, key)
    jax.block_until_ready(nxt)
    t0 = time.perf_counter()
    for _ in range(iters):
        eng.cache, eng.state, nxt, _ = eng._decode(eng.cache, eng.state, key)
    jax.block_until_ready(nxt)
    return (time.perf_counter() - t0) / iters * 1e3


def run(*, smoke: bool = False, fact_rank: float = 0.5, solver: str = "svd",
        seed: int = 0) -> tuple:
    cfg = get_config("paper-tiny")
    batch, max_len, max_prompt, block_size = 8, 256, 48, 16
    n_requests, load, max_new = 32, 0.5, 32
    step_iters = 20
    if smoke:
        cfg = cfg.reduced()
        batch, max_len, max_prompt, block_size = 4, 64, 12, 8
        n_requests, load, max_new = 8, 1.0, 6
        step_iters = 10

    model = build_model(jax.random.PRNGKey(0), cfg)
    trace = make_trace(n_requests, seed=seed, load=load, min_prompt=4,
                       max_prompt=max_prompt, min_new=4, max_new=max_new,
                       vocab=cfg.vocab)

    rows = []
    dims = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt)
    dense_done, dstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="dense")
    print(format_stats("dense-slot", dstats))
    print(format_kv_stats("dense-slot", dstats))
    rows.append({"variant": "dense-slot", **dstats})

    paged_done, pstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="paged",
                                     block_size=block_size)
    print(format_stats("paged", pstats))
    print(format_kv_stats("paged", pstats))
    rows.append({"variant": "paged", **pstats})

    # the whole point of the layout swap: identical greedy tokens...
    for cd, cp in zip(dense_done, paged_done):
        assert cd.tokens == cp.tokens, \
            f"paged/dense divergence (prompt_len={cd.prompt_len})"
    # ...at a fraction of the resident KV footprint
    reduction = (dstats["kv_allocated_bytes"]
                 / max(pstats["kv_peak_resident_bytes"], 1))
    print(f"paged layout needs {reduction:.1f}x fewer HBM-resident KV bytes "
          f"(dense-slot reserves batch*max_len = {batch}*{max_len} lanes)")
    assert reduction >= 2.0, f"expected >= 2x KV reduction, got {reduction:.2f}x"

    # fused Pallas paged-attention decode: same trace, same greedy tokens
    fused_done, fustats = bench_trace(model, cfg, trace, **dims,
                                      kv_layout="paged",
                                      block_size=block_size,
                                      decode_kernel="pallas")
    print(format_stats("paged+pallas", fustats))
    rows.append({"variant": "paged+pallas", **fustats})
    for cp, cf in zip(paged_done, fused_done):
        assert cp.tokens == cf.tokens, \
            f"fused/gather divergence (prompt_len={cp.prompt_len})"
    print("fused pallas decode: greedy tokens bit-identical to dense gather")

    # decode-step microbenchmark: the gather-vs-fused number BENCH_serve
    # tracks (interpret mode on CPU — overhead parity, not the TPU win)
    step_dims = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt,
                     block_size=block_size, iters=step_iters)
    gather_ms = decode_step_ms(model, cfg, decode_kernel="reference",
                               **step_dims)
    fused_ms = decode_step_ms(model, cfg, decode_kernel="pallas",
                              **step_dims)
    backend = jax.default_backend()
    print(f"decode step ({batch} slots, max_len {max_len}): "
          f"gather {gather_ms:.2f} ms vs fused {fused_ms:.2f} ms "
          f"[{backend}{'' if backend == 'tpu' else ', interpret'}]")

    fact = auto_fact(model, fact_rank, solver=solver,
                     key=jax.random.PRNGKey(1),
                     exclude=["embed", "lm_head"])
    fact_done, fstats = bench_trace(fact, cfg, trace, **dims,
                                    kv_layout="paged",
                                    block_size=block_size)
    print(format_stats("paged+fact", fstats))
    rows.append({"variant": f"paged+fact@{fact_rank}", **fstats})

    agree = greedy_agreement(dense_done, fact_done)
    print(f"greedy token agreement dense vs factorized: {agree:.1%}")

    # sanity: every request drained, token budgets respected
    assert all(len(done) == n_requests
               for done in (dense_done, paged_done, fused_done, fact_done))
    assert all(len(c.tokens) >= 1
               for c in dense_done + paged_done + fused_done + fact_done)

    summary = {
        "benchmark": "serve_continuous",
        "smoke": smoke,
        "backend": backend,
        "jax_version": jax.__version__,
        "config": cfg.name,
        "dims": {"batch": batch, "max_len": max_len,
                 "max_prompt_len": max_prompt, "block_size": block_size,
                 "n_requests": n_requests},
        "decode_step_ms": {"paged_gather": gather_ms,
                           "paged_pallas_fused": fused_ms},
        "kv_resident_reduction_x": reduction,
        "paged_vs_dense_tokens_identical": True,   # asserted above
        "fused_vs_gather_tokens_identical": True,  # asserted above
        "greedy_agreement_dense_vs_fact": agree,
        "rows": rows,
    }
    return rows, summary


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + short trace (CI gate)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the run summary as JSON (CI artifact / "
                        "BENCH_serve.json)")
    p.add_argument("--fact-rank", type=float, default=0.5)
    p.add_argument("--solver", default="svd")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    _, summary = run(smoke=args.smoke, fact_rank=args.fact_rank,
                     solver=args.solver, seed=args.seed)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"wrote summary to {args.json}")
    print("serve_continuous: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
