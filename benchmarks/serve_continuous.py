"""Continuous-batching serving benchmark: dense vs auto_fact-factorized.

    PYTHONPATH=src python benchmarks/serve_continuous.py            # full
    PYTHONPATH=src python benchmarks/serve_continuous.py --smoke    # CI

Replays a Poisson-ish arrival trace of variable-length prompts through
``repro.serve.ContinuousEngine`` (requests join recyclable decode slots
mid-flight; one jitted prefill + one jitted decode step) and reports
tokens/s plus p50/p95 per-request latency for the dense ``paper-tiny``
model and its SVD-factorized copy.  This is the workload where low-rank
factorization pays: the decode loop is memory-bound, so shrinking the
weight traffic lifts the whole batch.

``run()`` returns the rows for ``benchmarks.run``-style aggregation;
``--smoke`` uses the reduced config + a short trace and asserts the replay
drains correctly (the CI gate).
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import (bench_trace, format_stats, greedy_agreement,
                         make_trace)


def run(*, smoke: bool = False, fact_rank: float = 0.5, solver: str = "svd",
        seed: int = 0) -> list:
    cfg = get_config("paper-tiny")
    batch, max_len, max_prompt = 8, 128, 48
    n_requests, load, max_new = 32, 0.5, 32
    if smoke:
        cfg = cfg.reduced()
        batch, max_len, max_prompt = 4, 48, 16
        n_requests, load, max_new = 8, 1.0, 8

    model = build_model(jax.random.PRNGKey(0), cfg)
    trace = make_trace(n_requests, seed=seed, load=load, min_prompt=4,
                       max_prompt=max_prompt, min_new=4, max_new=max_new,
                       vocab=cfg.vocab)

    rows = []
    dims = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt)
    dense_done, dstats = bench_trace(model, cfg, trace, **dims)
    print(format_stats("dense", dstats))
    rows.append({"variant": "dense", **dstats})

    fact = auto_fact(model, fact_rank, solver=solver,
                     key=jax.random.PRNGKey(1),
                     exclude=["embed", "lm_head"])
    fact_done, fstats = bench_trace(fact, cfg, trace, **dims)
    print(format_stats("factorized", fstats))
    rows.append({"variant": f"fact@{fact_rank}", **fstats})

    agree = greedy_agreement(dense_done, fact_done)
    print(f"greedy token agreement dense vs factorized: {agree:.1%}")

    # sanity: every request drained, token budgets respected
    assert len(dense_done) == n_requests and len(fact_done) == n_requests
    assert all(len(c.tokens) >= 1 for c in dense_done + fact_done)
    return rows


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + short trace (CI gate)")
    p.add_argument("--fact-rank", type=float, default=0.5)
    p.add_argument("--solver", default="svd")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    run(smoke=args.smoke, fact_rank=args.fact_rank, solver=args.solver,
        seed=args.seed)
    print("serve_continuous: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
