"""Continuous-batching serving benchmark: dense-slot vs paged KV layout.

    PYTHONPATH=src python benchmarks/serve_continuous.py            # full
    PYTHONPATH=src python benchmarks/serve_continuous.py --smoke    # CI

Replays one Poisson arrival trace of variable-length prompts through
``repro.serve.ContinuousEngine`` three times:

* ``dense`` — the per-slot KV layout: every decode slot pins a dense
  ``max_len`` KV lane for its whole lifetime, so HBM-resident KV bytes are
  ``batch * max_len`` lanes regardless of what the requests actually use.
* ``paged`` — the block-table layout: slots share a pool of
  ``block_size``-token KV blocks and each request reserves only
  ``ceil(min(prompt+max_new, max_len) / block_size)`` blocks, so the KV
  high-water mark tracks live tokens.  Greedy tokens are asserted
  bit-identical to the dense replay.
* ``paged+fact`` — the paper's post-training use case on top: the model is
  SVD-factorized with ``auto_fact`` and served through the same paged
  engine.

Reports tokens/s + p50/p95 per-request latency, and HBM-resident KV bytes
(dense allocation vs paged peak residency).  The mixed-length trace leaves
the dense layout's worst-case reservation mostly idle; the run asserts the
paged layout needs >= 2x fewer resident KV bytes.

``run()`` returns the rows for ``benchmarks.run``-style aggregation;
``--smoke`` uses the reduced config + a short trace (the CI gate).
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config
from repro.core import auto_fact
from repro.models import build_model
from repro.serve import (bench_trace, format_kv_stats, format_stats,
                         greedy_agreement, make_trace)


def run(*, smoke: bool = False, fact_rank: float = 0.5, solver: str = "svd",
        seed: int = 0) -> list:
    cfg = get_config("paper-tiny")
    batch, max_len, max_prompt, block_size = 8, 256, 48, 16
    n_requests, load, max_new = 32, 0.5, 32
    if smoke:
        cfg = cfg.reduced()
        batch, max_len, max_prompt, block_size = 4, 64, 12, 8
        n_requests, load, max_new = 8, 1.0, 6

    model = build_model(jax.random.PRNGKey(0), cfg)
    trace = make_trace(n_requests, seed=seed, load=load, min_prompt=4,
                       max_prompt=max_prompt, min_new=4, max_new=max_new,
                       vocab=cfg.vocab)

    rows = []
    dims = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt)
    dense_done, dstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="dense")
    print(format_stats("dense-slot", dstats))
    print(format_kv_stats("dense-slot", dstats))
    rows.append({"variant": "dense-slot", **dstats})

    paged_done, pstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="paged",
                                     block_size=block_size)
    print(format_stats("paged", pstats))
    print(format_kv_stats("paged", pstats))
    rows.append({"variant": "paged", **pstats})

    # the whole point of the layout swap: identical greedy tokens...
    for cd, cp in zip(dense_done, paged_done):
        assert cd.tokens == cp.tokens, \
            f"paged/dense divergence (prompt_len={cd.prompt_len})"
    # ...at a fraction of the resident KV footprint
    reduction = (dstats["kv_allocated_bytes"]
                 / max(pstats["kv_peak_resident_bytes"], 1))
    print(f"paged layout needs {reduction:.1f}x fewer HBM-resident KV bytes "
          f"(dense-slot reserves batch*max_len = {batch}*{max_len} lanes)")
    assert reduction >= 2.0, f"expected >= 2x KV reduction, got {reduction:.2f}x"

    fact = auto_fact(model, fact_rank, solver=solver,
                     key=jax.random.PRNGKey(1),
                     exclude=["embed", "lm_head"])
    fact_done, fstats = bench_trace(fact, cfg, trace, **dims,
                                    kv_layout="paged",
                                    block_size=block_size)
    print(format_stats("paged+fact", fstats))
    rows.append({"variant": f"paged+fact@{fact_rank}", **fstats})

    agree = greedy_agreement(dense_done, fact_done)
    print(f"greedy token agreement dense vs factorized: {agree:.1%}")

    # sanity: every request drained, token budgets respected
    assert all(len(done) == n_requests
               for done in (dense_done, paged_done, fact_done))
    assert all(len(c.tokens) >= 1
               for c in dense_done + paged_done + fact_done)
    return rows


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + short trace (CI gate)")
    p.add_argument("--fact-rank", type=float, default=0.5)
    p.add_argument("--solver", default="svd")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    run(smoke=args.smoke, fact_rank=args.fact_rank, solver=args.solver,
        seed=args.seed)
    print("serve_continuous: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
