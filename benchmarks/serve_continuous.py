"""Continuous-batching serving benchmark: dense-slot vs paged KV layout,
dense-gather vs fused Pallas paged-attention decode, monolithic vs
chunked prefill, prefix-reuse compute skip.

    PYTHONPATH=src python benchmarks/serve_continuous.py            # full
    PYTHONPATH=src python benchmarks/serve_continuous.py --smoke    # CI
    PYTHONPATH=src python benchmarks/serve_continuous.py --smoke \
        --json BENCH_serve.json                                     # artifact

Four replays of one Poisson arrival trace establish the layout/kernel
matrix (all greedy tokens asserted bit-identical where applicable):

* ``dense`` — per-slot KV lanes: HBM-resident KV bytes are
  ``batch * max_len`` regardless of live tokens.
* ``paged`` — block-table layout: the KV high-water mark tracks live
  tokens (asserted >= 2x below the dense reservation).
* ``paged+pallas`` — same layout, fused paged-attention decode kernel
  (interpret mode on CPU).
* ``paged+fact@R`` — the paper's post-training use case: the model is
  SVD-factorized with ``auto_fact`` at rank ratios 0.25/0.5/0.75 and
  served through the same engine (the **rank frontier**: greedy
  agreement vs dense, tokens/s, params and per-layer reconstruction
  error per rank).  The benchmark model's singular spectra are shaped
  to a power-law decay first (``spectral_decay``, alpha=2.5): random
  init has a flat Marchenko-Pastur spectrum where truncation at any
  rank destroys the logits — the old 3% agreement number measured that
  spectrum, not a serving bug — while trained networks (the regime the
  paper compresses) decay fast.  Agreement at ratio 0.5 is asserted
  >= 0.9 and exported as ``greedy_agreement_dense_vs_fact``.
* ``paged+spec`` — speculative decoding: a rank-0.5 factorized draft
  proposes ``k`` greedy tokens per round, the dense verifier re-scores
  them in ONE multi-token decode and commits the agreeing prefix plus
  its own next token.  Greedy tokens asserted bit-identical to the
  plain paged replay; acceptance rate and draft/verify step times land
  in the summary.

Two chunked-prefill experiments then demonstrate the admission-path wins:

* **stall** — a mixed long/short trace replayed through the
  monolithic-equivalent prefill (one full-width chunk, unbounded per-step
  budget: every admission stalls decode for its whole prompt) vs the
  chunked pipeline (bounded padded tokens per step).  Asserted: identical
  greedy tokens, and the chunked path's worst per-step prefill burst —
  the deterministic stand-in for inter-decode-step stall — is both
  bounded by its budget and strictly below the monolithic burst.  Wall
  p50/p95/max per step are recorded (not asserted: CPU timing noise).
* **prefix** — a shared-system-prompt trace replayed with prefix reuse
  on vs off.  Asserted: identical greedy tokens, and prefill compute
  drops by EXACTLY the tokens served from cached prefix blocks.

A **hymba replay cell** (new-families smoke) pushes the hybrid
sliding-window + SSM family through the same continuous engine: greedy
tokens asserted identical to the one-shot ``generate`` baseline, and the
ring-KV lanes asserted resident at O(window) bytes per slot — not the
O(max_len) a dense lane would pin (the engine reports the lane length in
``kv_stats()['kv_lane_tokens']``).

An **http_serve cell** pushes the same trace through the async HTTP
front door (``repro.serve.http`` + the ``repro.launch.loadgen`` client):
closed-loop SSE completions asserted bit-identical to the offline paged
replay, then an open-loop run with Poisson arrivals and a 30% client
disconnect fraction asserted to leak zero paged blocks, with a
``/metrics`` scrape checked at the end.  Closed- and open-loop
tok/s + TTFT/latency percentiles land in ``summary["http_serve"]``.

A decode-step microbenchmark times the jitted batched decode step alone
(gather vs fused kernel) — on CPU the fused kernel runs in interpret
mode, so that timing measures overhead parity, not the TPU win.

``run()`` returns (rows, summary); ``--smoke`` uses the reduced config +
short traces (the CI gate — the long-prompt mixed trace runs there too,
so chunking is exercised in CI) and ``--json`` writes the summary for
the workflow artifact / the committed ``BENCH_serve.json``.  The summary
carries TTFT p50/p95, prefix-hit-rate, and per-step stall fields for
every variant row.

``--sharded`` runs the dp x tp sharded serving sweep instead: the same
seeded trace replayed through engines on ``{data, model}`` meshes at
every grid point of ``repro.launch.microbench.SHARDED_GRID``, tokens
asserted bit-identical to the 1x1 replay, with ``sharded_tok_s`` /
``sharded_decode_step_ms`` / ``sharded_tokens_mismatch`` cells appended
to ``--history`` for the regression gate.  It re-execs itself under
``--xla_force_host_platform_device_count=8`` when fewer than 4 devices
are visible, so the sweep runs on any CPU host.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import auto_fact, spectral_decay
from repro.models import build_model
from repro.serve import (ContinuousEngine, bench_trace, format_kv_stats,
                         format_prefill_stats, format_stats, generate,
                         greedy_agreement, make_trace)

try:  # repo root on sys.path (python -m benchmarks.serve_continuous)
    from benchmarks.common import speedup, timing_cell
except ImportError:  # bare script: benchmarks/ itself is sys.path[0]
    from common import speedup, timing_cell


def decode_step_ms(model, cfg, *, batch, max_len, max_prompt_len,
                   block_size, decode_kernel, iters=20, warmup=3,
                   mesh=None) -> float:
    """Mean wall time of ONE jitted batched decode step with every slot
    live — isolates the attention-gather cost from scheduler/prefill
    overhead.  Submits ``batch`` max-budget requests, admits them all,
    then drives the jitted decode directly.  With ``mesh`` the engine
    runs sharded (params/pool/state placed, activations constrained), so
    the timing includes any collective cost the partitioner inserts."""
    eng = ContinuousEngine(model, cfg, batch=batch, max_len=max_len,
                           max_prompt_len=max_prompt_len, kv_layout="paged",
                           block_size=block_size,
                           decode_kernel=decode_kernel,
                           prefill_chunk_budget=10**9, mesh=mesh)
    rng = np.random.default_rng(0)
    for _ in range(batch):
        eng.submit(rng.integers(0, cfg.vocab, max_prompt_len - 1)
                   .astype(np.int32), max_new_tokens=max_len)
    eng.step()  # admit every slot + compile the decode step
    key = eng._next_key()
    for _ in range(warmup):
        eng.cache, eng.state, nxt, _ = eng._decode(eng.cache, eng.state, key)
    jax.block_until_ready(nxt)
    t0 = time.perf_counter()
    for _ in range(iters):
        eng.cache, eng.state, nxt, _ = eng._decode(eng.cache, eng.state, key)
    jax.block_until_ready(nxt)
    return (time.perf_counter() - t0) / iters * 1e3


def spec_step_ms(model, draft, cfg, *, batch, max_prompt_len, block_size,
                 spec_k, iters=10, warmup=2) -> tuple:
    """Mean wall time of the two halves of one speculative round with
    every slot live: the k-step factorized draft scan and the single
    dense multi-token verify.  Drives the jitted pair directly (the
    engine's python bookkeeping is bypassed), so ``max_len`` is sized to
    keep every timed round's positions in range."""
    max_len = max_prompt_len + (warmup + iters + 2) * spec_k + 8
    eng = ContinuousEngine(model, cfg, batch=batch, max_len=max_len,
                           max_prompt_len=max_prompt_len, kv_layout="paged",
                           block_size=block_size,
                           prefill_chunk_budget=10**9,
                           draft_model=draft, spec_k=spec_k)
    rng = np.random.default_rng(0)
    for _ in range(batch):
        eng.submit(rng.integers(0, cfg.vocab, max_prompt_len - 1)
                   .astype(np.int32), max_new_tokens=max_len)
    eng.step()  # admit every slot + compile + run the first spec round
    draft_s = verify_s = 0.0
    for i in range(warmup + iters):
        t0 = time.perf_counter()
        eng.draft_cache, drafts = eng._spec_draft(
            eng.draft_cache, eng.cache.length, eng.state)
        jax.block_until_ready(drafts)
        t1 = time.perf_counter()
        out = eng._spec_verify(eng.cache, eng.state, drafts)
        eng.cache, eng.state = out[0], out[1]
        jax.block_until_ready(out[2])
        t2 = time.perf_counter()
        if i >= warmup:
            draft_s += t1 - t0
            verify_s += t2 - t1
    return draft_s / iters * 1e3, verify_s / iters * 1e3


def http_serve_cell(model, cfg, trace, paged_done, *, dims, block_size,
                    n_open, seed) -> dict:
    """The service front door under load: the SAME trace served over HTTP
    (SSE streaming) must emit bit-identical tokens to the offline paged
    replay, and an open-loop run with client disconnects must leak zero
    paged blocks.  Returns the ``http_serve`` summary cell."""
    import asyncio

    from repro.launch.loadgen import (make_payloads, run_closed_loop,
                                      run_open_loop, summarize)
    from repro.serve.http import BackgroundServer

    def wait_drained(eng, timeout=60.0):
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < timeout:
            if eng.scheduler.idle and eng.manager.fully_free:
                return
            time.sleep(0.05)
        raise AssertionError("http engine did not drain")

    # closed loop: the trace's own requests, tokens vs the offline replay
    eng = ContinuousEngine(model, cfg, **dims, kv_layout="paged",
                           block_size=block_size)
    payloads = [{"prompt": req.prompt.tolist(),
                 "max_new_tokens": req.max_new_tokens} for _, req in trace]
    with BackgroundServer(eng, max_pending=len(payloads) + 1) as bg:
        t0 = time.perf_counter()
        closed = asyncio.run(run_closed_loop(bg.host, bg.port, payloads,
                                             concurrency=4))
        closed_wall = time.perf_counter() - t0
        for cp, r in zip(paged_done, closed):
            assert r["status"] == 200, f"http request failed: {r['error']}"
            assert r["tokens"] == cp.tokens, \
                f"http/offline divergence (prompt_len={cp.prompt_len})"
        closed_stats = summarize(closed, closed_wall)
        print(f"http closed : {closed_stats['tokens_per_s']:9.1f} tok/s   "
              f"p50 {closed_stats['latency_p50_ms']:7.1f} ms   "
              f"ttft p50 {closed_stats['ttft_p50_ms']:6.1f} ms   "
              f"({closed_stats['served']} reqs over SSE)")
        print("http serve: greedy tokens bit-identical to the offline "
              "paged replay")
        wait_drained(eng)

        # open loop with client disconnects: Poisson arrivals, a fraction
        # of clients abandon after their first token; every cancel must
        # return its blocks (pool asserted fully free afterwards)
        open_payloads = make_payloads(n_open, seed=seed + 4, min_prompt=4,
                                      max_prompt=dims["max_prompt_len"] // 2,
                                      min_new=4, max_new=8, vocab=cfg.vocab)
        t0 = time.perf_counter()
        opened = asyncio.run(run_open_loop(bg.host, bg.port, open_payloads,
                                           rate=20.0, cancel_frac=0.3,
                                           seed=seed))
        wait_drained(eng)
        open_wall = time.perf_counter() - t0
        open_stats = summarize(opened, open_wall)
        assert open_stats["errors"] == 0, f"open-loop errors: {open_stats}"
        assert eng.manager.fully_free, \
            "cancelled requests leaked paged blocks"
        n_cancel = open_stats["cancelled_by_client"]
        print(f"http open   : {open_stats['served']} served, "
              f"{n_cancel} client-cancelled, 0 leaked blocks "
              f"({open_stats['streamed_tokens']} tok streamed)")

        # the scrape endpoint works under/after load
        status, body = asyncio.run(_scrape(bg.host, bg.port))
        assert status == 200
        text = body.decode()
        for name in ("repro_serve_ttft_seconds", "repro_serve_prefix_hit_rate",
                     "repro_serve_completions_total",
                     "repro_serve_kv_blocks_in_use"):
            assert name in text, f"metric {name} missing from /metrics"
        print("http serve: /metrics scrape OK")

    return {
        "tokens_identical_to_paged_replay": True,  # asserted above
        "closed_loop": closed_stats,
        "open_loop": {**open_stats, "rate_per_s": 20.0, "cancel_frac": 0.3},
        "cancel_leaked_blocks": 0,                 # asserted fully_free
        "metrics_scrape_ok": True,                 # asserted above
    }


async def _scrape(host, port):
    from repro.launch.loadgen import fetch
    return await fetch(host, port, "/metrics")


def run(*, smoke: bool = False, fact_rank: float = 0.5, solver: str = "svd",
        seed: int = 0) -> tuple:
    cfg = get_config("paper-tiny")
    batch, max_len, max_prompt, block_size = 8, 256, 48, 16
    n_requests, load, max_new = 32, 0.5, 32
    chunk, budget = 16, 16
    long_prompt, long_frac = 48, 0.25
    step_iters = 20
    if smoke:
        cfg = cfg.reduced()
        batch, max_len, max_prompt, block_size = 4, 64, 24, 8
        n_requests, load, max_new = 8, 1.0, 6
        chunk, budget = 8, 8
        long_prompt, long_frac = 24, 0.3
        step_iters = 10

    # shape the singular spectra to the trained-network regime (random
    # init is flat Marchenko-Pastur — see the module docstring) so the
    # rank frontier below measures the paper's use case, not init noise
    model = spectral_decay(build_model(jax.random.PRNGKey(0), cfg), 2.5,
                           exclude=["embed", "lm_head"])
    trace = make_trace(n_requests, seed=seed, load=load, min_prompt=4,
                       max_prompt=max_prompt // 2, min_new=4,
                       max_new=max_new, vocab=cfg.vocab)

    rows = []
    dims = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt,
                chunk_size=chunk, prefill_chunk_budget=budget)
    dense_done, dstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="dense")
    print(format_stats("dense-slot", dstats))
    print(format_kv_stats("dense-slot", dstats))
    rows.append({"variant": "dense-slot", **dstats})

    paged_done, pstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="paged",
                                     block_size=block_size)
    print(format_stats("paged", pstats))
    print(format_kv_stats("paged", pstats))
    rows.append({"variant": "paged", **pstats})

    # the whole point of the layout swap: identical greedy tokens...
    for cd, cp in zip(dense_done, paged_done):
        assert cd.tokens == cp.tokens, \
            f"paged/dense divergence (prompt_len={cd.prompt_len})"
    # ...at a fraction of the resident KV footprint
    reduction = (dstats["kv_allocated_bytes"]
                 / max(pstats["kv_peak_resident_bytes"], 1))
    print(f"paged layout needs {reduction:.1f}x fewer HBM-resident KV bytes "
          f"(dense-slot reserves batch*max_len = {batch}*{max_len} lanes)")
    assert reduction >= 2.0, f"expected >= 2x KV reduction, got {reduction:.2f}x"

    # fused Pallas paged-attention decode: same trace, same greedy tokens
    fused_done, fustats = bench_trace(model, cfg, trace, **dims,
                                      kv_layout="paged",
                                      block_size=block_size,
                                      decode_kernel="pallas")
    print(format_stats("paged+pallas", fustats))
    rows.append({"variant": "paged+pallas", **fustats})
    for cp, cf in zip(paged_done, fused_done):
        assert cp.tokens == cf.tokens, \
            f"fused/gather divergence (prompt_len={cp.prompt_len})"
    print("fused pallas decode: greedy tokens bit-identical to dense gather")

    # ---- chunked-prefill win 1: bounded decode stall under long prompts ----
    mixed = make_trace(n_requests, seed=seed + 1, load=load, min_prompt=4,
                       max_prompt=max_prompt // 3, min_new=4,
                       max_new=max_new, vocab=cfg.vocab,
                       long_frac=long_frac, long_prompt=long_prompt)
    base = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt,
                kv_layout="paged", block_size=block_size)
    mono_done, mono = bench_trace(model, cfg, mixed, **base,
                                  chunk_size=max_prompt,
                                  buckets=(max_prompt,),
                                  prefill_chunk_budget=10**9)
    chunk_done, chnk = bench_trace(model, cfg, mixed, **base,
                                   chunk_size=chunk,
                                   prefill_chunk_budget=budget)
    print(format_prefill_stats("monolithic", mono))
    print(format_prefill_stats("chunked", chnk))
    rows.append({"variant": "mixed+monolithic", **mono})
    rows.append({"variant": "mixed+chunked", **chnk})
    for cm, cc in zip(mono_done, chunk_done):
        assert cm.tokens == cc.tokens, \
            f"chunked/monolithic divergence (prompt_len={cm.prompt_len})"
    stall_mono = mono["step_prefill_tokens_max"]
    stall_chnk = chnk["step_prefill_tokens_max"]
    print(f"worst per-step prefill burst: monolithic {stall_mono} tok "
          f"vs chunked {stall_chnk} tok (budget {budget})")
    assert stall_chnk <= max(budget, chunk), \
        f"chunked burst {stall_chnk} exceeds budget bound"
    assert stall_chnk < stall_mono, \
        "chunking did not reduce the per-step prefill burst"

    # ---- chunked-prefill win 2: prefix hits skip prefill compute -----------
    shared = make_trace(n_requests, seed=seed + 2, load=load, min_prompt=4,
                        max_prompt=max_prompt // 3, min_new=4,
                        max_new=max_new, vocab=cfg.vocab,
                        shared_prefix=2 * block_size)
    reuse_done, ron = bench_trace(model, cfg, shared, **base,
                                  chunk_size=chunk,
                                  prefill_chunk_budget=budget,
                                  prefix_reuse=True)
    plain_done, roff = bench_trace(model, cfg, shared, **base,
                                   chunk_size=chunk,
                                   prefill_chunk_budget=budget,
                                   prefix_reuse=False)
    print(format_prefill_stats("prefix-on", ron))
    print(format_prefill_stats("prefix-off", roff))
    rows.append({"variant": "prefix+reuse", **ron})
    rows.append({"variant": "prefix+noreuse", **roff})
    for ca, cb in zip(reuse_done, plain_done):
        assert ca.tokens == cb.tokens, \
            f"prefix-skip divergence (prompt_len={ca.prompt_len})"
    saved = (roff["prefill_tokens_computed"] - ron["prefill_tokens_computed"])
    print(f"prefix reuse skipped {ron['prefix_skipped_tokens']} prompt "
          f"tokens ({ron['prefix_hit_rate']:.0%} of admitted); prefill "
          f"compute dropped by {saved} tokens")
    assert saved == ron["prefix_skipped_tokens"] > 0, \
        "prefix-hit compute reduction must equal the skipped tokens"

    # ---- new-families smoke: hymba (ring + ssm per-slot state) -------------
    # reduced config in both modes: the cell proves the state machinery
    # (ring wraparound, ssm scan-in, slot recycling), not model-scale perf
    hy_cfg = get_config("hymba-1.5b").reduced()
    hy_model = build_model(jax.random.PRNGKey(2), hy_cfg)
    hy_max_len, hy_chunk = 64, hy_cfg.window
    hy_trace = make_trace(max(6, n_requests // 2), seed=seed + 3, load=load,
                          min_prompt=2, max_prompt=24, min_new=4,
                          max_new=max_new, vocab=hy_cfg.vocab)
    hy_done, hstats = bench_trace(hy_model, hy_cfg, hy_trace, batch=batch,
                                  max_len=hy_max_len, max_prompt_len=24,
                                  chunk_size=hy_chunk,
                                  prefill_chunk_budget=hy_chunk)
    print(format_stats("hymba-ring", hstats))
    print(format_kv_stats("hymba-ring", hstats))
    rows.append({"variant": "hymba-ring", **hstats})
    assert hstats["cache_kind"] == "hybrid"
    # ring-KV lanes are O(window) per slot, NOT O(max_len): the resident
    # ring bytes are window/max_len of what dense lanes would pin
    assert hstats["kv_lane_tokens"] == hy_cfg.window < hy_max_len
    ring_bytes = hstats["kv_ring_bytes"]
    dense_equiv = ring_bytes * hy_max_len // hy_cfg.window
    ring_reduction = dense_equiv / ring_bytes
    print(f"hymba ring KV: {ring_bytes / 1024:.1f} KiB resident "
          f"(O(window={hy_cfg.window})) vs {dense_equiv / 1024:.1f} KiB "
          f"for dense max_len={hy_max_len} lanes "
          f"({ring_reduction:.0f}x)")
    assert ring_bytes * 2 <= dense_equiv, \
        "ring lanes failed the O(window) residency bound"
    # and the tokens stay correct: every completion matches the one-shot
    # baseline (chunk == window, so boundaries land on the window edge)
    for (_, req), c in zip(hy_trace, hy_done):
        cache = hy_model.init_cache(1, hy_max_len, hy_cfg,
                                    dtype=jnp.float32)
        ref, _ = generate(hy_model, jnp.asarray(req.prompt)[None, :], cache,
                          n_steps=req.max_new_tokens)
        assert c.tokens == np.asarray(ref)[0].tolist(), \
            f"hymba replay diverged (prompt_len={req.prompt.size})"
    print("hymba ring+ssm replay: greedy tokens identical to generate")

    # decode-step microbenchmark: the gather-vs-fused number BENCH_serve
    # tracks.  Cells carry explicit provenance (compiled_backend is null
    # in interpret mode) so an interpret-mode "5x slowdown" can never
    # read as a real perf number, and the speedup below REFUSES to
    # compare across provenance mismatches.  The full sweep lives in
    # benchmarks/microbench_kernels.py -> BENCH_history.jsonl.
    step_dims = dict(batch=batch, max_len=max_len, max_prompt_len=max_prompt,
                     block_size=block_size, iters=step_iters)
    gather_cell = timing_cell(decode_step_ms(
        model, cfg, decode_kernel="reference", **step_dims))
    fused_cell = timing_cell(decode_step_ms(
        model, cfg, decode_kernel="pallas", **step_dims))
    backend = jax.default_backend()
    tag = gather_cell["compiled_backend"] or f"{backend}+interpret"
    print(f"decode step ({batch} slots, max_len {max_len}): "
          f"gather {gather_cell['ms']:.2f} ms vs fused "
          f"{fused_cell['ms']:.2f} ms "
          f"({speedup(gather_cell, fused_cell):.2f}x) [{tag}]")

    # ---- rank frontier: quality vs compression of the served model ---------
    ratios = sorted({0.25, 0.5, 0.75, fact_rank})
    frontier = []
    agree_at = {}
    for ratio in ratios:
        fact, rep = auto_fact(model, ratio, solver=solver,
                              key=jax.random.PRNGKey(1),
                              exclude=["embed", "lm_head"], gate=False,
                              return_report=True)
        fact_done, fstats = bench_trace(fact, cfg, trace, **dims,
                                        kv_layout="paged",
                                        block_size=block_size)
        assert len(fact_done) == n_requests
        agree = greedy_agreement(dense_done, fact_done)
        agree_at[ratio] = agree
        worst_err = max(e[5] for e in rep.entries)
        print(format_stats(f"fact@{ratio}", fstats))
        print(f"fact@{ratio}: agreement {agree:.1%}, "
              f"{rep.params_before:,} -> {rep.params_after:,} params "
              f"({rep.compression:.2f}x), worst layer rel_err "
              f"{worst_err:.4f}")
        rows.append({"variant": f"paged+fact@{ratio}", **fstats})
        frontier.append({
            "rank_ratio": ratio,
            "solver": solver,
            "greedy_agreement": agree,
            "tokens_per_s": fstats["tokens_per_s"],
            "params_before": rep.params_before,
            "params_after": rep.params_after,
            "compression_x": rep.compression,
            "max_layer_rel_err": worst_err,
        })
    headline = agree_at[fact_rank]
    assert headline >= 0.9, \
        f"factorized serving regressed: agreement@{fact_rank} = {headline}"

    # ---- speculative decoding: low-rank draft, dense verify ----------------
    spec_k = 4
    draft = auto_fact(model, 0.5, solver="svd",
                      exclude=["embed", "lm_head"], gate=False)
    spec_done, sstats = bench_trace(model, cfg, trace, **dims,
                                    kv_layout="paged",
                                    block_size=block_size,
                                    draft_model=draft, spec_k=spec_k)
    print(format_stats("paged+spec", sstats))
    rows.append({"variant": f"paged+spec@k{spec_k}", **sstats})
    for cp, cs in zip(paged_done, spec_done):
        assert cp.tokens == cs.tokens, \
            f"speculative divergence (prompt_len={cp.prompt_len})"
    assert sstats["spec_acceptance_rate"] > 0.0, \
        "rank-0.5 draft accepted nothing — draft path broken"
    print(f"speculative decode: k={spec_k} rounds={sstats['spec_rounds']} "
          f"accepted {sstats['spec_accepted_tokens']}"
          f"/{sstats['spec_drafted_tokens']} drafted "
          f"({sstats['spec_acceptance_rate']:.1%}); greedy tokens "
          "bit-identical to the plain paged replay")

    draft_ms, verify_ms = spec_step_ms(model, draft, cfg, batch=batch,
                                       max_prompt_len=max_prompt,
                                       block_size=block_size, spec_k=spec_k,
                                       iters=step_iters)
    print(f"spec round ({batch} slots): draft {draft_ms:.2f} ms "
          f"(k={spec_k} factorized steps) + verify {verify_ms:.2f} ms "
          f"(1 dense multi-token step)")

    # ---- HTTP front door: same trace through the async server --------------
    http_summary = http_serve_cell(model, cfg, trace, paged_done,
                                   dims=dims, block_size=block_size,
                                   n_open=max(6, n_requests // 2), seed=seed)

    # sanity: every request drained, token budgets respected
    for done in (dense_done, paged_done, fused_done, spec_done,
                 mono_done, chunk_done, reuse_done, plain_done):
        assert len(done) == n_requests
        assert all(len(c.tokens) >= 1 for c in done)
    assert len(hy_done) == len(hy_trace)

    summary = {
        "benchmark": "serve_continuous",
        "smoke": smoke,
        "backend": backend,
        "jax_version": jax.__version__,
        "config": cfg.name,
        "dims": {"batch": batch, "max_len": max_len,
                 "max_prompt_len": max_prompt, "block_size": block_size,
                 "n_requests": n_requests, "chunk_size": chunk,
                 "prefill_chunk_budget": budget,
                 "long_prompt": long_prompt, "long_frac": long_frac},
        # provenance-stamped cells, NOT bare floats: compiled_backend is
        # null when these numbers measured the Pallas interpreter
        "decode_step_ms": {"paged_gather": gather_cell,
                           "paged_pallas_fused": fused_cell},
        "kv_resident_reduction_x": reduction,
        "paged_vs_dense_tokens_identical": True,    # asserted above
        "fused_vs_gather_tokens_identical": True,   # asserted above
        "chunked_vs_monolithic_tokens_identical": True,  # asserted above
        "ttft_p50_ms": pstats["ttft_p50_ms"],
        "ttft_p95_ms": pstats["ttft_p95_ms"],
        "prefix_hit_rate": ron["prefix_hit_rate"],
        "prefix_skipped_tokens": ron["prefix_skipped_tokens"],
        "prefill_compute_saved_tokens": saved,
        "stall_step_prefill_tokens_max": {"monolithic": stall_mono,
                                          "chunked": stall_chnk},
        "stall_step_wall_p95_ms": {"monolithic": mono["step_wall_p95_ms"],
                                   "chunked": chnk["step_wall_p95_ms"]},
        "hymba_ring": {
            "cache_kind": hstats["cache_kind"],
            "window": hy_cfg.window,
            "max_len": hy_max_len,
            "kv_lane_tokens": hstats["kv_lane_tokens"],
            "ring_kv_bytes": ring_bytes,
            "dense_lane_equiv_bytes": dense_equiv,
            "ring_residency_reduction_x": ring_reduction,
            "tokens_identical_to_generate": True,  # asserted above
        },
        "greedy_agreement_dense_vs_fact": headline,
        "fact_frontier": frontier,
        "spec_decode": {
            "spec_k": spec_k,
            "draft_rank_ratio": 0.5,
            "rounds": sstats["spec_rounds"],
            "drafted_tokens": sstats["spec_drafted_tokens"],
            "accepted_tokens": sstats["spec_accepted_tokens"],
            "acceptance_rate": sstats["spec_acceptance_rate"],
            "tokens_per_s": sstats["tokens_per_s"],
            "draft_step_ms": draft_ms,
            "verify_step_ms": verify_ms,
            "tokens_identical_to_dense": True,  # asserted above
        },
        "http_serve": http_summary,
        "rows": rows,
    }
    return rows, summary


SHARDED_DIMS = dict(batch=4, max_len=48, max_prompt_len=16)


def run_sharded(*, smoke: bool = True, seed: int = 0) -> tuple:
    """The dp x tp sharded serving sweep (``--sharded``).

    Replays ONE seeded trace (chunked prefill + shared prefix) through a
    ContinuousEngine at every mesh point of
    :data:`repro.launch.microbench.SHARDED_GRID`, asserts every grid
    point's tokens bit-identical to the 1x1 replay, and times the jitted
    sharded decode step.  Returns ``(cells, summary)`` — provenance-
    stamped cells for ``BENCH_history.jsonl`` plus a JSON summary.
    """
    from repro.dist import make_serve_mesh
    from repro.launch.microbench import SHARDED_GRID, make_cell, provenance

    n_dev = len(jax.devices())
    assert n_dev >= 4, "run_sharded needs >= 4 devices (main() re-execs)"
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    n_req = 8 if smoke else 24
    trace = make_trace(n_req, seed=seed, load=0.5, min_prompt=4,
                       max_prompt=12, min_new=2, max_new=8,
                       vocab=cfg.vocab, shared_prefix=4)
    block_size = 8
    prov = provenance()
    axes = dict(SHARDED_DIMS, block_size=block_size, requests=n_req)
    cells, grid, ref, mismatch = [], {}, None, 0
    for dp, tp in SHARDED_GRID:
        mesh = make_serve_mesh(f"{dp},{tp}")  # None at 1x1: the baseline
        variant = f"dp{dp}tp{tp}"
        rows, stats = bench_trace(model, cfg, trace, kv_layout="paged",
                                  block_size=block_size, mesh=mesh,
                                  **SHARDED_DIMS)
        toks = {r.uid: tuple(r.tokens) for r in rows}
        if ref is None:
            ref = toks
        bad = sum(1 for uid in ref if toks.get(uid) != ref[uid])
        mismatch += bad
        assert bad == 0, f"{variant}: {bad} request(s) diverged from 1x1"
        step = decode_step_ms(model, cfg, block_size=block_size,
                              decode_kernel="reference", mesh=mesh,
                              iters=(8 if smoke else 20), warmup=2,
                              **SHARDED_DIMS)
        cells.append(make_cell("sharded_tok_s", variant, axes,
                               {"value": round(stats["tokens_per_s"], 3)},
                               prov, smoke=smoke))
        cells.append(make_cell("sharded_decode_step_ms", variant, axes,
                               {"mean_ms": step}, prov, smoke=smoke))
        grid[variant] = {"devices": dp * tp,
                         "tokens_per_s": stats["tokens_per_s"],
                         "decode_step_ms": step}
    cells.append(make_cell(
        "sharded_tokens_mismatch", "total", axes,
        {"value": mismatch,
         "grid": [f"dp{d}tp{t}" for d, t in SHARDED_GRID]},
        prov, smoke=smoke))
    paths = sorted({f"{c['metric']}/{c['variant']}" for c in cells})
    cells.append(make_cell("cells_emitted", "sharded_serve", {},
                           {"value": len(cells), "paths": paths}, prov,
                           smoke=smoke))
    summary = {"suite": "sharded_serve", "smoke": smoke, "seed": seed,
               "n_devices": n_dev, "grid": grid,
               "tokens_identical_to_1x1": True,  # asserted above
               "cells": cells}
    return cells, summary


PRIORITY_DIMS = dict(batch=2, max_len=48, max_prompt_len=12)
PRIORITY_MIX = (0.25, 0.75)  # 25% class-0 urgent, 75% class-1 default


def _replay_counting_steps(model, cfg, trace, **engine_kwargs) -> tuple:
    """Replay a trace counting ENGINE STEPS, not wall time: TTFT measured
    in steps is deterministic (same seed -> same number, no CPU-timing
    flake), which is what a CI-gated scheduling comparison needs.
    Returns ``(tokens, ttft_steps)``, both keyed by trace index."""
    engine = ContinuousEngine(model, cfg, **engine_kwargs)
    pending = sorted(enumerate(trace), key=lambda p: p[1][0])
    uid_of, submit_tick, first_step = {}, {}, {}
    done, i, tick = [], 0, 0
    while i < len(pending) or not engine.scheduler.idle:
        while i < len(pending) and pending[i][1][0] <= tick:
            idx, (_, req) = pending[i]
            uid_of[idx] = engine.submit(req)
            submit_tick[idx] = tick
            i += 1
        done.extend(engine.step())
        for uid, _ in engine.step_events:
            first_step.setdefault(uid, tick)  # bind emits the first token
        tick += 1
        if tick >= 100_000:
            raise RuntimeError("priority trace did not drain")
    idx_of = {u: k for k, u in uid_of.items()}
    tokens = {idx_of[c.uid]: tuple(c.tokens) for c in done}
    ttft_steps = {idx: first_step[uid] - submit_tick[idx]
                  for idx, uid in uid_of.items() if uid in first_step}
    return tokens, ttft_steps, engine


def run_priority(*, smoke: bool = True, seed: int = 0) -> tuple:
    """Priority + preemption scheduling cells (``--priority``).

    One mixed-priority overloaded trace (25% class-0 urgent) replayed
    five ways: priority scheduling with preemption on both KV layouts,
    preemption off on both layouts, and a priority-stripped FIFO
    baseline.  Asserted/gated (all step-count based, so deterministic):

    * ``priority_ttft_regression`` — class-0 p95 TTFT (in engine steps)
      under priority scheduling minus the FIFO baseline's, clamped at 0:
      priority must beat (or tie) FIFO for the urgent class.  Hard gate 0.
    * ``resumed_tokens_mismatch`` per layout — requests whose tokens
      differ between the preemption-on and preemption-off replays; a
      resumed stream that is not bit-identical hard-fails at 0.
    * ``preempt_leaked_blocks`` / ``preempt_violations`` — 0 after drain.
    * ``preemptions`` — the scenario must actually preempt (>= 1).
    """
    from repro.launch.microbench import make_cell, provenance

    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    n_req = 12 if smoke else 24
    block_size = 4
    # load 1.0 = one expected arrival per decode step on a 2-slot batch:
    # a standing queue forms, which is the regime scheduling policy matters
    trace = make_trace(n_req, seed=seed, load=1.0, min_prompt=4,
                       max_prompt=10, min_new=4, max_new=10,
                       vocab=cfg.vocab, priority_mix=PRIORITY_MIX)
    klass = {idx: req.priority for idx, (_, req) in enumerate(trace)}
    import dataclasses as _dc
    fifo_trace = [(t, _dc.replace(r, priority=1)) for t, r in trace]

    prov = provenance()
    axes = dict(PRIORITY_DIMS, block_size=block_size, requests=n_req,
                load=1.0, priority_mix=",".join(map(str, PRIORITY_MIX)))
    paged = dict(PRIORITY_DIMS, kv_layout="paged", block_size=block_size)
    dense = dict(PRIORITY_DIMS, kv_layout="dense")

    tok_prio, ttft_prio, eng = _replay_counting_steps(
        model, cfg, trace, **paged)
    ps = eng.preempt_stats()
    leaked = eng.manager.allocator.n_in_use
    tok_off, _, _ = _replay_counting_steps(model, cfg, trace, **paged,
                                           preemption=False)
    dtok_on, _, deng = _replay_counting_steps(model, cfg, trace, **dense)
    dtok_off, _, _ = _replay_counting_steps(model, cfg, trace, **dense,
                                            preemption=False)
    _, ttft_fifo, _ = _replay_counting_steps(model, cfg, fifo_trace, **paged)

    def p95_class0(ttfts):
        vals = [s for idx, s in ttfts.items() if klass[idx] == 0]
        return float(np.percentile(np.asarray(vals), 95))

    prio_p95, fifo_p95 = p95_class0(ttft_prio), p95_class0(ttft_fifo)
    regression = max(0.0, prio_p95 - fifo_p95)
    mismatch = {
        "paged": sum(tok_prio[i] != tok_off[i] for i in tok_prio),
        "dense": sum(dtok_on[i] != dtok_off[i] for i in dtok_on),
    }
    print(f"priority    : class-0 ttft p95 {prio_p95:.0f} steps "
          f"(priority+preemption) vs {fifo_p95:.0f} steps (FIFO) "
          f"over {sum(1 for k in klass.values() if k == 0)} urgent reqs")
    print(f"preemption  : {ps['preemptions']} preempted / {ps['resumes']} "
          f"resumed, violations {ps['preempt_violations']}, "
          f"leaked blocks {leaked}, resumed-token mismatches "
          f"{mismatch['paged']} paged / {mismatch['dense']} dense")
    assert ps["preemptions"] >= 1, "overload scenario never preempted"
    assert ps["preempt_violations"] == 0
    assert leaked == 0 and deng.manager is None
    assert mismatch == {"paged": 0, "dense": 0}, mismatch
    assert prio_p95 <= fifo_p95, \
        f"priority scheduling lost to FIFO for class 0: {prio_p95} vs " \
        f"{fifo_p95} steps"

    cells = [
        make_cell("priority_ttft_regression", "class0_p95_steps", axes,
                  {"value": regression, "priority_p95_steps": prio_p95,
                   "fifo_p95_steps": fifo_p95}, prov, smoke=smoke),
        make_cell("resumed_tokens_mismatch", "paged", axes,
                  {"value": mismatch["paged"]}, prov, smoke=smoke),
        make_cell("resumed_tokens_mismatch", "dense", axes,
                  {"value": mismatch["dense"]}, prov, smoke=smoke),
        make_cell("preempt_leaked_blocks", "paged", axes,
                  {"value": leaked}, prov, smoke=smoke),
        make_cell("preempt_violations", "paged", axes,
                  {"value": ps["preempt_violations"]}, prov, smoke=smoke),
        make_cell("preemptions", "paged", axes,
                  {"value": ps["preemptions"],
                   "resumes": ps["resumes"]}, prov, smoke=smoke),
    ]
    paths = sorted({f"{c['metric']}/{c['variant']}" for c in cells})
    cells.append(make_cell("cells_emitted", "priority_serve", {},
                           {"value": len(cells), "paths": paths}, prov,
                           smoke=smoke))
    summary = {"suite": "priority_serve", "smoke": smoke, "seed": seed,
               "class0_ttft_p95_steps": {"priority": prio_p95,
                                         "fifo": fifo_p95},
               "preempt_stats": ps, "leaked_blocks": leaked,
               "resumed_tokens_mismatch": mismatch, "cells": cells}
    return cells, summary


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + short trace (CI gate)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the run summary as JSON (CI artifact / "
                        "BENCH_serve.json)")
    p.add_argument("--fact-rank", type=float, default=0.5)
    p.add_argument("--solver", default="svd")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--sharded", action="store_true",
                   help="run the dp x tp sharded serving sweep instead of "
                        "the replay suite (re-execs itself under 8 forced "
                        "CPU host devices when fewer than 4 are visible)")
    p.add_argument("--priority", action="store_true",
                   help="run the priority + preemption scheduling cells "
                        "instead of the replay suite (step-count TTFT vs "
                        "a FIFO baseline, resumed-token bit-identity)")
    p.add_argument("--history", default="",
                   help="append the sharded/priority cells to this JSONL "
                        "perf trajectory (BENCH_history.jsonl)")
    args = p.parse_args(argv)
    if args.priority:
        cells, summary = run_priority(smoke=args.smoke, seed=args.seed)
        if args.history:
            from repro.launch.microbench import append_history
            n = append_history(args.history, cells)
            print(f"# appended {n} cells to {args.history}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(summary, f, indent=2, default=float)
                f.write("\n")
            print(f"wrote summary to {args.json}")
        print("serve_continuous priority: OK")
        return 0
    if args.sharded:
        if len(jax.devices()) < 4:
            env = dict(os.environ)
            env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            env.setdefault("JAX_PLATFORMS", "cpu")
            print("# <4 devices visible; re-exec with "
                  "--xla_force_host_platform_device_count=8")
            return subprocess.run(
                [sys.executable, __file__] + list(argv or sys.argv[1:]),
                env=env).returncode
        cells, summary = run_sharded(smoke=args.smoke, seed=args.seed)
        for v, row in summary["grid"].items():
            print(f"  {v}: {row['tokens_per_s']:8.1f} tok/s   decode "
                  f"{row['decode_step_ms']:7.3f} ms/step "
                  f"({row['devices']} device(s))")
        if args.history:
            from repro.launch.microbench import append_history
            n = append_history(args.history, cells)
            print(f"# appended {n} cells to {args.history}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(summary, f, indent=2, default=float)
                f.write("\n")
            print(f"wrote summary to {args.json}")
        print("serve_continuous sharded: OK")
        return 0
    _, summary = run(smoke=args.smoke, fact_rank=args.fact_rank,
                     solver=args.solver, seed=args.seed)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
            f.write("\n")
        print(f"wrote summary to {args.json}")
    print("serve_continuous: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
