"""Paper Fig. 2 (left): factorization-by-design.

Factorize a fresh model with the `random` solver at several rank ratios,
train each from scratch, and report relative performance (eval loss vs the
dense baseline) and speed-up (train step time ratio) — the purple/green
curves of the paper's left panel, on the synthetic Markov-LM task.
"""

from __future__ import annotations

import jax

from benchmarks.common import eval_loss, param_millions, tiny_cfg, train_model
from repro.core import auto_fact
from repro.models import build_model

RATIOS = (0.75, 0.5, 0.25, 0.1)


def run(steps: int = 150, seed: int = 0) -> list[dict]:
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(seed)
    rows = []

    dense = build_model(key, cfg)
    dense_trained, dense_loss, dense_dt = train_model(dense, cfg, steps=steps)
    dense_eval, dense_fwd = eval_loss(dense_trained, cfg)
    rows.append({"variant": "dense", "ratio": 1.0,
                 "params_M": param_millions(dense),
                 "train_s_per_step": dense_dt, "eval_loss": dense_eval,
                 "rel_perf": 1.0, "speedup": 1.0})

    for ratio in RATIOS:
        fact = auto_fact(build_model(key, cfg), ratio, solver="random",
                         key=jax.random.fold_in(key, int(ratio * 100)),
                         exclude=["embed", "lm_head"])
        trained, loss, dt = train_model(fact, cfg, steps=steps)
        ev, fwd = eval_loss(trained, cfg)
        rows.append({"variant": f"by-design@{ratio}", "ratio": ratio,
                     "params_M": param_millions(fact),
                     "train_s_per_step": dt, "eval_loss": ev,
                     "rel_perf": dense_eval / ev,  # lower loss => better
                     "speedup": dense_dt / dt})
    return rows


def main() -> None:
    for row in run():
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items()))


if __name__ == "__main__":
    main()
