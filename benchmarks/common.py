"""Shared benchmark helpers: timed training/eval on the synthetic tasks,
plus the provenance stamp every emitted perf number must carry."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import icl_batch, markov_lm_batch
from repro.models import build_model
from repro.optim import AdamW, linear_warmup_cosine
from repro.train import TrainState, make_train_step, make_eval_step

# canonical provenance stamp + comparability predicate — one definition,
# shared by the microbench harness, the serving benchmark, and the gate
from repro.launch.microbench import comparable, provenance  # noqa: F401


def timing_cell(ms: float, prov: dict | None = None, **extra) -> dict:
    """A provenance-stamped timing: ``{"ms": ..., "backend": ...,
    "compiled_backend": ..., "interpret_mode": ...}``.  Bare floats in
    benchmark summaries are how an interpret-mode 5x "slowdown" ends up
    mislabeled as a real perf number — always emit through this."""
    return {"ms": ms, **(prov if prov is not None else provenance()),
            **extra}


def assert_comparable(a: dict, b: dict) -> None:
    """Refuse to compare timings across provenance mismatches."""
    if not comparable(a, b):
        keys = ("backend", "interpret_mode", "compiled_backend")
        raise ValueError(
            "refusing to compare timings with mismatched provenance: "
            + " vs ".join(str({k: c.get(k) for k in keys})
                          for c in (a, b)))


def speedup(baseline: dict, candidate: dict) -> float:
    """baseline_ms / candidate_ms, but only within one provenance —
    raises ValueError on a cross-provenance comparison."""
    assert_comparable(baseline, candidate)
    return baseline["ms"] / candidate["ms"]


def tiny_cfg(**overrides):
    cfg = get_config("paper-tiny")
    return cfg.replace(**overrides) if overrides else cfg


def train_model(model, cfg, *, steps: int, batch: int = 16, seq: int = 64,
                lr: float = 3e-3, seed: int = 7, task: str = "markov"):
    """Train and return (model, final_loss, s_per_step)."""
    opt = AdamW(linear_warmup_cosine(lr, steps // 10 + 1, steps),
                weight_decay=0.01, master_fp32=False)
    state = TrainState(model=model, opt=opt.init(model),
                       step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(opt))

    def get_batch(i):
        if task == "markov":
            b = markov_lm_batch(i, batch=batch, seq=seq, vocab=cfg.vocab,
                                seed=seed)
            return {"tokens": b.tokens, "labels": b.labels}
        b = icl_batch(i, batch=batch, n_pairs=max(seq // 4, 2),
                      vocab=cfg.vocab, seed=seed)
        return {"tokens": b.tokens, "labels": b.labels}

    # warmup/compile
    state, metrics = step_fn(state, get_batch(0))
    jax.block_until_ready(metrics["loss"])
    t0 = time.time()
    for i in range(1, steps):
        state, metrics = step_fn(state, get_batch(i))
    jax.block_until_ready(metrics["loss"])
    dt = (time.time() - t0) / max(steps - 1, 1)
    return state.model, float(metrics["loss"]), dt


def eval_loss(model, cfg, *, batches: int = 8, batch: int = 32,
              seq: int = 64, seed: int = 7, task: str = "markov"):
    """Returns (mean loss, s_per_batch forward).

    NOTE: must use the TRAINING seed — the seed selects the underlying
    Markov chain; evaluation uses unseen steps (10k+) of the same chain."""
    eval_fn = jax.jit(make_eval_step())
    tot = 0.0
    # compile
    b = markov_lm_batch(10_000, batch=batch, seq=seq, vocab=cfg.vocab,
                        seed=seed)
    m = eval_fn(model, {"tokens": b.tokens, "labels": b.labels})
    jax.block_until_ready(m["loss"])
    t0 = time.time()
    for i in range(batches):
        b = markov_lm_batch(10_001 + i, batch=batch, seq=seq,
                            vocab=cfg.vocab, seed=seed)
        m = eval_fn(model, {"tokens": b.tokens, "labels": b.labels})
        tot += float(m["loss"])
    dt = (time.time() - t0) / batches
    return tot / batches, dt


def icl_accuracy(model, cfg, *, batches: int = 8, batch: int = 64,
                 n_pairs: int = 8, seed: int = 99):
    """Few-shot induction accuracy: argmax at the query position."""

    @jax.jit
    def acc_fn(model, tokens, qpos, answer):
        logits, _ = model(tokens)
        pred = jnp.argmax(
            jnp.take_along_axis(logits, qpos[:, None, None], axis=1)[:, 0],
            axis=-1)
        return jnp.mean((pred == answer).astype(jnp.float32))

    b = icl_batch(50_000, batch=batch, n_pairs=n_pairs, vocab=cfg.vocab,
                  seed=seed)
    a = acc_fn(model, b.tokens, b.query_pos, b.answer)
    jax.block_until_ready(a)
    t0 = time.time()
    tot = 0.0
    for i in range(batches):
        b = icl_batch(50_001 + i, batch=batch, n_pairs=n_pairs,
                      vocab=cfg.vocab, seed=seed)
        tot += float(acc_fn(model, b.tokens, b.query_pos, b.answer))
    dt = (time.time() - t0) / batches
    return tot / batches, dt


def param_millions(model) -> float:
    from repro.nn import param_count

    return param_count(model) / 1e6
