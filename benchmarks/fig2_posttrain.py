"""Paper Fig. 2 (center): post-training factorization.

Train ONE dense model, then factorize it at several rank ratios with each
solver (svd / snmf / random) and evaluate WITHOUT retraining.  Reproduces the
paper's claims that (a) SVD retains performance at moderate ratios, and
(b) the random solver destroys a trained model (it ignores W).
"""

from __future__ import annotations

import jax

from benchmarks.common import eval_loss, param_millions, tiny_cfg, train_model
from repro.core import auto_fact
from repro.models import build_model

RATIOS = (0.75, 0.5, 0.25, 0.1)
SOLVERS = ("svd", "snmf", "random")


def run(steps: int = 200, seed: int = 0) -> list[dict]:
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(seed)
    dense = build_model(key, cfg)
    dense, _, _ = train_model(dense, cfg, steps=steps)
    dense_eval, dense_fwd = eval_loss(dense, cfg)
    rows = [{"variant": "dense", "solver": "-", "ratio": 1.0,
             "params_M": param_millions(dense), "eval_loss": dense_eval,
             "rel_perf": 1.0, "speedup": 1.0}]

    for solver in SOLVERS:
        for ratio in RATIOS:
            fact = auto_fact(dense, ratio, solver=solver, num_iter=50,
                             key=jax.random.fold_in(key, hash(solver) % 997),
                             exclude=["embed", "lm_head"])
            ev, fwd = eval_loss(fact, cfg)
            rows.append({"variant": f"{solver}@{ratio}", "solver": solver,
                         "ratio": ratio, "params_M": param_millions(fact),
                         "eval_loss": ev, "rel_perf": dense_eval / ev,
                         "speedup": dense_fwd / fwd})
    return rows


def main() -> None:
    for row in run():
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items()))


if __name__ == "__main__":
    main()
