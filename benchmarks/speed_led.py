"""LED vs dense Linear micro-benchmark (wall time + theoretical FLOPs).

Measures the jnp path (the one XLA optimizes on every backend).  The Pallas
kernel targets TPU; on this CPU container it runs in interpret mode, so its
wall-time is not meaningful — its contribution is measured structurally in
the roofline (§Perf: HBM traffic of the fused vs unfused LED).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import nn

SIZES = [(1024, 1024, 1024), (2048, 2048, 2048), (4096, 1024, 4096)]
RATIOS = (0.5, 0.25, 0.1)


def _time(fn, *args, iters: int = 20) -> float:
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for m, k, n in SIZES:
        x = jax.random.normal(key, (m, k))
        lin = nn.Linear.create(key, k, n)
        t_dense = _time(jax.jit(lambda x, l: l(x)), x, lin)
        for ratio in RATIOS:
            r = max(1, int(ratio * (k * n) / (k + n)))
            led = nn.LED.create(key, k, n, r)
            t_led = _time(jax.jit(lambda x, l: l(x)), x, led)
            flop_ratio = (k * n) / (r * (k + n))
            rows.append({
                "shape": f"{m}x{k}x{n}", "rank": r,
                "dense_us": t_dense * 1e6, "led_us": t_led * 1e6,
                "speedup": t_dense / t_led,
                "theory_speedup": flop_ratio,
            })
    return rows


def main() -> None:
    for row in run():
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items()))


if __name__ == "__main__":
    main()
