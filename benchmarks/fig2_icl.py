"""Paper Fig. 2 (right): in-context-learning factorization.

Train a small LM on the synthetic induction task until in-context learning
emerges (the model retrieves a value for a repeated key), then apply
post-training SVD factorization at several ratios and measure the few-shot
accuracy drop + speed-up — the paper's third use case, where a PRETRAINED
model's ICL ability must survive factorization.
"""

from __future__ import annotations

import jax

from benchmarks.common import icl_accuracy, tiny_cfg, train_model
from repro.core import auto_fact
from repro.models import build_model

RATIOS = (0.75, 0.5, 0.25, 0.1)


def run(steps: int = 400, seed: int = 0) -> list[dict]:
    cfg = tiny_cfg(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   head_dim=16, d_ff=256, vocab=64)
    key = jax.random.PRNGKey(seed)
    model = build_model(key, cfg)
    model, _, _ = train_model(model, cfg, steps=steps, seq=32, batch=64,
                              lr=1e-2, task="icl")
    dense_acc, dense_dt = icl_accuracy(model, cfg)
    rows = [{"variant": "dense", "ratio": 1.0, "icl_acc": dense_acc,
             "rel_perf": 1.0, "speedup": 1.0}]
    for ratio in RATIOS:
        fact = auto_fact(model, ratio, solver="svd",
                         exclude=["embed", "lm_head"])
        acc, dt = icl_accuracy(fact, cfg)
        rows.append({"variant": f"svd@{ratio}", "ratio": ratio,
                     "icl_acc": acc,
                     "rel_perf": acc / max(dense_acc, 1e-9),
                     "speedup": dense_dt / dt})
    return rows


def main() -> None:
    for row in run():
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items()))


if __name__ == "__main__":
    main()
