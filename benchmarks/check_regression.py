"""Gate the perf trajectory in ``BENCH_history.jsonl``.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --history BENCH_history.jsonl \
        --thresholds benchmarks/thresholds.json

Reads the append-only JSONL of microbench cells (see
``repro.launch.microbench``), groups them by **series** — (metric,
variant, sweep axes) **and provenance signature** (backend,
interpret_mode, compiled_backend) — and compares each series' newest
cell against the best prior cell *of the same series*.  Cells with
different provenance are never compared: an interpret-mode CPU timing
vs a compiled TPU timing is a category error, not a regression (the
exact mislabeling that made ``decode_step_ms.paged_pallas_fused`` in
the old BENCH_serve.json read as a 5x slowdown).

Threshold rules (``benchmarks/thresholds.json``) match series by glob
on ``metric/variant`` and come in three kinds:

* ``timing``      — newest ``mean_ms`` may exceed the best prior
                    ``mean_ms`` by at most ``max_regression_pct``.
                    Violations are WARN-only unless the cell was
                    actually compiled for hardware
                    (``compiled_backend`` non-null): CPU/interpret
                    timings on shared CI runners are too noisy to
                    block a merge, compiled timings are not.
* ``correctness`` — newest ``value`` must be ``<= max_value``
                    (kernel-vs-oracle parity).  Always hard-fails.
* ``count``       — newest ``value`` must be ``>= min_value`` (a
                    benchmarked path disappearing from the sweep).
                    Always hard-fails.

Exit status 1 iff any hard failure.  ``check()`` is importable for the
unit test in ``tests/test_bench_history.py``.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys
from typing import Iterable, Optional


def load_history(path: str) -> list[dict]:
    cells = []
    with open(path) as fh:
        for ln, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                cells.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{ln}: not valid JSON ({e})")
    return cells


def load_thresholds(path: str) -> list[dict]:
    with open(path) as fh:
        rules = json.load(fh)
    for r in rules:
        if r.get("kind") not in ("timing", "correctness", "count"):
            raise SystemExit(f"threshold rule {r!r}: unknown kind")
    return rules


def _series_key(cell: dict) -> str:
    from repro.launch.microbench import cell_key

    return cell_key(cell)


def provenance_sig(cell: dict) -> tuple:
    p = cell.get("provenance", {})
    return (p.get("backend"), p.get("interpret_mode"),
            p.get("compiled_backend"))


def _rule_for(rules: list[dict], metric_variant: str) -> Optional[dict]:
    for r in rules:
        if fnmatch.fnmatch(metric_variant, r["pattern"]):
            return r
    return None


def check(cells: Iterable[dict], rules: list[dict]
          ) -> tuple[list[str], list[str]]:
    """Returns (hard_failures, warnings), each a list of messages.

    History order matters: the LAST cell of each series is "newest" and
    is judged against the best (timing) prior cell of that series.  A
    series with no prior cell establishes its baseline silently.
    """
    series: dict[tuple, list[dict]] = {}
    for cell in cells:
        key = (_series_key(cell), provenance_sig(cell))
        series.setdefault(key, []).append(cell)

    failures: list[str] = []
    warnings: list[str] = []
    for (skey, sig), run in series.items():
        newest = run[-1]
        mv = f"{newest['metric']}/{newest['variant']}"
        rule = _rule_for(rules, mv)
        if rule is None:
            continue
        tag = (sig[2] or f"{sig[0]}+interpret")
        if rule["kind"] == "correctness":
            value = newest["stats"]["value"]
            if value > rule["max_value"]:
                failures.append(
                    f"CORRECTNESS {skey} [{tag}]: {value:g} > "
                    f"max {rule['max_value']:g}")
        elif rule["kind"] == "count":
            value = newest["stats"]["value"]
            if value < rule["min_value"]:
                failures.append(
                    f"COUNT {skey} [{tag}]: {value:g} < "
                    f"min {rule['min_value']:g} — a benchmarked path "
                    f"disappeared from the sweep")
        else:  # timing
            prior = [c for c in run[:-1] if "mean_ms" in c["stats"]]
            if not prior or "mean_ms" not in newest["stats"]:
                continue  # first cell of the series: becomes baseline
            base = min(c["stats"]["mean_ms"] for c in prior)
            now = newest["stats"]["mean_ms"]
            limit = base * (1 + rule["max_regression_pct"] / 100.0)
            if now > limit:
                pct = (now / base - 1) * 100
                msg = (f"TIMING {skey} [{tag}]: {now:.3f} ms vs "
                       f"baseline {base:.3f} ms (+{pct:.0f}%, allowed "
                       f"+{rule['max_regression_pct']:.0f}%)")
                # Only compiled-for-hardware timings block the merge;
                # CPU/interpret numbers on shared runners warn.
                if sig[2] is not None:
                    failures.append(msg)
                else:
                    warnings.append(msg + "  [warn-only: not compiled "
                                    "for hardware]")
    return failures, warnings


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--history", default="BENCH_history.jsonl")
    p.add_argument("--thresholds", default="benchmarks/thresholds.json")
    args = p.parse_args(argv)
    cells = load_history(args.history)
    rules = load_thresholds(args.thresholds)
    failures, warnings = check(cells, rules)
    n_series = len({(_series_key(c), provenance_sig(c)) for c in cells})
    print(f"checked {len(cells)} cells across {n_series} series "
          f"({len(rules)} threshold rules)")
    for w in warnings:
        print(f"  WARN {w}")
    for f in failures:
        print(f"  FAIL {f}")
    if failures:
        print(f"{len(failures)} hard failure(s)")
        return 1
    print("perf trajectory OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
