"""Serving engine: greedy decode == scan-based generate == full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Engine, generate


def test_engine_greedy_matches_generate(key):
    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)

    eng = Engine(model, cfg, batch=2, max_len=24, cache_dtype=jnp.float32)
    out_eng = eng.greedy(toks, 6)

    cache = model.init_cache(2, 24, cfg, dtype=jnp.float32)
    out_gen, _ = generate(model, toks, cache, n_steps=6)
    np.testing.assert_array_equal(np.asarray(out_eng), np.asarray(out_gen))


def test_greedy_matches_teacher_forced_argmax(key):
    """Greedy decode must equal argmax of the full forward on its own
    continuation (consistency of the incremental path)."""
    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab)
    eng = Engine(model, cfg, batch=1, max_len=32, cache_dtype=jnp.float32)
    gen = eng.greedy(toks, 5)
    seq = jnp.concatenate([toks, gen], axis=1)
    logits, _ = model(seq)
    ref = jnp.argmax(logits[:, 7:-1], axis=-1)
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(ref))


def test_engine_reset(key):
    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    eng = Engine(model, cfg, batch=2, max_len=24, cache_dtype=jnp.float32)
    a = eng.greedy(toks, 4)
    eng.reset()
    b = eng.greedy(toks, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_temperature_sampling_runs(key):
    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    cache = model.init_cache(2, 24, cfg, dtype=jnp.float32)
    out, _ = generate(model, toks, cache, n_steps=4, temperature=1.0,
                      key=key)
    assert out.shape == (2, 4)
    assert int(out.max()) < cfg.vocab


def test_lockstep_engine_with_ssm_cache(key):
    """The lock-step Engine must work with SSM-state caches (mamba
    family) — the fixed-batch baseline path."""
    cfg = get_config("mamba2-2.7b").reduced()
    model = build_model(key, cfg)
    eng = Engine(model, cfg, batch=2, max_len=24, cache_dtype=jnp.float32)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    out = eng.greedy(toks, 4)
    assert out.shape == (2, 4)
    # consistency with teacher-forced argmax
    seq = jnp.concatenate([toks, out], axis=1)
    logits, _ = model(seq)
    ref = jnp.argmax(logits[:, 7:-1], axis=-1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_continuous_engine_with_ssm_cache(key):
    """The continuous engine serves the same SSM family through per-slot
    conv/ssm state — separate, non-shadowing coverage from the lock-step
    case above (this used to be a single Engine-only test)."""
    from repro.serve import ContinuousEngine

    cfg = get_config("mamba2-2.7b").reduced()
    model = build_model(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    lock = Engine(model, cfg, batch=2, max_len=24, cache_dtype=jnp.float32)
    ref = np.asarray(lock.greedy(toks, 4))
    eng = ContinuousEngine(model, cfg, batch=2, max_len=24,
                           max_prompt_len=12, chunk_size=4)
    for row in np.asarray(toks):
        eng.submit(row.astype(np.int32), max_new_tokens=4)
    comps = eng.run()
    assert eng.kv_stats()["cache_kind"] == "ssm"
    for row, c in zip(ref, comps):
        np.testing.assert_array_equal(np.array(c.tokens), row)


def test_engine_with_factorized_model(key):
    """Post-training-factorized models serve through the same engine."""
    from repro.core import auto_fact

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    fact = auto_fact(model, 0.9, solver="svd", exclude=["embed"])
    eng = Engine(fact, cfg, batch=2, max_len=16, cache_dtype=jnp.float32)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    out = eng.greedy(toks, 4)
    assert out.shape == (2, 4) and int(out.max()) < cfg.vocab


def test_trace_replay_drains_and_reports(key):
    """Poisson trace replay: every request completes, stats are coherent."""
    from repro.serve import (ContinuousEngine, latency_stats, make_trace,
                             replay)

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8)
    trace = make_trace(6, seed=3, load=1.0, min_prompt=2, max_prompt=8,
                       min_new=2, max_new=6, vocab=cfg.vocab)
    completions, wall = replay(eng, trace)
    assert len(completions) == 6
    assert all(1 <= len(c.tokens) <= 6 for c in completions)
    assert all(c.latency >= c.ttft >= 0 for c in completions)
    stats = latency_stats(completions, wall)
    assert stats["requests"] == 6
    assert stats["generated_tokens"] == sum(len(c.tokens)
                                            for c in completions)
    assert stats["tokens_per_s"] > 0
    assert stats["latency_p95_ms"] >= stats["latency_p50_ms"]


def test_trace_is_deterministic():
    from repro.serve import make_trace

    a = make_trace(5, seed=9, load=0.5)
    b = make_trace(5, seed=9, load=0.5)
    for (ta, ra), (tb, rb) in zip(a, b):
        assert ta == tb and ra.max_new_tokens == rb.max_new_tokens
        assert (ra.prompt == rb.prompt).all()


def test_trace_shared_prefix():
    from repro.serve import make_trace

    trace = make_trace(4, seed=2, min_prompt=2, max_prompt=6,
                       shared_prefix=8)
    first = trace[0][1].prompt[:8]
    for _, r in trace:
        assert r.prompt.size >= 10
        np.testing.assert_array_equal(r.prompt[:8], first)


def test_stream_yields_every_token_in_order(key):
    """The streaming API must yield exactly the tokens each completion
    reports, in generation order, attaching the Completion to the last
    token — and the push callback must see the same sequence."""
    from repro.serve import ContinuousEngine

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8)
    pushed = []
    eng.on_token = lambda uid, tok: pushed.append((uid, tok))
    rng = np.random.default_rng(1)
    uids = [eng.submit(rng.integers(0, cfg.vocab, n).astype(np.int32),
                       max_new_tokens=m)
            for n, m in [(5, 4), (3, 6), (7, 3)]]
    seen: dict = {u: [] for u in uids}
    comps: dict = {}
    for uid, tok, comp in eng.stream():
        seen[uid].append(tok)
        if comp is not None:
            assert comp.uid == uid
            comps[uid] = comp
    assert sorted(comps) == sorted(uids)  # every request completed
    for uid, comp in comps.items():
        assert seen[uid] == comp.tokens         # streamed == collected
        assert seen[uid][-1] == comp.tokens[-1]  # done rode the last token
    assert sorted(pushed) == sorted(
        (u, t) for u, toks in seen.items() for t in toks)


def test_paged_kv_resident_bytes_below_dense_allocation(key):
    """The point of paging: on a mixed-length trace the peak HBM-resident
    KV bytes of the paged layout stay well under the dense layout's
    batch*max_len reservation, with identical greedy tokens."""
    from repro.serve import bench_trace, make_trace

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    trace = make_trace(6, seed=3, load=1.0, min_prompt=2, max_prompt=8,
                       min_new=2, max_new=6, vocab=cfg.vocab)
    dims = dict(batch=2, max_len=64, max_prompt_len=8)
    dense_done, dstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="dense")
    paged_done, pstats = bench_trace(model, cfg, trace, **dims,
                                     kv_layout="paged", block_size=8)
    for cd, cp in zip(dense_done, paged_done):
        assert cd.tokens == cp.tokens
    assert dstats["kv_layout"] == "dense"
    assert pstats["kv_layout"] == "paged"
    # each request needs at most 14 positions => 2 blocks of 8; dense pins
    # 2 slots * 64 lanes
    assert pstats["peak_blocks_in_use"] <= 4
    assert pstats["kv_peak_resident_bytes"] * 2 <= \
        dstats["kv_allocated_bytes"]


def test_on_token_error_does_not_desync_engine(key):
    """A raising ``on_token`` consumer must not corrupt host bookkeeping:
    the run still completes with the exact same tokens as a clean run,
    the error is recorded in ``on_token_errors``, and the paged pool
    drains back to empty."""
    from repro.serve import ContinuousEngine

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    dims = dict(batch=2, max_len=32, max_prompt_len=8, block_size=8)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 3, 7)]

    clean = ContinuousEngine(model, cfg, **dims)
    for p in prompts:
        clean.submit(p, max_new_tokens=4)
    want = [c.tokens for c in sorted(clean.run(), key=lambda c: c.uid)]

    def boom(uid, tok):
        raise RuntimeError("consumer bug")

    eng = ContinuousEngine(model, cfg, **dims)
    eng.on_token = boom
    for p in prompts:
        eng.submit(p, max_new_tokens=4)
    done = sorted(eng.run(), key=lambda c: c.uid)
    assert [c.tokens for c in done] == want
    assert all(c.finish_reason in ("stop", "length") for c in done)
    # one recorded error per emitted token, none swallowed silently
    assert len(eng.on_token_errors) == sum(len(c.tokens) for c in done)
    uid_tok = [(u, t) for u, t, _ in eng.on_token_errors]
    assert sorted(uid_tok) == sorted(
        (c.uid, t) for c in done for t in c.tokens)
    assert all("consumer bug" in msg for _, _, msg in eng.on_token_errors)
    assert eng.manager.fully_free  # no leaked blocks


def test_greedy_agreement_skips_empty_pairs():
    """Pairs with no overlapping tokens (e.g. one side cancelled before
    its first token) carry no evidence and must be skipped — previously
    an empty pair produced a NaN that poisoned the mean."""
    from repro.serve import Completion, greedy_agreement

    def comp(tokens):
        return Completion(uid=0, prompt_len=4, tokens=list(tokens),
                          finish_reason="stop")

    a = [comp([1, 2, 3]), comp([]), comp([5, 6])]
    b = [comp([1, 2, 9]), comp([4, 4]), comp([5, 6, 7])]
    score = greedy_agreement(a, b)
    assert not np.isnan(score)
    # pair 0 agrees 2/3, pair 1 skipped, pair 2 agrees 2/2
    assert score == pytest.approx((2 / 3 + 1.0) / 2)
    # all-empty traces: vacuous agreement, not NaN
    assert greedy_agreement([comp([])], [comp([1])]) == 1.0
    assert greedy_agreement([], []) == 1.0


def test_latency_stats_skips_cancelled_before_first_token():
    """TTFT over completions cancelled before their first token
    (``first_token_at == 0.0``) is meaningless; the reducer must not
    fold huge negative values into the percentiles."""
    from repro.serve import Completion, latency_stats

    served = Completion(uid=1, prompt_len=4, tokens=[1, 2],
                        finish_reason="length", submitted_at=10.0,
                        first_token_at=10.5, finished_at=11.0)
    killed = Completion(uid=2, prompt_len=4, tokens=[],
                        finish_reason="cancelled", submitted_at=10.0,
                        first_token_at=0.0, finished_at=10.2)
    stats = latency_stats([served, killed], wall=2.0)
    assert stats["ttft_p50_ms"] == pytest.approx(500.0)
    assert stats["ttft_p50_ms"] >= 0.0
