"""Speculative decoding: low-rank draft, dense verify, bit-exact output.

The draft model proposes ``spec_k`` greedy tokens with cheap factorized
weights; the dense verifier re-scores them in ONE multi-token decode and
the engine commits the agreeing prefix plus the verifier's own next
token.  Every emitted token is a dense argmax conditioned on previously
emitted tokens, so the output is bit-identical to plain greedy decoding
*by construction* — the draft can only change how many tokens land per
step, never which tokens.  These tests pin that contract:

- spec engine == plain engine == one-shot ``generate``, token for token,
  across paged and dense KV layouts, stop ids, and slot recycling;
- property sweep over draft depth k and trace seeds (via ``_hyp``);
- a pathologically bad draft (random solver) still terminates and still
  emits exact tokens — just with zero accepted drafts;
- the multi-token decode primitive underneath the verifier matches s
  sequential single-token decodes bit for bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.core import auto_fact, spectral_decay
from repro.models import build_model
from repro.serve import (ContinuousEngine, format_kv_stats, generate,
                         make_trace, replay)
from repro.serve.engine import UnsupportedCacheError

EXCLUDE = ["embed", "lm_head"]


@pytest.fixture(scope="module")
def shaped():
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return spectral_decay(model, 2.5, exclude=EXCLUDE), cfg


@pytest.fixture(scope="module")
def draft(shaped):
    """Rank-0.5 SVD factorization of the serving model: cheap enough to
    draft with, close enough to be accepted most of the time."""
    model, _ = shaped
    return auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE, gate=False)


def _baseline(model, cfg, prompt, n, max_len=64):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return np.asarray(out)[0]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


def _engine(model, cfg, *, batch=4, max_len=64, **kw):
    return ContinuousEngine(model, cfg, batch=batch, max_len=max_len,
                            max_prompt_len=32, chunk_size=8,
                            buckets=(8, 16, 32), **kw)


# ---- bit-exactness vs the plain engine --------------------------------------


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_spec_matches_plain_engine(shaped, draft, layout):
    """Same trace through a speculative engine and a plain one: tokens
    and finish reasons identical, and the good draft earns a nonzero
    acceptance rate."""
    model, cfg = shaped
    trace = make_trace(6, seed=41, load=0.7, min_prompt=3, max_prompt=20,
                       min_new=4, max_new=12, vocab=cfg.vocab)
    plain = _engine(model, cfg, kv_layout=layout)
    spec = _engine(model, cfg, kv_layout=layout, draft_model=draft,
                   spec_k=4)
    pc, _ = replay(plain, trace)
    sc, _ = replay(spec, trace)
    assert len(sc) == len(trace)
    # uid counters are global across engines: compare by submission order
    for (_, req), p, s in zip(trace, pc, sc):
        np.testing.assert_array_equal(
            np.array(s.tokens), np.array(p.tokens),
            err_msg=f"{layout}: spec diverged, plen={req.prompt.size}")
        assert s.finish_reason == p.finish_reason
    stats = spec.spec_stats()
    assert stats["spec_k"] == 4
    assert stats["spec_rounds"] > 0
    assert stats["spec_acceptance_rate"] > 0.0


def test_spec_matches_generate(shaped, draft):
    """Spec engine completions equal the one-shot ``generate`` ground
    truth (schedule-independent, so this also covers admission
    interleaving differing from the plain engine's)."""
    model, cfg = shaped
    prompts = _prompts([5, 12, 20, 3], cfg.vocab, seed=2)
    eng = _engine(model, cfg, draft_model=draft, spec_k=4)
    for p in prompts:
        eng.submit(p, max_new_tokens=10)
    comps = eng.run()
    assert len(comps) == len(prompts)
    for p, c in zip(prompts, comps):
        np.testing.assert_array_equal(np.array(c.tokens),
                                      _baseline(model, cfg, p, 10),
                                      err_msg=f"plen={p.size}")
        assert len(c.tokens) == 10  # no token lost, none duplicated


# ---- property sweep: draft depth x trace seed -------------------------------


_ENGINES = {}


@given(k=st.integers(1, 5), seed=st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_spec_bit_exact_property(shaped, draft, k, seed):
    """For any draft depth and any seeded workload, accepted-prefix
    commitment never changes the emitted tokens.  Engines are cached per
    k and reused across examples — reuse IS the test: stale spec state
    from a previous example's requests must not leak into the next."""
    model, cfg = shaped
    if k not in _ENGINES:
        _ENGINES[k] = _engine(model, cfg, draft_model=draft, spec_k=k)
    eng = _ENGINES[k]
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 24)))
               .astype(np.int32) for _ in range(3)]
    n_new = [int(rng.integers(2, 9)) for _ in range(3)]
    for p, n in zip(prompts, n_new):
        eng.submit(p, max_new_tokens=n)
    comps = eng.run()
    assert len(comps) == len(prompts)
    for p, n, c in zip(prompts, n_new, comps):
        assert len(c.tokens) == n
        np.testing.assert_array_equal(np.array(c.tokens),
                                      _baseline(model, cfg, p, n),
                                      err_msg=f"k={k} seed={seed} "
                                              f"plen={p.size}")


# ---- slot recycling ---------------------------------------------------------


def test_recycled_slot_no_loss_no_duplication(shaped, draft):
    """Four requests through a 1-slot spec engine: every request after
    the first reuses a slot whose main AND draft cache rows still hold
    the previous occupant's tokens beyond the parked frontier.  Each
    completion must match a fresh baseline with exact token counts."""
    model, cfg = shaped
    prompts = _prompts([9, 5, 12, 3], cfg.vocab, seed=21)
    eng = _engine(model, cfg, batch=1, draft_model=draft, spec_k=3)
    for p in prompts:
        eng.submit(p, max_new_tokens=6)
    comps = eng.run()
    assert len(comps) == len(prompts)
    for p, c in zip(prompts, comps):
        assert len(c.tokens) == 6
        np.testing.assert_array_equal(
            np.array(c.tokens), _baseline(model, cfg, p, 6),
            err_msg=f"recycled slot corrupted plen={p.size}")


# ---- degenerate draft: still exact, still terminates ------------------------


def test_degenerate_draft_terminates_and_stays_exact(shaped):
    """A random-solver rank-0.25 draft proposes garbage: acceptance
    collapses toward zero but the verifier's own argmax still advances
    every slot each round (m >= 1), so the engine terminates with the
    exact dense tokens."""
    model, cfg = shaped
    bad = auto_fact(model, 0.25, solver="random", exclude=EXCLUDE,
                    gate=False, key=jax.random.PRNGKey(9))
    prompts = _prompts([7, 14], cfg.vocab, seed=4)
    eng = _engine(model, cfg, batch=2, draft_model=bad, spec_k=3)
    for p in prompts:
        eng.submit(p, max_new_tokens=8)
    comps = eng.run(max_steps=500)  # termination bound
    for p, c in zip(prompts, comps):
        np.testing.assert_array_equal(np.array(c.tokens),
                                      _baseline(model, cfg, p, 8))
    stats = eng.spec_stats()
    assert stats["spec_rounds"] > 0
    assert 0.0 <= stats["spec_acceptance_rate"] <= 1.0


# ---- stop ids through the spec path -----------------------------------------


def test_spec_stop_ids_match_plain(shaped, draft):
    """Stop tokens may land mid-accepted-prefix: the spec engine must
    cut the emission at the stop exactly where the plain engine does."""
    model, cfg = shaped
    prompts = _prompts([6, 11, 17], cfg.vocab, seed=8)
    stop = (5, 17)
    plain = _engine(model, cfg)
    spec = _engine(model, cfg, draft_model=draft, spec_k=4)
    for p in prompts:
        plain.submit(p, max_new_tokens=12, stop_ids=stop)
        spec.submit(p, max_new_tokens=12, stop_ids=stop)
    pc, sc = plain.run(), spec.run()
    for p, s in zip(pc, sc):
        np.testing.assert_array_equal(np.array(s.tokens), np.array(p.tokens))
        assert s.finish_reason == p.finish_reason


# ---- accounting & guardrails ------------------------------------------------


def test_spec_accounting(shaped, draft):
    model, cfg = shaped
    eng = _engine(model, cfg, batch=2, draft_model=draft, spec_k=4)
    for p in _prompts([8, 15], cfg.vocab, seed=6):
        eng.submit(p, max_new_tokens=8)
    eng.run()
    s = eng.spec_stats()
    # each round drafts spec_k tokens per running slot (1..batch of them)
    assert s["spec_k"] * s["spec_rounds"] <= s["spec_drafted_tokens"] \
        <= s["spec_k"] * s["spec_rounds"] * eng.batch
    assert s["spec_drafted_tokens"] % s["spec_k"] == 0
    assert 0 <= s["spec_accepted_tokens"] <= s["spec_drafted_tokens"]
    assert s["spec_acceptance_rate"] == pytest.approx(
        s["spec_accepted_tokens"] / s["spec_drafted_tokens"])


def test_spec_rejects_sampling(shaped, draft):
    """Greedy-only: the accepted-prefix argument needs argmax on both
    sides, so sampled requests are refused up front."""
    model, cfg = shaped
    eng = _engine(model, cfg, batch=2, draft_model=draft, spec_k=2)
    with pytest.raises(ValueError, match="greedy"):
        eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=4,
                   temperature=0.8)


def test_spec_requires_draft_and_k(shaped, draft):
    model, cfg = shaped
    with pytest.raises(ValueError, match="draft_model and spec_k"):
        _engine(model, cfg, spec_k=3)
    with pytest.raises(ValueError, match="draft_model and spec_k"):
        _engine(model, cfg, draft_model=draft)


def test_spec_unsupported_cache_kind(draft):
    """Ring/hybrid/ssm slots have no multi-token decode; the constructor
    refuses a draft there instead of silently decoding wrong."""
    cfg = get_config("paper-tiny").reduced().replace(window=8)
    model = build_model(jax.random.PRNGKey(0), cfg)
    d = auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE, gate=False)
    with pytest.raises(UnsupportedCacheError):
        ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=16,
                         draft_model=d, spec_k=2)


# ---- the multi-token decode primitive ---------------------------------------


@pytest.mark.parametrize("per_slot", [False, True])
def test_multitoken_decode_matches_sequential(shaped, per_slot):
    """decode((b, s)) == s chained decode((b, 1)) calls, bit for bit —
    logits, cache contents and length counters — for the lock-step and
    per-slot dense layouts (the paged layout is covered end-to-end by
    the spec-vs-plain paged test)."""
    model, cfg = shaped
    b, s, plen = 2, 4, 6
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (b, plen)).astype(np.int32))
    c0 = model.init_cache(b, 32, cfg, dtype=jnp.float32, per_slot=per_slot)
    _, c0 = model.prefill(toks, c0)
    step = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab, (b, s)).astype(np.int32))

    l_multi, c_multi = model.decode(step, c0)
    assert l_multi.shape == (b, s, cfg.vocab)

    c_seq, logits = c0, []
    for j in range(s):
        lj, c_seq = model.decode(step[:, j:j + 1], c_seq)
        logits.append(lj)
    l_seq = jnp.concatenate(logits, axis=1)

    np.testing.assert_array_equal(np.asarray(l_multi), np.asarray(l_seq))
    np.testing.assert_array_equal(np.asarray(c_multi.k),
                                  np.asarray(c_seq.k))
    np.testing.assert_array_equal(np.asarray(c_multi.length),
                                  np.asarray(c_seq.length))


def test_multitoken_decode_ring_raises(shaped):
    """Sliding-window ring lanes reject s > 1 loudly."""
    cfg = get_config("paper-tiny").reduced().replace(window=8)
    model = build_model(jax.random.PRNGKey(1), cfg)
    c = model.init_cache(1, 32, cfg, dtype=jnp.float32)
    _, c = model.prefill(jnp.zeros((1, 4), jnp.int32), c)
    with pytest.raises(NotImplementedError):
        model.decode(jnp.zeros((1, 2), jnp.int32), c)


# ---- KV accounting with the draft's mirror cache ----------------------------


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_kv_stats_includes_draft_pool(shaped, draft, layout):
    """The draft model's mirror cache is real HBM: ``kv_stats`` must fold
    it into the aggregates and split it out as
    ``draft_kv_allocated_bytes`` — previously the draft pool was
    invisible, underreporting KV HBM by ~2x for a same-shape draft."""
    model, cfg = shaped
    plain = _engine(model, cfg, batch=2, kv_layout=layout)
    spec = _engine(model, cfg, batch=2, kv_layout=layout,
                   draft_model=draft, spec_k=2)
    base = plain.kv_stats()
    s = spec.kv_stats()
    assert "draft_kv_allocated_bytes" not in base
    dalloc = s["draft_kv_allocated_bytes"]
    assert dalloc > 0
    # the draft mirrors the verifier's geometry (same layers/heads/dims
    # in this factorization), so the split-out pool matches the base pool
    # and the aggregate is exactly base + draft
    assert s["kv_allocated_bytes"] == base["kv_allocated_bytes"] + dalloc
    if layout == "paged":
        # shared tables: one in-use block pins rows in both pools
        assert s["kv_block_bytes"] == 2 * base["kv_block_bytes"]
    fmt = format_kv_stats("spec", s)
    assert "draft" in fmt


def test_kv_stats_draft_counted_in_peak_resident(shaped, draft):
    """Peak-resident tracking must also see the draft pool: after a run,
    the paged peak with spec on is at least double the per-block cost of
    the same blocks without the draft."""
    model, cfg = shaped
    eng = _engine(model, cfg, batch=2, draft_model=draft, spec_k=2)
    for p in _prompts([8, 12], cfg.vocab, seed=9):
        eng.submit(p, max_new_tokens=6)
    eng.run()
    s = eng.kv_stats()
    assert s["kv_peak_resident_bytes"] \
        == s["peak_blocks_in_use"] * s["kv_block_bytes"]
    assert s["kv_peak_resident_bytes"] > 0
