"""Layer semantics: attention (GQA/rings), SSM scan-vs-recurrence, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn


# ---- attention ---------------------------------------------------------------


def naive_mha(q, k, v, causal=True):
    """O(s²) reference attention, full heads."""
    b, s, h, d = q.shape
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, h * d)


def test_gqa_matches_naive_when_mha(key):
    attn = nn.Attention.create(key, 32, 4, 4, rope=False)
    x = jax.random.normal(key, (2, 6, 32))
    q = attn.q_proj(x).reshape(2, 6, 4, 8)
    k = attn.k_proj(x).reshape(2, 6, 4, 8)
    v = attn.v_proj(x).reshape(2, 6, 4, 8)
    ref = attn.o_proj(naive_mha(q, k, v))
    np.testing.assert_allclose(np.asarray(attn(x)), np.asarray(ref),
                               atol=1e-5)


def test_gqa_repeats_kv_heads(key):
    """GQA == MHA with tiled K/V heads."""
    gqa = nn.Attention.create(key, 32, 4, 2, rope=False)
    x = jax.random.normal(key, (2, 5, 32))
    q = gqa.q_proj(x).reshape(2, 5, 4, 8)
    k = gqa.k_proj(x).reshape(2, 5, 2, 8)
    v = gqa.v_proj(x).reshape(2, 5, 2, 8)
    k_t = jnp.repeat(k, 2, axis=2)
    v_t = jnp.repeat(v, 2, axis=2)
    ref = gqa.o_proj(naive_mha(q, k_t, v_t))
    np.testing.assert_allclose(np.asarray(gqa(x)), np.asarray(ref),
                               atol=1e-5)


def test_rope_relative_property(key):
    """RoPE scores depend only on relative distance."""
    from repro.nn.rotary import apply_rope

    q = jax.random.normal(key, (1, 1, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 2, 16))
    def score(qpos, kpos):
        qr = apply_rope(q, jnp.array([[qpos]]))
        kr = apply_rope(k, jnp.array([[kpos]]))
        return jnp.einsum("bqhd,bkhd->bhqk", qr, kr)

    np.testing.assert_allclose(np.asarray(score(5, 3)),
                               np.asarray(score(105, 103)), atol=1e-3)


def test_sliding_window_mask(key):
    attn = nn.Attention.create(key, 16, 2, 2, window=2, rope=False)
    x = jax.random.normal(key, (1, 6, 16))
    # position 5 must ignore positions <= 3: perturbing x[0] can't change y[5]
    y1 = attn(x)
    x2 = x.at[0, 0].add(100.0)
    y2 = attn(x2)
    np.testing.assert_allclose(np.asarray(y1[0, 5]), np.asarray(y2[0, 5]),
                               atol=1e-4)
    assert float(jnp.abs(y1[0, 1] - y2[0, 1]).max()) > 1e-3  # in-window


def test_prefill_decode_matches_full(key):
    attn = nn.Attention.create(key, 32, 4, 2)
    x = jax.random.normal(key, (2, 9, 32))
    full = attn(x)
    cache = nn.KVCache.zeros(2, 16, 2, 8, dtype=jnp.float32)
    pre, cache = attn.prefill(x[:, :6], cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :6]),
                               atol=1e-5)
    for t in range(6, 9):
        y, cache = attn.decode(x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(y[:, 0]),
                                   np.asarray(full[:, t]), atol=1e-5)


def test_ring_buffer_decode_matches_full(key):
    """SWA with an O(window) ring cache must equal full SWA attention."""
    w = 4
    attn = nn.Attention.create(key, 32, 4, 2, window=w)
    x = jax.random.normal(key, (2, 12, 32))
    full = attn(x)
    cache = nn.KVCache.zeros(2, w, 2, 8, dtype=jnp.float32)  # ring: slots == w
    pre, cache = attn.prefill(x[:, :6], cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :6]),
                               atol=1e-5)
    for t in range(6, 12):
        y, cache = attn.decode(x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(y[:, 0]),
                                   np.asarray(full[:, t]), atol=1e-5,
                                   err_msg=f"t={t}")


def test_ring_prefill_shorter_than_window(key):
    w = 8
    attn = nn.Attention.create(key, 16, 2, 2, window=w)
    x = jax.random.normal(key, (1, 10, 16))
    full = attn(x)
    cache = nn.KVCache.zeros(1, w, 2, 8, dtype=jnp.float32)
    pre, cache = attn.prefill(x[:, :3], cache)  # 3 < window
    for t in range(3, 10):
        y, cache = attn.decode(x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(y[:, 0]),
                                   np.asarray(full[:, t]), atol=1e-5)


def test_cross_attention_paths_agree(key):
    attn = nn.Attention.create(key, 32, 4, 4, rope=False, causal=False)
    x = jax.random.normal(key, (2, 5, 32))
    ctx = jax.random.normal(jax.random.fold_in(key, 2), (2, 7, 32))
    direct = attn(x, context=ctx)
    k, v = attn.project_kv(ctx)
    via_kv = attn.attend_kv(x, k, v)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(via_kv),
                               atol=1e-5)


# ---- SSM -----------------------------------------------------------------------


def test_ssd_chunked_equals_recurrent(key):
    ssm = nn.Mamba2Mixer.create(key, 32, head_dim=16, d_state=8, chunk=4)
    x = 0.1 * jax.random.normal(key, (2, 16, 32))
    y_full = ssm(x)
    st = ssm.init_state(2)
    ys = []
    for t in range(16):
        yt, st = ssm.decode(x[:, t:t + 1], st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)


def test_ssd_chunk_size_invariance(key):
    ssm4 = nn.Mamba2Mixer.create(key, 32, head_dim=16, d_state=8, chunk=4)
    ssm8 = ssm4.replace(chunk=8)
    x = 0.1 * jax.random.normal(key, (1, 16, 32))
    np.testing.assert_allclose(np.asarray(ssm4(x)), np.asarray(ssm8(x)),
                               atol=1e-4)


def test_ssd_state_matches_sequential(key):
    ssm = nn.Mamba2Mixer.create(key, 16, head_dim=8, d_state=4, chunk=4)
    x = 0.1 * jax.random.normal(key, (1, 8, 16))
    _, final = ssm.forward_with_state(x)
    st = ssm.init_state(1)
    for t in range(8):
        _, st = ssm.decode(x[:, t:t + 1], st)
    np.testing.assert_allclose(np.asarray(final), np.asarray(st.ssm),
                               atol=1e-5)


# ---- MoE ------------------------------------------------------------------------


def test_moe_no_drop_equals_dense_mixture(key):
    """With huge capacity, MoE output == prob-weighted expert outputs."""
    moe = nn.MoE.create(key, 16, 32, n_experts=4, top_k=2,
                        capacity_factor=16.0)
    x = jax.random.normal(key, (2, 6, 16))
    out = moe(x)

    logits = moe.router(x)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    # dense reference: run every expert on every token
    g = jnp.einsum("bsd,edf->besf", x, moe.experts.gate_proj.weight)
    u = jnp.einsum("bsd,edf->besf", x, moe.experts.up_proj.weight)
    y_all = jnp.einsum("besf,efd->besd", jax.nn.silu(g) * u,
                       moe.experts.down_proj.weight)
    ref = jnp.zeros_like(x)
    for slot in range(2):
        w = top_p[..., slot][..., None]
        e = top_e[..., slot]
        # gather the chosen expert's output per (b, s)
        ref = ref + w * jnp.take_along_axis(
            y_all.transpose(0, 2, 1, 3), e[..., None, None], axis=2)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(ref), atol=1e-4)


def test_moe_capacity_drops_tokens(key):
    moe = nn.MoE.create(key, 8, 16, n_experts=2, top_k=1,
                        capacity_factor=0.25)
    x = jax.random.normal(key, (1, 16, 8))
    out = moe(x)  # with cap ~2, most tokens dropped → many zero rows
    norms = jnp.linalg.norm(out.y[0], axis=-1)
    assert int((norms < 1e-6).sum()) > 0


def test_moe_aux_loss_balanced_is_one(key):
    """Perfectly uniform routing gives aux ≈ 1 (Switch normalization)."""
    moe = nn.MoE.create(key, 8, 16, n_experts=4, top_k=4)
    x = jax.random.normal(key, (4, 32, 8))
    out = moe(x)
    assert 0.9 < float(out.aux_loss) < 1.3


def test_moe_shared_expert_always_applies(key):
    moe = nn.MoE.create(key, 8, 16, n_experts=2, top_k=1, n_shared=1,
                        capacity_factor=0.01)  # routed path ~all dropped
    x = jax.random.normal(key, (1, 8, 8))
    out = moe(x)
    shared_only = moe.shared(x)
    # with cap≈1 most outputs are just the shared expert
    diff = jnp.abs(out.y - shared_only).max(axis=-1)
    assert float(jnp.median(diff)) < 1.0


# ---- chunked (flash-style) attention ------------------------------------------


def test_chunked_attention_matches_dense(key):
    for causal, window in [(True, 0), (True, 5), (False, 0)]:
        dense = nn.Attention.create(key, 32, 4, 2, causal=causal,
                                    window=window)
        chunked = dense.replace(chunk=4)
        x = jax.random.normal(key, (2, 19, 32))  # non-divisible length
        np.testing.assert_allclose(np.asarray(dense(x)),
                                   np.asarray(chunked(x)), atol=1e-5,
                                   err_msg=f"causal={causal} window={window}")


def test_chunked_prefill_matches_dense(key):
    dense = nn.Attention.create(key, 32, 4, 2)
    chunked = dense.replace(chunk=4)
    cache = nn.KVCache.zeros(2, 24, 2, 8, dtype=jnp.float32)
    x = jax.random.normal(key, (2, 16, 32))
    pd, cd = dense.prefill(x, cache)
    pc, cc = chunked.prefill(x, cache)
    np.testing.assert_allclose(np.asarray(pd), np.asarray(pc), atol=1e-5)
    assert bool(jnp.array_equal(cd.k, cc.k))


def test_chunked_attention_differentiable(key):
    attn = nn.Attention.create(key, 16, 2, 2).replace(chunk=4)
    x = jax.random.normal(key, (1, 10, 16))
    g = jax.grad(lambda m: float(0) + jnp.sum(m(x) ** 2).astype(jnp.float32))(attn)
    assert bool(jnp.isfinite(g.q_proj.weight).all())
