"""Chunked, prefix-aware, bucketed prefill: differential harness + edges.

The load-bearing guarantee: feeding a prompt in bucket-padded chunks
interleaved with decode steps — and *starting* prefill after a cached
prefix instead of recomputing it — must be greedy-token BIT-IDENTICAL to
the one-shot ``generate`` baseline and to the monolithic-equivalent
engine (one full-width chunk, unbounded budget), across both KV layouts
and both paged decode kernels.  On top sit the admission edge cases:
chunk boundary == prefix-hit boundary, prompts shorter than one chunk,
whole-prompt prefix hits (only the final token recomputes), pool
exhaustion mid-prefill (reservation defers FIFO, failed admits roll back
cleanly), LRU retention racing eviction, and same-step prefix hits that
must wait for their provider's chunks to land.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (ContinuousEngine, PagedCacheManager, generate,
                         make_trace, replay)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


def _baseline(model, cfg, prompt, n, max_len=32):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return np.asarray(out)[0]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


# ---- differential: chunked == monolithic == generate -------------------------


@pytest.mark.parametrize("kv_layout,decode_kernel", [
    ("dense", "reference"),
    ("paged", "reference"),
    ("paged", "pallas"),
])
def test_chunked_matches_monolithic_and_generate(setup, kv_layout,
                                                 decode_kernel):
    """Acceptance criterion: a seeded shared-prefix trace replayed through
    the chunked+bucketed+prefix-skip path produces the same greedy tokens
    as the monolithic-equivalent prefill (one max-width chunk, unbounded
    budget) and as the per-request one-shot baseline — for both kv_layouts
    and both paged decode kernels."""
    model, cfg = setup
    trace = make_trace(10, seed=13, load=0.7, min_prompt=2, max_prompt=10,
                       min_new=2, max_new=8, vocab=cfg.vocab,
                       shared_prefix=6)
    dims = dict(batch=3, max_len=32, max_prompt_len=16, kv_layout=kv_layout)
    if kv_layout == "paged":
        dims.update(block_size=4, decode_kernel=decode_kernel)
    chunked = ContinuousEngine(model, cfg, chunk_size=4, buckets=(4, 8),
                               prefill_chunk_budget=4, **dims)
    mono = ContinuousEngine(model, cfg, chunk_size=16, buckets=(16,),
                            prefill_chunk_budget=10**9, **dims)
    cc, _ = replay(chunked, trace)
    mc, _ = replay(mono, trace)
    assert len(cc) == len(mc) == len(trace)
    for (_, req), a, b in zip(trace, cc, mc):
        ref = _baseline(model, cfg, req.prompt, req.max_new_tokens)
        np.testing.assert_array_equal(
            np.array(a.tokens), ref,
            err_msg=f"chunked diverged ({kv_layout}/{decode_kernel}) "
                    f"plen={req.prompt.size}")
        assert a.tokens == b.tokens  # chunked == monolithic-equivalent
        assert a.finish_reason == b.finish_reason
    # the chunked engine really did split prompts (not one chunk each);
    # the monolithic-equivalent ran exactly one chunk per request
    assert chunked.prefill_stats()["prefill_chunks"] > len(trace)
    assert mono.prefill_stats()["prefill_chunks"] == len(trace)


def test_prefix_skip_computes_fewer_tokens(setup):
    """With prefix_reuse the engine must compute exactly the non-cached
    suffix of each prompt — identical tokens, fewer prefill tokens, and
    the reduction must equal the tokens reported as skipped."""
    model, cfg = setup
    trace = make_trace(8, seed=5, load=0.5, min_prompt=2, max_prompt=6,
                       min_new=2, max_new=6, vocab=cfg.vocab,
                       shared_prefix=8)
    dims = dict(batch=3, max_len=32, max_prompt_len=16, kv_layout="paged",
                block_size=4, chunk_size=4, buckets=(4, 8),
                prefill_chunk_budget=8)
    on = ContinuousEngine(model, cfg, prefix_reuse=True, **dims)
    off = ContinuousEngine(model, cfg, prefix_reuse=False, **dims)
    con, _ = replay(on, trace)
    coff, _ = replay(off, trace)
    for a, b in zip(con, coff):
        assert a.tokens == b.tokens
    son, soff = on.prefill_stats(), off.prefill_stats()
    assert soff["prefix_skipped_tokens"] == 0
    assert son["prefix_skipped_tokens"] > 0
    assert son["prefill_tokens_computed"] < soff["prefill_tokens_computed"]
    assert (soff["prefill_tokens_computed"] - son["prefill_tokens_computed"]
            == son["prefix_skipped_tokens"])
    assert son["prefix_hit_rate"] > 0


# ---- admission edge cases ----------------------------------------------------


def test_chunk_boundary_equals_prefix_boundary(setup):
    """Prefix-hit boundary falling exactly on a chunk AND block boundary:
    the follow-up request's first chunk starts at the boundary with no
    overlap or gap."""
    model, cfg = setup
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab, 8).astype(np.int32)  # 2 blocks,
    tail = rng.integers(0, cfg.vocab, 4).astype(np.int32)    # 2 chunks of 4
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4, chunk_size=4, buckets=(4,))
    eng.submit(prefix, max_new_tokens=4)
    eng.run()
    eng.submit(np.concatenate([prefix, tail]), max_new_tokens=4)
    (comp,) = eng.run()
    stats = eng.prefill_stats()
    assert stats["prefix_skipped_tokens"] == 8  # whole prefix, nothing else
    np.testing.assert_array_equal(
        np.array(comp.tokens),
        _baseline(model, cfg, np.concatenate([prefix, tail]), 4))


def test_prompt_shorter_than_one_chunk(setup):
    """A 1-token prompt (shorter than every bucket) still prefills and
    matches its baseline."""
    model, cfg = setup
    p = _prompts([1], cfg.vocab, seed=2)[0]
    eng = ContinuousEngine(model, cfg, batch=1, max_len=32, max_prompt_len=8,
                           chunk_size=4, buckets=(4, 8))
    eng.submit(p, max_new_tokens=5)
    (comp,) = eng.run()
    np.testing.assert_array_equal(np.array(comp.tokens),
                                  _baseline(model, cfg, p, 5))


def test_full_prompt_prefix_hit_recomputes_only_last_token(setup):
    """When the WHOLE prompt is resident (its length a block multiple),
    only the final token may be recomputed — something must produce the
    first-sample logits — and its K/V must not rewrite the shared block."""
    model, cfg = setup
    prompt = _prompts([8], cfg.vocab, seed=7)[0]  # exactly 2 blocks of 4
    ref = _baseline(model, cfg, prompt, 6)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4, chunk_size=4, buckets=(4,))
    eng.submit(prompt, max_new_tokens=6)
    (first,) = eng.run()
    eng.reset_stats()
    eng.submit(prompt, max_new_tokens=6)
    (second,) = eng.run()
    np.testing.assert_array_equal(np.array(first.tokens), ref)
    np.testing.assert_array_equal(np.array(second.tokens), ref)
    stats = eng.prefill_stats()
    assert stats["prefix_skipped_tokens"] == 7   # capped at plen - 1
    assert stats["prefill_tokens_computed"] == 1
    assert eng.manager.prefix_hit_tokens == 8    # both blocks shared


def test_failed_admit_rolls_back_cleanly():
    """An admit() the pool cannot satisfy must raise BEFORE mutating any
    state: allocator counts, tables, prefix entries, and retention all
    unchanged."""
    mgr = PagedCacheManager(n_blocks=4, block_size=4, batch=2, max_len=32,
                            retain_blocks=4)
    rng = np.random.default_rng(3)
    big = rng.integers(0, 256, 8).astype(np.int32)
    mgr.admit(0, big, 16)  # 4 blocks: pool exhausted
    snap = (mgr.allocator.n_free, mgr.allocator.n_in_use,
            mgr.allocator.refcount.copy(), mgr.tables.copy(),
            len(mgr.prefix), dict(mgr.retained))
    other = rng.integers(0, 256, 6).astype(np.int32)
    assert not mgr.can_admit(other, 8)
    with pytest.raises(RuntimeError):
        mgr.admit(1, other, 8)
    assert mgr.allocator.n_free == snap[0]
    assert mgr.allocator.n_in_use == snap[1]
    np.testing.assert_array_equal(mgr.allocator.refcount, snap[2])
    np.testing.assert_array_equal(mgr.tables, snap[3])
    assert len(mgr.prefix) == snap[4]
    assert dict(mgr.retained) == snap[5]


def test_out_of_blocks_mid_prefill_defers_fifo(setup):
    """Pool exhaustion while a long prompt is mid-chunked-prefill: the
    reservation holds (decode can never strand it), later requests defer
    FIFO across the multi-step prefill, and every token stays bit-exact."""
    model, cfg = setup
    rng = np.random.default_rng(9)
    long_p = rng.integers(0, cfg.vocab, 6).astype(np.int32)   # 3 blocks
    late_p = rng.integers(0, cfg.vocab, 4).astype(np.int32)   # 2 blocks
    eng = ContinuousEngine(model, cfg, batch=2, max_len=16, max_prompt_len=8,
                           kv_layout="paged", block_size=4, n_blocks=4,
                           chunk_size=2, buckets=(2,),
                           prefill_chunk_budget=2, prefix_reuse=False)
    ua = eng.submit(long_p, max_new_tokens=6)   # total 12 -> 3 blocks
    ub = eng.submit(late_p, max_new_tokens=4)   # total 8 -> 2 > 1 free
    eng.step()
    # long prompt: one 2-token chunk in; still prefilling, late one queued
    assert eng.scheduler.n_prefilling == 1
    assert eng.scheduler.n_pending == 1
    assert eng.manager.allocator.n_free == 1
    comps = eng.run()
    assert [c.uid for c in comps] == sorted([ua, ub])
    assert list(eng.scheduler.admitted) == [ua, ub]  # FIFO preserved
    by_len = {c.prompt_len: c for c in comps}
    np.testing.assert_array_equal(
        np.array(by_len[6].tokens),
        _baseline(model, cfg, long_p, 6, max_len=16))
    np.testing.assert_array_equal(
        np.array(by_len[4].tokens),
        _baseline(model, cfg, late_p, 4, max_len=16))
    assert eng.manager.fully_free


def test_lru_eviction_races_new_prefix_hit(setup):
    """A retention budget of one prefix: parking B's prefix evicts A's; a
    new request with prefix A must cleanly miss (recompute, correct
    tokens) while a new request with prefix B still hits the warm parked
    blocks."""
    model, cfg = setup
    pa, pb = _prompts([8, 8], cfg.vocab, seed=4)
    ref_a = _baseline(model, cfg, pa, 4)
    ref_b = _baseline(model, cfg, pb, 4)
    eng = ContinuousEngine(model, cfg, batch=1, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4, chunk_size=4, buckets=(4,),
                           prefix_retain_blocks=2)  # ONE 8-token prefix
    eng.submit(pa, max_new_tokens=4)
    eng.run()
    assert len(eng.manager.retained) == 2  # A's prefix parked warm
    eng.submit(pb, max_new_tokens=4)
    eng.run()
    assert len(eng.manager.retained) == 2  # B parked, A evicted (LRU)
    eng.reset_stats()
    eng.submit(pa, max_new_tokens=4)       # must MISS: A was evicted
    (ca,) = eng.run()
    assert eng.prefill_stats()["prefix_skipped_tokens"] == 0
    np.testing.assert_array_equal(np.array(ca.tokens), ref_a)
    eng.reset_stats()
    eng.submit(pb, max_new_tokens=4)       # must HIT: B is still parked...
    (cb,) = eng.run()
    # ...unless A's re-run just evicted it — assert on whichever the LRU
    # actually did, then on correctness either way
    assert eng.prefill_stats()["prefix_skipped_tokens"] in (0, 7)
    np.testing.assert_array_equal(np.array(cb.tokens), ref_b)


def test_short_prompt_binds_before_long_neighbour_finishes(setup):
    """The headline fairness property: with a one-chunk-per-step budget, a
    short prompt admitted behind a long one must emit its first token
    (bind) BEFORE the long prompt's multi-step prefill completes — the
    rotating round-robin; monolithic admission served them strictly in
    order."""
    model, cfg = setup
    rng = np.random.default_rng(15)
    long_p = rng.integers(0, cfg.vocab, 12).astype(np.int32)  # 3 chunks
    short_p = rng.integers(0, cfg.vocab, 4).astype(np.int32)  # 1 chunk
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4, chunk_size=4, buckets=(4,),
                           prefill_chunk_budget=4)
    ul = eng.submit(long_p, max_new_tokens=5)
    us = eng.submit(short_p, max_new_tokens=5)
    eng.step()  # both admitted; long got the first chunk
    assert eng.scheduler.n_prefilling == 2
    eng.step()  # rotation: the SHORT prompt's chunk runs and binds
    assert list(eng.scheduler.admitted) == [us]
    assert eng.scheduler.n_prefilling == 1  # long still mid-prefill
    comps = eng.run()
    assert sorted(c.uid for c in comps) == [ul, us]
    by_len = {c.prompt_len: c for c in comps}
    np.testing.assert_array_equal(np.array(by_len[12].tokens),
                                  _baseline(model, cfg, long_p, 5))
    np.testing.assert_array_equal(np.array(by_len[4].tokens),
                                  _baseline(model, cfg, short_p, 5))


def test_same_step_prefix_hit_waits_for_provider(setup):
    """Two same-prefix requests admitted together, with the prefix wider
    than one chunk: the second request's prefill must stall until the
    provider's chunks have actually written the shared blocks, then both
    match their baselines (a hit block read before publish would decode
    from zeros)."""
    model, cfg = setup
    rng = np.random.default_rng(6)
    prefix = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    ta, tb = (rng.integers(0, cfg.vocab, 4).astype(np.int32)
              for _ in range(2))
    pa, pb = np.concatenate([prefix, ta]), np.concatenate([prefix, tb])
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4, chunk_size=4, buckets=(4,),
                           prefill_chunk_budget=4)  # one chunk per step
    eng.submit(pa, max_new_tokens=5)
    eng.submit(pb, max_new_tokens=5)
    eng.step()
    # both admitted up front; B hit A's registered-but-unwritten blocks
    assert eng.scheduler.n_prefilling == 2
    assert eng.manager.prefix_hit_tokens == 8
    comps = eng.run()
    a, b = sorted(comps, key=lambda c: c.uid)
    np.testing.assert_array_equal(np.array(a.tokens),
                                  _baseline(model, cfg, pa, 5))
    np.testing.assert_array_equal(np.array(b.tokens),
                                  _baseline(model, cfg, pb, 5))
    assert eng.manager.fully_free
