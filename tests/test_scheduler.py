"""Continuous batching: scheduler lifecycle + engine equivalence.

The load-bearing guarantee is the last test: the continuous engine, with
requests admitted mid-flight into recycled slots and prompts right-padded
to a fixed prefill width, must produce BIT-IDENTICAL greedy tokens to the
one-shot ``generate`` baseline run per request at exact length.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousEngine, Request, Scheduler, generate


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


def _baseline(model, cfg, prompt, n, max_len=32):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return np.asarray(out)[0]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


# ---- Scheduler bookkeeping (no jax) -----------------------------------------


def test_admission_is_fifo():
    sched = Scheduler(2)
    reqs = [Request(prompt=np.array([1]), max_new_tokens=1) for _ in range(3)]
    for r in reqs:
        sched.submit(r)
    s0, r0 = sched.next_admission()
    sched.bind(s0, r0, first_token=7)
    s1, r1 = sched.next_admission()
    sched.bind(s1, r1, first_token=7)
    assert (s0, s1) == (0, 1)
    assert (r0.uid, r1.uid) == (reqs[0].uid, reqs[1].uid)
    assert sched.next_admission() is None  # batch full, third stays queued
    assert sched.n_pending == 1

    done = sched.finish(0, "length")
    assert done.uid == reqs[0].uid and done.tokens == [7]
    s2, r2 = sched.next_admission()  # freed slot goes to the queued request
    assert s2 == 0 and r2.uid == reqs[2].uid


def test_scheduler_slot_accounting():
    sched = Scheduler(2)
    assert sched.idle and sched.free_slot() == 0
    sched.submit(Request(prompt=np.array([1]), max_new_tokens=2))
    assert not sched.idle
    slot, req = sched.next_admission()
    sched.bind(slot, req, first_token=3)
    assert sched.running_slots() == [0] and sched.free_slot() == 1
    sched.append_token(0, 5)
    comp = sched.finish(0, "length")
    assert comp.tokens == [3, 5] and sched.idle


def test_prefill_lifecycle_occupies_slot_without_decoding():
    """A slot in the PREFILLING state is occupied (not offered to new
    admissions) but absent from the decode batch until bind."""
    sched = Scheduler(2)
    reqs = [Request(prompt=np.array([1]), max_new_tokens=2)
            for _ in range(3)]
    for r in reqs:
        sched.submit(r)
    s0, r0 = sched.next_admission()
    sched.begin_prefill(s0, r0)
    assert sched.n_prefilling == 1 and not sched.idle
    assert sched.running_slots() == []         # nothing decodes yet
    assert sched.free_slot() == 1              # slot 0 is taken
    s1, r1 = sched.next_admission()
    assert s1 == 1
    sched.begin_prefill(s1, r1)
    assert sched.next_admission() is None      # batch full mid-prefill
    sched.bind(s0, r0, first_token=9)
    assert sched.n_prefilling == 1 and sched.running_slots() == [0]
    comp = sched.finish(s0, "length")
    assert comp.tokens == [9]
    s2, r2 = sched.next_admission()            # recycled slot, FIFO order
    assert s2 == 0 and r2.uid == reqs[2].uid


def test_request_validation():
    with pytest.raises(ValueError):
        Request(prompt=np.array([], np.int32), max_new_tokens=1)
    with pytest.raises(ValueError):
        Request(prompt=np.array([1]), max_new_tokens=0)


# ---- engine lifecycle -------------------------------------------------------


def test_slot_eviction_on_stop_token(setup):
    model, cfg = setup
    prompt = _prompts([6], cfg.vocab, seed=3)[0]
    ref = _baseline(model, cfg, prompt, 8)
    # stop on the first token the model will actually emit after step 0
    stop = int(ref[1]) if ref[1] != ref[0] else int(ref[0])
    first_hit = int(np.argmax(ref == stop))
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=12)
    eng.submit(prompt, max_new_tokens=8, stop_ids=(stop,))
    (comp,) = eng.run()
    assert comp.finish_reason == "stop"
    assert comp.tokens == ref[:first_hit + 1].tolist()  # stop id included
    assert eng.scheduler.idle  # slot freed


def test_slot_reuse_by_queued_request(setup):
    """More requests than slots: every queued request must be served through
    a recycled slot and still match its one-shot baseline exactly."""
    model, cfg = setup
    prompts = _prompts([5, 9, 12, 7, 4], cfg.vocab, seed=1)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=12)
    uids = [eng.submit(p, max_new_tokens=6) for p in prompts]
    comps = eng.run()
    assert [c.uid for c in comps] == sorted(uids)
    for p, c in zip(prompts, comps):
        assert c.finish_reason == "length"
        np.testing.assert_array_equal(np.array(c.tokens),
                                      _baseline(model, cfg, p, 6))


def test_mid_flight_admission(setup):
    """A request submitted while another is mid-decode joins the running
    batch without perturbing it (and both match their baselines)."""
    model, cfg = setup
    long_p, late_p = _prompts([9, 6], cfg.vocab, seed=2)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=12)
    eng.submit(long_p, max_new_tokens=10)
    for _ in range(4):
        eng.step()
    eng.submit(late_p, max_new_tokens=6)  # joins mid-flight
    comps = eng.run()
    by_len = {c.prompt_len: c for c in comps}
    np.testing.assert_array_equal(np.array(by_len[9].tokens),
                                  _baseline(model, cfg, long_p, 10))
    np.testing.assert_array_equal(np.array(by_len[6].tokens),
                                  _baseline(model, cfg, late_p, 6))


def test_per_request_sampling_isolation(setup):
    """A temperature-sampled request must not perturb the greedy request
    decoding in the adjacent slot (per-slot params are batched arrays)."""
    model, cfg = setup
    greedy_p, samp_p = _prompts([6, 9], cfg.vocab, seed=4)
    ref = _baseline(model, cfg, greedy_p, 8)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=12,
                           seed=11)
    eng.submit(samp_p, max_new_tokens=5, temperature=1.0)
    eng.submit(greedy_p, max_new_tokens=8)
    comps = eng.run()
    by_len = {c.prompt_len: c for c in comps}
    np.testing.assert_array_equal(np.array(by_len[6].tokens), ref)
    assert len(by_len[9].tokens) == 5
    assert max(by_len[9].tokens) < cfg.vocab


def test_max_new_tokens_one(setup):
    """A 1-token request finishes at admission (prefill-only)."""
    model, cfg = setup
    p = _prompts([5], cfg.vocab, seed=5)[0]
    eng = ContinuousEngine(model, cfg, batch=1, max_len=32, max_prompt_len=8)
    eng.submit(p, max_new_tokens=1)
    (comp,) = eng.run()
    assert comp.finish_reason == "length"
    assert comp.tokens == [int(_baseline(model, cfg, p, 1)[0])]


def test_continuous_matches_generate_mixed_lengths(setup):
    """Acceptance criterion: bit-identical greedy tokens vs the one-shot
    baseline for a mixed-length request set pushed through 2 slots."""
    model, cfg = setup
    lengths = [5, 12, 8, 3, 10, 6]
    prompts = _prompts(lengths, cfg.vocab, seed=6)
    budgets = [6, 4, 8, 5, 3, 7]
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=12)
    for p, n in zip(prompts, budgets):
        eng.submit(p, max_new_tokens=n)
    comps = eng.run()
    assert len(comps) == len(prompts)
    for p, n, c in zip(prompts, budgets, comps):
        np.testing.assert_array_equal(
            np.array(c.tokens), _baseline(model, cfg, p, n),
            err_msg=f"divergence for prompt_len={len(p)} budget={n}")


def test_continuous_with_factorized_model(setup):
    """auto_fact'ed models serve through the continuous engine, and the
    factorized continuous path matches the factorized one-shot baseline."""
    from repro.core import auto_fact

    model, cfg = setup
    fact = auto_fact(model, 0.5, solver="svd", exclude=["embed", "lm_head"])
    prompts = _prompts([7, 4, 11], cfg.vocab, seed=7)
    eng = ContinuousEngine(fact, cfg, batch=2, max_len=32, max_prompt_len=12)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    for p, c in zip(prompts, eng.run()):
        np.testing.assert_array_equal(np.array(c.tokens),
                                      _baseline(fact, cfg, p, 5))


def test_window_model_degrades_to_ring_lanes(setup):
    """Regression FLIP: sliding-window configs used to raise the
    structured UnsupportedCacheError here — they now serve through
    per-slot ring lanes, with the paged machinery (block reservation,
    prefix cache) degraded away."""
    model, cfg = setup
    eng = ContinuousEngine(model, cfg.replace(window=8), batch=2,
                           max_len=32, max_prompt_len=12)
    assert eng.cache_kind == "ring"
    assert eng.manager is None
    assert eng.kv_stats()["kv_lane_tokens"] == 8


def test_out_of_blocks_admission_defers_fifo(setup):
    """Deliberate worst-case trace for pool exhaustion: a 4-slot engine
    over a 4-block pool where the head request alone reserves 3 blocks.
    Admission must defer on free BLOCKS (not free slots) without crashing,
    keep strict FIFO order (later small requests never jump the blocked
    head), resume as finished requests free their blocks, and still
    produce bit-exact tokens."""
    model, cfg = setup
    rng = np.random.default_rng(17)
    # head request: 5+4 -> 9 tokens -> 3 blocks; three more at 2 blocks each
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 4, 4, 4)]
    budgets = [4, 3, 3, 3]
    eng = ContinuousEngine(model, cfg, batch=4, max_len=16, max_prompt_len=6,
                           kv_layout="paged", block_size=4, n_blocks=4)
    uids = [eng.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets)]
    eng.step()
    # head took 3 of 4 blocks; the next (2-block) request must wait even
    # though 3 slots are free
    assert eng.scheduler.n_running == 1
    assert eng.scheduler.n_pending == 3
    assert eng.manager.allocator.n_free == 1
    comps = eng.run()
    assert [c.uid for c in comps] == sorted(uids)
    assert list(eng.scheduler.admitted) == uids  # FIFO, no starvation
    for p, n, c in zip(prompts, budgets, comps):
        np.testing.assert_array_equal(
            np.array(c.tokens), _baseline(model, cfg, p, n, max_len=16))
    assert eng.manager.fully_free


def test_request_larger_than_pool_rejected_at_submit(setup):
    """A request whose worst-case reservation can NEVER fit the pool is
    rejected up front instead of deadlocking the FIFO head."""
    model, cfg = setup
    eng = ContinuousEngine(model, cfg, batch=2, max_len=16, max_prompt_len=6,
                           kv_layout="paged", block_size=4, n_blocks=2)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(6, np.int32), max_new_tokens=8)  # needs 4 > 2
    eng.submit(np.zeros(4, np.int32), max_new_tokens=4)  # 2 blocks: fits
    (comp,) = eng.run()
    assert len(comp.tokens) == 4


def test_prompt_longer_than_prefill_width_rejected(setup):
    model, cfg = setup
    eng = ContinuousEngine(model, cfg, batch=1, max_len=32, max_prompt_len=8)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(9, np.int32), max_new_tokens=2)


def test_batched_prefill_vector_lengths(setup):
    """(batch,) prefill lengths over a per-slot cache: logits at each row's
    own last position must equal the per-request scalar-length prefill
    (batch != n_layers to catch layout mixups)."""
    model, cfg = setup
    lengths = [3, 7, 5]
    prompts = _prompts(lengths, cfg.vocab, seed=8)
    padded = np.zeros((3, 8), np.int32)
    for i, p in enumerate(prompts):
        padded[i, :len(p)] = p
    cache = model.init_cache(3, 16, cfg, dtype=jnp.float32, per_slot=True)
    logits, new_cache = model.prefill(jnp.asarray(padded), cache,
                                      length=jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(new_cache.length),
                                  np.tile(lengths, (cfg.n_layers, 1)))
    for i, p in enumerate(prompts):
        lane = model.init_cache(1, 16, cfg, dtype=jnp.float32)
        ref, _ = model.prefill(jnp.asarray(p)[None, :], lane)
        np.testing.assert_array_equal(np.asarray(logits[i]),
                                      np.asarray(ref[0]))


def test_vector_length_requires_per_slot_cache(setup):
    model, cfg = setup
    cache = model.init_cache(3, 16, cfg, dtype=jnp.float32)  # scalar lengths
    with pytest.raises(ValueError):
        model.prefill(jnp.zeros((3, 8), jnp.int32), cache,
                      length=jnp.asarray([3, 7, 5]))


def test_submit_copies_request_and_reuids_duplicates():
    """``submit`` must not mutate the caller's Request (stamping
    ``submitted_at`` on it made a re-used object carry a stale
    timestamp), and resubmitting the same object must mint a fresh uid —
    a reused uid collided in every per-uid map downstream (stream event
    maps, HTTP response routing)."""
    sched = Scheduler(n_slots=2)
    req = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=3)
    orig_uid = req.uid
    uid1 = sched.submit(req)
    assert req.submitted_at == 0.0          # caller's object untouched
    assert uid1 == orig_uid                 # first submit keeps the uid
    assert sched.pending[-1] is not req     # queued object is a copy
    assert sched.pending[-1].submitted_at > 0.0

    uid2 = sched.submit(req)                # same object again
    assert uid2 != uid1                     # fresh uid, no collision
    assert req.uid == orig_uid              # still not mutated
    assert sched.n_pending == 2
    assert len({r.uid for r in sched.pending}) == 2
