"""Distributed semantics: the sharded train step must compute the SAME math
as single-device execution.  Runs in a subprocess with 8 forced host devices
(the XLA device count is locked at first jax init, so it cannot be set in
this process)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.optim.adamw import AdamWState
    from repro.train import TrainState, make_train_step
    from repro.dist.sharding import (activation_mesh, data_sharding,
                                     model_shardings)

    cfg = get_config("paper-tiny").reduced().replace(
        dtype="float32", n_heads=4, n_kv_heads=4, d_model=64, head_dim=16)
    model = build_model(jax.random.PRNGKey(0), cfg)
    opt = AdamW(1e-2, master_fp32=False)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    step = make_train_step(opt)

    def fresh_state():
        return TrainState(model=model, opt=opt.init(model),
                          step=jnp.zeros((), jnp.int32))

    # --- single device (reference) ---
    ref_state, ref_metrics = jax.jit(step)(fresh_state(), batch)

    # --- sharded: dp=4 x tp=2 mesh, TP+FSDP+activation constraints ---
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ms = model_shardings(model, mesh, fsdp=True)
    repl = NamedSharding(mesh, P())
    st_sh = TrainState(model=ms, opt=AdamWState(step=repl, m=ms, v=ms,
                                                master=None), step=repl)
    b_sh = {k: data_sharding(mesh, v.shape) for k, v in batch.items()}
    with mesh, activation_mesh(mesh):
        sharded = jax.jit(step, in_shardings=(st_sh, b_sh))(
            fresh_state(), batch)
    sh_state, sh_metrics = sharded

    np.testing.assert_allclose(float(ref_metrics["loss"]),
                               float(sh_metrics["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(ref_metrics["grad_norm"]),
                               float(sh_metrics["grad_norm"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(ref_state.model),
                    jax.tree_util.tree_leaves(sh_state.model)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    print("DISTRIBUTED_EQUIVALENCE_OK")
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "DISTRIBUTED_EQUIVALENCE_OK" in r.stdout
