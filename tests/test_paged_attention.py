"""Fused Pallas paged-attention decode kernel: parity + serving identity.

Three layers of guarantee, all running in interpret mode on CPU (the
``kernels-interpret`` CI job forces it explicitly so the same tests keep
kernel regressions visible without a TPU):

* kernel vs oracle — :func:`repro.kernels.paged_attention` must match the
  pure-jnp :func:`repro.kernels.ref.paged_attention_ref` AND the
  dense-gather attention it replaces (materialized pool gather + masked
  softmax, the exact math of ``Attention._decode_paged``'s reference
  branch) to fp32 tolerance.  Property-based via the ``tests/_hyp`` shim:
  random block tables, ragged per-slot positions, GQA/MQA head ratios,
  sentinel blocks past each slot's reservation.
* in-kernel masking — sentinel blocks and ``kpos > pos`` lanes contribute
  exactly zero; a fully-masked slot (all-sentinel table, the state of a
  released decode slot) emits zeros, not NaN.
* serving identity — greedy decode through ``ContinuousEngine`` with
  ``decode_kernel="pallas"`` is bit-identical to the dense-gather
  reference path on seeded shared-prefix traces, including the
  cache-full frozen-slot eviction path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.kernels import paged_attention, paged_attention_ref
from repro.models import build_model
from repro.serve import ContinuousEngine, make_trace, replay

NEG_INF = -1e30


# ---- case construction -------------------------------------------------------


def _make_case(seed, *, batch, heads, kvh, hd, bs, n_table, extra_blocks=2,
               dtype=jnp.float32):
    """A well-formed paged layout: each slot owns ``pos // bs + 1`` distinct
    pool blocks (the manager's reservation invariant), the rest of its
    table row is the sentinel.  Positions are ragged across slots."""
    rng = np.random.default_rng(seed)
    n_blocks = batch * n_table + extra_blocks
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (batch, heads, hd), dtype)
    k_pool = jax.random.normal(kk, (n_blocks, bs, kvh, hd), dtype)
    v_pool = jax.random.normal(kv, (n_blocks, bs, kvh, hd), dtype)
    pos = rng.integers(0, n_table * bs, batch).astype(np.int32)
    table = np.full((batch, n_table), n_blocks, np.int32)
    perm = rng.permutation(n_blocks)
    off = 0
    for b in range(batch):
        need = pos[b] // bs + 1
        table[b, :need] = perm[off:off + need]
        off += need
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(pos)


def _dense_gather_attend(q, k_pool, v_pool, table, pos):
    """The attention the kernel replaces: materialize the dense per-slot
    gather from the pool (sentinel rows clip, like jnp out-of-bounds
    gathers), then masked-softmax single-query attention in fp32 — the
    same math as ``Attention._decode_paged``'s reference branch."""
    q, k_pool, v_pool = (np.asarray(a, np.float32)
                         for a in (q, k_pool, v_pool))
    table, pos = np.asarray(table), np.asarray(pos)
    batch, heads, hd = q.shape
    nb, bs, kvh, _ = k_pool.shape
    group = heads // kvh
    kpos = np.arange(table.shape[1] * bs)
    rows = np.minimum(table[:, kpos // bs] * bs + kpos[None, :] % bs,
                      nb * bs - 1)
    gk = k_pool.reshape(nb * bs, kvh, hd)[rows]  # (batch, S, kvh, hd)
    gv = v_pool.reshape(nb * bs, kvh, hd)[rows]
    valid = kpos[None, :] <= pos[:, None]
    qg = q.reshape(batch, kvh, group, hd)
    logits = np.einsum("bkgd,bskd->bkgs", qg, gk) / np.sqrt(hd)
    logits = np.where(valid[:, None, None, :], logits, NEG_INF)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.einsum("bkgs,bskd->bkgd", probs, gv)
    return out.reshape(batch, heads, hd)


def _assert_three_way(q, k_pool, v_pool, table, pos, tol=1e-5):
    y = paged_attention(q, k_pool, v_pool, table, pos)
    yr = paged_attention_ref(q, k_pool, v_pool, table, pos)
    yd = _dense_gather_attend(q, k_pool, v_pool, table, pos)
    assert y.shape == yr.shape == yd.shape
    assert y.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=tol, rtol=tol, err_msg="kernel vs ref")
    np.testing.assert_allclose(np.asarray(y, np.float32), yd,
                               atol=tol, rtol=tol,
                               err_msg="kernel vs dense gather")


# ---- kernel vs oracle vs dense gather ----------------------------------------


@pytest.mark.parametrize("heads,kvh", [(4, 4), (4, 2), (4, 1), (1, 1)])
def test_kernel_parity_head_ratios(heads, kvh):
    """MHA, GQA, and MQA all hit the same kernel; every ratio must match
    both oracles."""
    q, kp, vp, table, pos = _make_case(7, batch=3, heads=heads, kvh=kvh,
                                       hd=16, bs=4, n_table=5)
    _assert_three_way(q, kp, vp, table, pos)


def test_kernel_parity_block_size_one_and_single_slot():
    q, kp, vp, table, pos = _make_case(11, batch=1, heads=2, kvh=2, hd=8,
                                       bs=1, n_table=6)
    _assert_three_way(q, kp, vp, table, pos)


def test_kernel_parity_bf16_pool():
    """bf16 pools (the serving cache dtype at scale) accumulate in fp32."""
    q, kp, vp, table, pos = _make_case(3, batch=2, heads=4, kvh=2, hd=16,
                                       bs=4, n_table=4, dtype=jnp.bfloat16)
    _assert_three_way(q, kp, vp, table, pos, tol=2e-2)


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=15, deadline=None)
def test_kernel_parity_random_layouts(seed):
    """Property: random block tables, ragged positions, GQA ratios, block
    sizes, and sentinel tails — fused == reference == dense-gather to
    fp32 tolerance."""
    rng = np.random.default_rng(seed)
    heads, kvh = [(1, 1), (2, 1), (4, 2), (4, 4), (6, 3)][
        int(rng.integers(0, 5))]
    q, kp, vp, table, pos = _make_case(
        int(rng.integers(0, 2**31)),
        batch=int(rng.integers(1, 5)), heads=heads, kvh=kvh,
        hd=int(rng.choice([4, 8, 16])), bs=int(rng.integers(1, 9)),
        n_table=int(rng.integers(1, 7)),
        extra_blocks=int(rng.integers(0, 4)))
    _assert_three_way(q, kp, vp, table, pos)


# ---- in-kernel masking -------------------------------------------------------


def test_sentinel_block_inside_window_is_masked():
    """Defense in depth: a sentinel entry *below* ``pos`` (impossible for a
    live slot under the manager's reservation invariant, but exactly what
    a buggy host table would produce) is hard-masked by the kernel and the
    oracle alike, instead of attending whatever block the clamped fetch
    landed on."""
    q, kp, vp, table, pos = _make_case(19, batch=2, heads=4, kvh=2, hd=8,
                                       bs=4, n_table=4)
    n_blocks = kp.shape[0]
    table = table.at[0, 1].set(n_blocks)  # hole inside slot 0's window
    pos = pos.at[0].set(14)               # covers table entries 0..3
    y = paged_attention(q, kp, vp, table, pos)
    yr = paged_attention_ref(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)
    # and the hole genuinely changed the result vs the unholed table
    y_full = paged_attention(q, kp, vp, table.at[0, 1].set(1), pos)
    assert not np.allclose(np.asarray(y)[0], np.asarray(y_full)[0])


def test_fully_masked_slot_emits_zeros_not_nan():
    """A released decode slot (all-sentinel table) must emit zeros via the
    guarded division — the dense path's softmax would give uniform weights
    over garbage; both engines ignore the row, but the kernel must not
    poison anything with NaN."""
    q, kp, vp, table, pos = _make_case(23, batch=2, heads=4, kvh=2, hd=8,
                                       bs=4, n_table=3)
    n_blocks = kp.shape[0]
    table = table.at[1].set(n_blocks)
    y = paged_attention(q, kp, vp, table, pos)
    yr = paged_attention_ref(q, kp, vp, table, pos)
    assert np.isfinite(np.asarray(y)).all()
    assert (np.asarray(y)[1] == 0.0).all()
    np.testing.assert_array_equal(np.asarray(y)[1], np.asarray(yr)[1])
    # slot 0 is untouched by slot 1's masking
    np.testing.assert_allclose(
        np.asarray(y)[0],
        _dense_gather_attend(q, kp, vp, table, pos)[0], atol=1e-5, rtol=1e-5)


def test_mask_fill_constant_matches_attention_layer():
    """nn keeps its own NEG_INF literal (it must not eagerly import the
    pallas stack); this pins it to the kernels/oracle value so the paged
    bit-identity contract cannot drift apart silently."""
    from repro.kernels.ref import NEG_INF as kernel_fill
    from repro.nn.attention import NEG_INF as attn_fill

    assert kernel_fill == attn_fill == NEG_INF


def test_kernel_validates_shapes():
    q, kp, vp, table, pos = _make_case(1, batch=2, heads=4, kvh=2, hd=8,
                                       bs=4, n_table=3)
    with pytest.raises(ValueError, match="kv_heads"):
        paged_attention(q[:, :3], kp, vp, table, pos)  # 3 % 2 != 0
    with pytest.raises(ValueError, match="mismatch"):
        paged_attention(q, kp, vp[:, :, :, :4], table, pos)
    with pytest.raises(ValueError, match="batch"):
        paged_attention(q, kp, vp, table, pos[:1])


def test_interpret_mode_env_override(monkeypatch):
    """REPRO_PALLAS_INTERPRET forces interpret mode (the kernels-interpret
    CI job's contract); unset, the CPU backend already selects it."""
    from repro.kernels import default_interpret

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() == (jax.default_backend() != "tpu")


# ---- serving identity through ContinuousEngine -------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()  # GQA: 4 heads over 2 KV heads
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


def test_engine_pallas_bit_identical_on_shared_prefix_trace(setup):
    """Acceptance gate: greedy decode through ContinuousEngine with
    decode_kernel='pallas' (interpret mode on CPU) is bit-identical to the
    dense-gather reference path on a seeded shared-prefix trace —
    staggered arrivals, slot recycling, prefix-cache hits and all."""
    model, cfg = setup
    trace = make_trace(10, seed=13, load=0.7, min_prompt=2, max_prompt=10,
                       min_new=2, max_new=8, vocab=cfg.vocab,
                       shared_prefix=6)
    outs = {}
    for dk in ("reference", "pallas"):
        eng = ContinuousEngine(model, cfg, batch=3, max_len=32,
                               max_prompt_len=16, kv_layout="paged",
                               block_size=4, decode_kernel=dk)
        outs[dk], _ = replay(eng, trace)
        assert eng.kv_stats()["decode_kernel"] == dk
        assert eng.manager.fully_free
    assert len(outs["pallas"]) == len(trace)
    for cr, cp in zip(outs["reference"], outs["pallas"]):
        assert cr.tokens == cp.tokens, \
            f"pallas decode diverged for uid={cr.uid} plen={cr.prompt_len}"
        assert (cr.uid, cr.prompt_len, cr.finish_reason) == \
            (cp.uid, cp.prompt_len, cp.finish_reason)


def test_engine_pallas_cache_full_frozen_slot(setup):
    """The eviction-frozen-slot path from PR 2 under the fused kernel: a
    slot frozen at pos == max_len keeps writing nowhere and its (ignored)
    attention output never corrupts a live neighbor."""
    model, cfg = setup
    rng = np.random.default_rng(7)
    long_lived = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    cache_filler = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    outs = {}
    for dk in ("reference", "pallas"):
        eng = ContinuousEngine(model, cfg, batch=2, max_len=16,
                               max_prompt_len=8, kv_layout="paged",
                               block_size=4, decode_kernel=dk)
        eng.submit(long_lived, max_new_tokens=12)
        eng.submit(cache_filler, max_new_tokens=16)  # frozen at pos 16
        outs[dk] = {c.prompt_len: c for c in eng.run()}
    assert outs["pallas"][6].finish_reason == "cache_full"
    for plen in (4, 6):
        assert outs["pallas"][plen].tokens == outs["reference"][plen].tokens, \
            f"frozen cache-full slot corrupted prompt_len={plen}"


def test_engine_decode_kernel_validation(setup):
    model, cfg = setup
    with pytest.raises(ValueError, match="decode_kernel"):
        ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8,
                         decode_kernel="cuda")
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8,
                         kv_layout="dense", decode_kernel="pallas")
