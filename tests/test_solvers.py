"""Factorization solvers: exactness, optimality, constraints (w/ hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import get_solver, random_solver, snmf_solver, svd_solver


@given(m=st.integers(4, 48), n=st.integers(4, 48))
def test_svd_full_rank_exact(m, n):
    w = jax.random.normal(jax.random.PRNGKey(m * 100 + n), (m, n))
    a, b = svd_solver(w, min(m, n))
    np.testing.assert_allclose(np.asarray(a @ b), np.asarray(w), atol=1e-4)


@given(m=st.integers(8, 40), n=st.integers(8, 40),
       r=st.integers(1, 7))
def test_svd_truncation_is_optimal(m, n, r):
    """Eckart–Young: rank-r SVD error equals the tail singular values."""
    w = jax.random.normal(jax.random.PRNGKey(m + 7 * n + 13 * r), (m, n))
    a, b = svd_solver(w, r)
    err = float(jnp.linalg.norm(w - a @ b))
    s = jnp.linalg.svd(w, compute_uv=False)
    opt = float(jnp.sqrt(jnp.sum(s[r:] ** 2)))
    assert err <= opt * 1.001 + 1e-4


def test_svd_factor_shapes_and_dtype():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16), jnp.bfloat16)
    a, b = svd_solver(w, 4)
    assert a.shape == (32, 4) and b.shape == (4, 16)
    assert a.dtype == jnp.bfloat16 and b.dtype == jnp.bfloat16


def test_svd_batched_equals_loop():
    ws = jax.random.normal(jax.random.PRNGKey(1), (5, 12, 10))
    a, b = svd_solver(ws, 3)
    assert a.shape == (5, 12, 3) and b.shape == (5, 3, 10)
    for i in range(5):
        ai, bi = svd_solver(ws[i], 3)
        np.testing.assert_allclose(np.asarray(a[i] @ b[i]),
                                   np.asarray(ai @ bi), atol=1e-4)


def test_snmf_nonnegativity_and_approximation():
    w = jax.random.normal(jax.random.PRNGKey(2), (40, 30))
    a, b = snmf_solver(w, 20, num_iter=60)
    assert float(b.min()) >= 0.0
    rel = float(jnp.linalg.norm(w - a @ b) / jnp.linalg.norm(w))
    assert rel < 0.6  # semi-NMF at rank 20/30 should capture most energy


def test_snmf_more_iters_not_worse():
    w = jax.random.normal(jax.random.PRNGKey(3), (24, 24))
    errs = []
    for it in (1, 10, 50):
        a, b = snmf_solver(w, 12, num_iter=it)
        errs.append(float(jnp.linalg.norm(w - a @ b)))
    assert errs[2] <= errs[0] + 1e-3


def test_snmf_rank_monotone():
    w = jax.random.normal(jax.random.PRNGKey(4), (30, 20))
    e = []
    for r in (2, 8, 16):
        a, b = snmf_solver(w, r, num_iter=40)
        e.append(float(jnp.linalg.norm(w - a @ b)))
    assert e[0] > e[1] > e[2]


def test_random_solver_shapes_and_scale():
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 64))
    a, b = random_solver(w, 16, key=jax.random.PRNGKey(6))
    assert a.shape == (64, 16) and b.shape == (16, 64)
    # variance-preserving init: output std of x@A@B near std of x@W_fresh
    x = jax.random.normal(jax.random.PRNGKey(7), (128, 64))
    y = x @ a @ b
    assert 0.3 < float(y.std()) < 3.0


def test_random_solver_does_not_approximate():
    """Per the paper: random is for by-design only (ignores W)."""
    w = jnp.eye(16)
    a, b = random_solver(w, 8, key=jax.random.PRNGKey(8))
    assert float(jnp.linalg.norm(w - a @ b)) > 1.0


def test_get_solver_registry():
    assert get_solver("svd") is svd_solver
    with pytest.raises(ValueError):
        get_solver("nope")
