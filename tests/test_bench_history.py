"""The perf-trajectory gate: ``benchmarks/check_regression.py`` must
actually fail on a synthetic regression (the bench-trajectory CI job's
contract), and provenance-mismatched timings must refuse to compare.

No kernels run here — cells are hand-built to the microbench schema, so
this is cheap enough for tier 1.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.check_regression import (check, load_history,  # noqa: E402
                                         load_thresholds, provenance_sig)

CPU_INTERP = {"backend": "cpu", "device_kind": "cpu",
              "compiled_backend": None, "interpret_mode": True,
              "jax_version": "0.0"}
TPU_COMPILED = {"backend": "tpu", "device_kind": "TPU v5e",
                "compiled_backend": "tpu", "interpret_mode": False,
                "jax_version": "0.0"}

RULES = [
    {"pattern": "parity_max_abs_err/*", "kind": "correctness",
     "max_value": 5e-4},
    {"pattern": "cells_emitted/total", "kind": "count", "min_value": 20},
    {"pattern": "decode_step_ms/*", "kind": "timing",
     "max_regression_pct": 50},
]


def _cell(metric, variant, stats, prov, axes=None):
    return {"schema": 1, "suite": "microbench_kernels", "metric": metric,
            "variant": variant, "axes": axes or {"batch": 2, "seq": 32},
            "stats": stats, "provenance": dict(prov), "smoke": True,
            "unix_time": 0.0}


def _timing(ms, prov):
    return _cell("decode_step_ms", "pallas",
                 {"mean_ms": ms, "p50_ms": ms, "min_ms": ms,
                  "compile_ms": 100.0, "iters": 10, "warmup": 2}, prov)


# ---- timing regressions ------------------------------------------------------


def test_compiled_timing_regression_hard_fails():
    history = [_timing(1.0, TPU_COMPILED), _timing(1.9, TPU_COMPILED)]
    failures, warnings = check(history, RULES)
    assert len(failures) == 1 and "TIMING" in failures[0]
    assert not warnings


def test_interpret_timing_regression_only_warns():
    """CPU/interpret timings on shared runners are too noisy to block a
    merge: same synthetic regression, warn not fail."""
    history = [_timing(1.0, CPU_INTERP), _timing(1.9, CPU_INTERP)]
    failures, warnings = check(history, RULES)
    assert not failures
    assert len(warnings) == 1 and "warn-only" in warnings[0]


def test_timing_within_threshold_passes():
    history = [_timing(1.0, TPU_COMPILED), _timing(1.4, TPU_COMPILED)]
    failures, warnings = check(history, RULES)
    assert not failures and not warnings


def test_cross_provenance_cells_are_separate_series():
    """An interpret-mode cell after a compiled baseline is NOT a
    regression — different provenance means a different series, never a
    comparison (the BENCH_serve mislabeling this PR fixes)."""
    history = [_timing(0.3, TPU_COMPILED), _timing(1.9, CPU_INTERP)]
    failures, warnings = check(history, RULES)
    assert not failures and not warnings
    assert provenance_sig(history[0]) != provenance_sig(history[1])


def test_baseline_is_best_prior_not_last():
    """A noisy slow cell must not ratchet the baseline: newest compares
    against the BEST prior mean."""
    history = [_timing(1.0, TPU_COMPILED), _timing(2.5, TPU_COMPILED),
               _timing(1.2, TPU_COMPILED)]
    failures, _ = check(history, RULES)
    assert not failures  # 1.2 vs best 1.0 = +20% < 50%


# ---- correctness + count hard-fail everywhere --------------------------------


def test_parity_violation_hard_fails_even_interpreted():
    history = [_cell("parity_max_abs_err", "chunk_attention",
                     {"value": 0.2}, CPU_INTERP)]
    failures, _ = check(history, RULES)
    assert len(failures) == 1 and "CORRECTNESS" in failures[0]


def test_missing_benchmarked_path_hard_fails():
    history = [_cell("cells_emitted", "total", {"value": 12}, CPU_INTERP,
                     axes={})]
    failures, _ = check(history, RULES)
    assert len(failures) == 1 and "COUNT" in failures[0]


# ---- the real repo artifacts -------------------------------------------------


def test_repo_history_passes_repo_thresholds():
    """The committed trajectory must be green against the committed
    thresholds (otherwise the bench-trajectory job is red on main)."""
    history = load_history(str(REPO / "BENCH_history.jsonl"))
    rules = load_thresholds(str(REPO / "benchmarks" / "thresholds.json"))
    assert history, "BENCH_history.jsonl is empty"
    metrics = {f"{c['metric']}/{c['variant']}" for c in history}
    for path in ("decode_step_ms/pallas", "decode_step_ms/reference",
                 "prefill_chunk_ms/pallas", "prefill_chunk_ms/reference",
                 "kernel_us/paged_attention_pallas",
                 "kernel_us/chunk_attention_pallas",
                 "parity_max_abs_err/chunk_attention",
                 "cells_emitted/total"):
        assert path in metrics, f"no cell for benchmarked path {path}"
    for cell in history:  # every cell provenance-stamped
        prov = cell["provenance"]
        assert "interpret_mode" in prov and "compiled_backend" in prov
    failures, _ = check(history, rules)
    assert not failures, failures


def test_cli_exits_nonzero_on_synthetic_regression(tmp_path):
    """End-to-end: the CI invocation (python -m benchmarks.check_regression)
    demonstrably fails on a compiled-provenance regression."""
    hist = tmp_path / "hist.jsonl"
    with open(hist, "w") as fh:
        for cell in (_timing(1.0, TPU_COMPILED), _timing(3.0, TPU_COMPILED)):
            fh.write(json.dumps(cell) + "\n")
    rules = tmp_path / "thresholds.json"
    rules.write_text(json.dumps(RULES))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--history", str(hist), "--thresholds", str(rules)],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": f"{REPO}/src:{REPO}"})
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "TIMING" in proc.stdout
    # and the same history under interpret provenance exits 0 (warn-only)
    with open(hist, "w") as fh:
        for cell in (_timing(1.0, CPU_INTERP), _timing(3.0, CPU_INTERP)):
            fh.write(json.dumps(cell) + "\n")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.check_regression",
         "--history", str(hist), "--thresholds", str(rules)],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": f"{REPO}/src:{REPO}"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "WARN" in proc.stdout


# ---- provenance refusal in the shared helpers --------------------------------


def test_speedup_refuses_cross_provenance():
    from benchmarks.common import speedup, timing_cell

    a = {"ms": 1.0, **CPU_INTERP}
    b = {"ms": 0.5, **TPU_COMPILED}
    with pytest.raises(ValueError, match="provenance"):
        speedup(a, b)
    c = {"ms": 0.5, **CPU_INTERP}
    assert speedup(a, c) == pytest.approx(2.0)
    # timing_cell stamps the live provenance
    cell = timing_cell(1.25)
    assert cell["ms"] == 1.25
    assert "compiled_backend" in cell and "interpret_mode" in cell


def test_bench_serve_cells_are_provenance_stamped():
    """The committed BENCH_serve.json must never regress to bare floats."""
    with open(REPO / "BENCH_serve.json") as fh:
        summary = json.load(fh)
    for name, cell in summary["decode_step_ms"].items():
        assert isinstance(cell, dict), f"{name} is a bare float again"
        assert "ms" in cell and "compiled_backend" in cell, name
        if cell["interpret_mode"]:
            assert cell["compiled_backend"] is None, name
