"""Dense-vs-factorized differential serving matrix.

The paper's toolkit factorizes a model; PRs 1-5 built the serving stack.
This file proves the two compose: a factorized TransformerLM served
through the ContinuousEngine must (a) be *exact* at full rank — the SVD
path reconstructs W = A @ B to float tolerance, so the old 3% greedy
agreement was never a serving bug — and (b) degrade gracefully with
rank on a model whose spectra actually decay (random init has a flat
Marchenko-Pastur spectrum, so truncation there destroys the logits;
``spectral_decay`` shapes the fixture into the trained-network regime
the paper's compression results live in).

Matrix: solver in {svd, snmf, random} x rank ratio in {0.25, 0.5,
full-rank-equivalent}, each cell served end-to-end through the engine,
with agreement/exactness asserted on the SVD column and per-layer
reconstruction-error bounds asserted from the FactReport.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import auto_fact, spectral_decay
from repro.models import build_model
from repro.serve import ContinuousEngine, generate, make_trace, replay
from repro.serve.trace import greedy_agreement

EXCLUDE = ["embed", "lm_head"]  # factorize the blocks, keep the vocab maps


@pytest.fixture(scope="module")
def shaped():
    """Tiny transformer with power-law singular spectra (alpha=2.5) —
    the trained-weight regime where low-rank truncation is benign."""
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return spectral_decay(model, 2.5, exclude=EXCLUDE), cfg


@pytest.fixture(scope="module")
def flat():
    """Same architecture, raw random init: flat spectrum, the adversarial
    case for truncation (used for full-rank exactness, which must hold
    regardless of spectrum)."""
    cfg = get_config("paper-tiny").reduced()
    return build_model(jax.random.PRNGKey(0), cfg), cfg


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


def _serve(model, cfg, trace, **kw):
    eng = ContinuousEngine(model, cfg, batch=3, max_len=32,
                           max_prompt_len=16, chunk_size=8, buckets=(8, 16),
                           **kw)
    comps, _ = replay(eng, trace)
    return comps


# ---- full-rank exactness: the 3% agreement hole was a spectrum problem ------


def test_full_rank_svd_matches_dense_logits(flat):
    """rank=1.0 with gate=False keeps every LED at r = min(m, n): the SVD
    factors reconstruct W exactly, so logits match dense to float32
    round-off even on a flat-spectrum model."""
    model, cfg = flat
    fact, rep = auto_fact(model, 1.0, solver="svd", exclude=EXCLUDE,
                          gate=False, return_report=True)
    toks = jnp.asarray(_prompts([12], cfg.vocab, seed=7)[0])[None, :]
    ld, _ = model(toks)
    lf, _ = fact(toks)
    err = float(jnp.max(jnp.abs(ld - lf)))
    assert err < 1e-3, f"full-rank SVD logit error {err}"
    # per-layer reconstruction error is reported and ~0 at full rank
    assert rep.entries
    for path, kind, m, n, r, rel in rep.entries:
        assert r == min(m, n)
        assert rel < 1e-4, f"{path}: full-rank rel err {rel}"
    assert "rel_err" in rep.summary()


def test_full_rank_factorized_serving_agrees_exactly(flat):
    """The full-rank factorized model, served through the engine, emits
    the same greedy tokens as the dense engine on a seeded trace."""
    model, cfg = flat
    fact = auto_fact(model, 1.0, solver="svd", exclude=EXCLUDE, gate=False)
    trace = make_trace(6, seed=11, load=0.7, min_prompt=2, max_prompt=16,
                       min_new=2, max_new=8, vocab=cfg.vocab)
    dense_comps = _serve(model, cfg, trace)
    fact_comps = _serve(fact, cfg, trace)
    assert len(fact_comps) == len(trace)
    assert greedy_agreement(dense_comps, fact_comps) == 1.0


# ---- per-layer reconstruction-error bounds ----------------------------------


def test_recon_error_monotone_in_rank(shaped):
    """On the shaped model, SVD reconstruction error shrinks as rank
    grows, layer by layer; at ratio 0.5 every block layer is under 5%
    relative Frobenius error (alpha=2.5 concentrates >95% of the energy
    in the top half of the spectrum)."""
    model, _ = shaped
    errs = {}
    for ratio in (0.25, 0.5):
        _, rep = auto_fact(model, ratio, solver="svd", exclude=EXCLUDE,
                           gate=False, return_report=True)
        errs[ratio] = {e[0]: e[5] for e in rep.entries}
    assert errs[0.25].keys() == errs[0.5].keys()
    for path in errs[0.25]:
        assert errs[0.5][path] <= errs[0.25][path] + 1e-6, path
        assert errs[0.5][path] < 0.05, f"{path}: {errs[0.5][path]}"


def test_svd_recon_beats_random_per_layer(shaped):
    """SVD is the optimal rank-r approximation (Eckart-Young); the random
    solver must never beat it on any layer."""
    model, _ = shaped
    _, rs = auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE,
                      gate=False, return_report=True)
    _, rr = auto_fact(model, 0.5, solver="random", exclude=EXCLUDE,
                      gate=False, return_report=True)
    svd_err = {e[0]: e[5] for e in rs.entries}
    rnd_err = {e[0]: e[5] for e in rr.entries}
    assert svd_err.keys() == rnd_err.keys() and svd_err
    for path in svd_err:
        assert svd_err[path] <= rnd_err[path] + 1e-6, path


# ---- the solver x rank serving matrix ---------------------------------------


@pytest.mark.parametrize("solver", ["svd", "snmf", "random"])
@pytest.mark.parametrize("ratio", [0.25, 0.5, 1.0])
def test_solver_rank_matrix_serves(shaped, solver, ratio):
    """Every cell of the matrix must serve: the engine drains the trace,
    every completion is well-formed, and on the SVD column the factorized
    tokens track the dense engine (>= 0.9 agreement at ratio 0.5, exact
    at full rank)."""
    model, cfg = shaped
    kw = {"key": jax.random.PRNGKey(3)} if solver == "random" else {}
    if solver == "snmf":
        kw["num_iter"] = 10  # keep the matrix cheap; quality asserted on svd
    fact = auto_fact(model, ratio, solver=solver, exclude=EXCLUDE,
                     gate=False, **kw)
    trace = make_trace(6, seed=23, load=0.7, min_prompt=2, max_prompt=16,
                       min_new=2, max_new=8, vocab=cfg.vocab)
    comps = _serve(fact, cfg, trace)
    assert len(comps) == len(trace)
    for (_, req), c in zip(trace, comps):  # trace order == uid order
        assert len(c.tokens) == req.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in c.tokens)
    if solver == "svd":
        dense_comps = _serve(model, cfg, trace)
        agree = greedy_agreement(dense_comps, comps)
        if ratio == 1.0:
            assert agree == 1.0
        elif ratio == 0.5:
            assert agree >= 0.9, f"svd@0.5 agreement {agree}"


def test_rank_half_agreement_on_seeded_traces(shaped):
    """The headline number: svd @ ratio 0.5 on the shaped model keeps
    greedy agreement >= 0.9 across independent seeded traces (this is
    the bound the benchmark asserts into BENCH_serve.json)."""
    model, cfg = shaped
    fact = auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE, gate=False)
    for seed in (1, 2):
        trace = make_trace(5, seed=seed, load=0.7, min_prompt=2,
                           max_prompt=16, min_new=4, max_new=8,
                           vocab=cfg.vocab)
        agree = greedy_agreement(_serve(model, cfg, trace),
                                 _serve(fact, cfg, trace))
        assert agree >= 0.9, f"seed={seed}: agreement {agree}"


# ---- factorized engine matches one-shot generate ----------------------------


def test_factorized_continuous_matches_generate(shaped):
    """The factorized model is just a model: the continuous engine's
    output for it must match one-shot ``generate`` token for token
    (slot recycling, chunked prefill and paging change nothing)."""
    model, cfg = shaped
    fact = auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE, gate=False)
    prompts = _prompts([9, 5, 12, 3], cfg.vocab, seed=31)
    eng = ContinuousEngine(fact, cfg, batch=2, max_len=32,
                           max_prompt_len=16, chunk_size=8, buckets=(8, 16))
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    comps = eng.run()
    assert len(comps) == len(prompts)
    for p, c in zip(prompts, comps):
        cache = fact.init_cache(1, 32, cfg, dtype=jnp.float32)
        out, _ = generate(fact, jnp.asarray(p)[None, :], cache, n_steps=5)
        np.testing.assert_array_equal(np.array(c.tokens),
                                      np.asarray(out)[0],
                                      err_msg=f"plen={p.size}")


# ---- fuse='pallas' parity (interpret mode off-TPU) --------------------------


def test_fused_led_forward_parity(shaped):
    """auto_fact(fuse='pallas') routes every LED through the Pallas
    kernel; logits must match the jnp path to kernel tolerance and the
    greedy tokens must be identical on a seeded prompt."""
    model, cfg = shaped
    f_jnp = auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE,
                      gate=False, fuse="jnp")
    f_pl = auto_fact(model, 0.5, solver="svd", exclude=EXCLUDE,
                     gate=False, fuse="pallas")
    toks = jnp.asarray(_prompts([10], cfg.vocab, seed=17)[0])[None, :]
    lj, _ = f_jnp(toks)
    lp, _ = f_pl(toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lj),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(jnp.argmax(lp, -1)),
                                  np.asarray(jnp.argmax(lj, -1)))
