"""Sharding rules: Megatron TP + EP + LED boundary specs + FSDP fallbacks,
the paged/dense cache spec rules, and the activation-mesh context."""

import threading
from types import SimpleNamespace

import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (activation_mesh, active_activation_mesh,
                                 batch_spec, cache_specs, constrain_acts,
                                 spec_for_param)
from repro.nn.attention import KVCache, PagedKVCache


def mesh(shape_dict):
    return SimpleNamespace(shape=shape_dict)


POD = mesh({"data": 16, "model": 16})
MULTI = mesh({"pod": 2, "data": 16, "model": 16})
TP_ONLY = mesh({"model": 16})
DATA_ONLY = mesh({"data": 16})


def test_column_parallel_linear():
    assert spec_for_param("blocks.attn.q_proj.weight", (36, 2048, 2048),
                          POD) == P(None, None, "model")
    assert spec_for_param("blocks.mlp.up_proj.weight", (48, 4096, 11008),
                          POD) == P(None, None, "model")


def test_row_parallel_linear():
    assert spec_for_param("blocks.attn.o_proj.weight", (36, 2048, 2048),
                          POD) == P(None, "model", None)
    assert spec_for_param("blocks.mlp.down_proj.weight", (48, 11008, 4096),
                          POD) == P(None, "model", None)


def test_vocab_parallel_embedding_and_head():
    assert spec_for_param("embed.weight", (151936, 2048), POD) == \
        P("model", None)
    assert spec_for_param("lm_head.weight", (2048, 151936), POD) == \
        P(None, "model")


def test_column_bias_sharded_row_bias_replicated():
    assert spec_for_param("blocks.attn.q_proj.bias", (36, 2048), POD) == \
        P(None, "model")
    assert spec_for_param("blocks.mlp.down_proj.bias", (36, 4096), POD) == \
        P(None, None)


def test_led_factor_boundary_sharding():
    # column-parallel layer: A replicated, B out-sharded
    assert spec_for_param("blocks.attn.q_proj.A", (36, 2048, 128), POD) == \
        P(None, None, None)
    assert spec_for_param("blocks.attn.q_proj.B", (36, 128, 2048), POD) == \
        P(None, None, "model")
    # row-parallel layer: A in-sharded, B replicated
    assert spec_for_param("blocks.attn.o_proj.A", (36, 2048, 128), POD) == \
        P(None, "model", None)
    assert spec_for_param("blocks.attn.o_proj.B", (36, 128, 2048), POD) == \
        P(None, None, None)


def test_expert_parallel():
    # (L, E, in, out): expert axis on "model"
    assert spec_for_param("blocks.mlp.experts.gate_proj.weight",
                          (61, 384, 7168, 2048), POD) == \
        P(None, "model", None, None)
    # factorized experts keep EP
    assert spec_for_param("blocks.mlp.experts.up_proj.A",
                          (61, 384, 7168, 128), POD) == \
        P(None, "model", None, None)


def test_router_and_norms_replicated():
    assert spec_for_param("blocks.mlp.router.weight", (61, 7168, 384),
                          POD) == P(None, None, None)
    assert spec_for_param("blocks.attn_norm.scale", (36, 2048), POD) == \
        P(None, None)


def test_divisibility_fallback():
    # hymba vocab 32001 is not divisible by 16 → replicate that dim
    assert spec_for_param("lm_head.weight", (1600, 32001), POD) == \
        P(None, None)
    assert spec_for_param("embed.weight", (32001, 1600), POD) == \
        P(None, None)


def test_fsdp_adds_data_axis():
    spec = spec_for_param("blocks.mlp.experts.gate_proj.weight",
                          (61, 384, 7168, 2048), POD, fsdp=True)
    assert spec == P(None, "model", "data", None)
    # small params stay unsharded on data
    spec_small = spec_for_param("blocks.attn_norm.scale", (36, 2048), POD,
                                fsdp=True)
    assert spec_small == P(None, None)


def test_fsdp_multipod_uses_both_dp_axes():
    spec = spec_for_param("blocks.mlp.down_proj.weight",
                          (48, 11008, 4096), MULTI, fsdp=True)
    assert spec == P(None, "model", ("pod", "data"))


def test_batch_spec():
    assert batch_spec(POD) == P("data")
    assert batch_spec(MULTI) == P(("pod", "data"))


def test_mamba_projections():
    assert spec_for_param("blocks.mixer.in_proj.weight", (64, 2560, 10368),
                          POD) == P(None, None, "model")
    assert spec_for_param("blocks.mixer.out_proj.weight", (64, 5120, 2560),
                          POD) == P(None, "model", None)
    assert spec_for_param("blocks.mixer.A_log", (64, 80), POD) == P(None, None)


# -- replication-fallback spec matrix over mesh shapes -----------------------

# non-divisible dims must replicate NO MATTER the mesh shape; divisible
# dims shard only on the axes the mesh actually has
_MESHES = {"pod": POD, "multi": MULTI, "tp_only": TP_ONLY,
           "data_only": DATA_ONLY}


@pytest.mark.parametrize("name", sorted(_MESHES))
def test_fallback_matrix_odd_vocab_replicates(name):
    m = _MESHES[name]
    # 32001 % 16 != 0 → both the table and the head replicate everywhere
    assert spec_for_param("embed.weight", (32001, 1600), m) == P(None, None)
    assert spec_for_param("lm_head.weight", (1600, 32001), m) == \
        P(None, None)


@pytest.mark.parametrize("name", sorted(_MESHES))
def test_fallback_matrix_odd_proj_dims(name):
    m = _MESHES[name]
    has_tp = "model" in m.shape
    # divisible output dim shards iff the mesh has a model axis
    want = P(None, None, "model") if has_tp else P(None, None, None)
    assert spec_for_param("blocks.attn.q_proj.weight", (4, 64, 2048),
                          m) == want
    # odd output dim (prime) replicates even with a model axis
    assert spec_for_param("blocks.attn.q_proj.weight", (4, 64, 2003),
                          m) == P(None, None, None)
    # odd input dim on a row-parallel layer replicates too
    assert spec_for_param("blocks.attn.o_proj.weight", (4, 2003, 64),
                          m) == P(None, None, None)
    # odd expert count falls back from expert parallelism (the expert
    # branch owns the param: no silent downgrade to column sharding)
    assert spec_for_param("blocks.mlp.experts.up_proj.weight",
                          (4, 17, 64, 2048), m) == \
        P(None, None, None, None)


def test_fallback_matrix_fsdp_skips_odd_dims():
    # fsdp walks to the FIRST data-divisible free dim: dim 1 (11008) on
    # POD; a shape with no divisible free dim stays unsharded on data
    assert spec_for_param("blocks.mlp.down_proj.weight", (47, 11008, 4096),
                          POD, fsdp=True) == P(None, "model", "data")
    assert spec_for_param("blocks.mlp.router.weight", (47, 2003, 383),
                          POD, fsdp=True) == P(None, None, None)


# -- cache spec rules: paged pool vs dense per-slot lanes --------------------


def _leaf(*shape):
    return SimpleNamespace(shape=shape)


def test_paged_cache_specs():
    # pool (L, n_blocks, bs, kvh, hd): blocks GLOBAL over data (the host
    # allocator is placement-free), kv heads over "model"; table/length
    # shard their batch dim over data
    cache = PagedKVCache(k=_leaf(2, 64, 8, 16, 64), v=_leaf(2, 64, 8, 16, 64),
                         table=_leaf(32, 16), length=_leaf(2, 32))
    specs = cache_specs(cache, POD)
    assert specs.k == P(None, None, None, "model", None)
    assert specs.v == P(None, None, None, "model", None)
    assert specs.table == P("data", None)
    assert specs.length == P(None, "data")


def test_paged_cache_specs_gqa_fallback():
    # kv_heads=3 does not divide model=16 → pool replicates entirely
    cache = PagedKVCache(k=_leaf(2, 64, 8, 3, 64), v=_leaf(2, 64, 8, 3, 64),
                         table=_leaf(32, 16), length=_leaf(2, 32))
    specs = cache_specs(cache, POD)
    assert specs.k == P(None, None, None, None, None)
    assert specs.table == P("data", None)
    # odd batch → table and length replicate but heads still shard
    cache = PagedKVCache(k=_leaf(2, 64, 8, 16, 64), v=_leaf(2, 64, 8, 16, 64),
                         table=_leaf(33, 16), length=_leaf(2, 33))
    specs = cache_specs(cache, POD)
    assert specs.k == P(None, None, None, "model", None)
    assert specs.table == P(None, None)
    assert specs.length == P(None, None)


def test_dense_cache_specs():
    # per-slot lanes (L, B, S, kvh, hd): batch over data, heads over model
    cache = KVCache(k=_leaf(2, 32, 128, 16, 64), v=_leaf(2, 32, 128, 16, 64),
                    length=_leaf(2, 32))
    specs = cache_specs(cache, POD)
    assert specs.k == P(None, "data", None, "model", None)
    assert specs.length == P(None, "data")
    # multi-pod meshes spread the batch over both data axes
    specs = cache_specs(cache, MULTI)
    assert specs.k == P(None, ("pod", "data"), None, "model", None)


# -- activation_mesh context: thread-safe by construction --------------------


def _one_device_mesh():
    import jax
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_activation_mesh_does_not_leak_across_threads():
    # BackgroundServer traces engine steps off the main thread; a scope
    # entered on THIS thread must be invisible there (ContextVar — each
    # thread starts from a fresh context), so constrain_acts stays the
    # identity off-thread and an off-thread scope is invisible here
    m = _one_device_mesh()
    x = jnp.ones((4, 8))
    seen = {}
    inner = threading.Event()
    release = threading.Event()

    def probe():
        seen["off_thread_scope"] = active_activation_mesh()
        seen["off_thread_identity"] = constrain_acts(x) is x
        with activation_mesh(m, seq_parallel=True):
            inner.set()
            release.wait(timeout=10)

    with activation_mesh(m):
        assert active_activation_mesh() == (m, False)
        t = threading.Thread(target=probe)
        t.start()
        assert inner.wait(timeout=10)
        # the probe thread is INSIDE its own seq-parallel scope right now;
        # this thread still sees only its own
        assert active_activation_mesh() == (m, False)
        release.set()
        t.join()
    assert seen["off_thread_scope"] is None
    assert seen["off_thread_identity"]
    assert active_activation_mesh() is None


def test_activation_mesh_restores_on_exception():
    m = _one_device_mesh()
    x = jnp.ones((4, 8))
    with pytest.raises(RuntimeError):
        with activation_mesh(m):
            raise RuntimeError("boom")
    assert active_activation_mesh() is None
    assert constrain_acts(x) is x


def test_activation_mesh_scopes_nest():
    m = _one_device_mesh()
    with activation_mesh(m):
        with activation_mesh(m, seq_parallel=True):
            assert active_activation_mesh() == (m, True)
        assert active_activation_mesh() == (m, False)  # outer restored
    assert active_activation_mesh() is None
