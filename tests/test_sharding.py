"""Sharding rules: Megatron TP + EP + LED boundary specs + FSDP fallbacks."""

from types import SimpleNamespace

from jax.sharding import PartitionSpec as P

from repro.dist.sharding import batch_spec, spec_for_param


def mesh(shape_dict):
    return SimpleNamespace(shape=shape_dict)


POD = mesh({"data": 16, "model": 16})
MULTI = mesh({"pod": 2, "data": 16, "model": 16})


def test_column_parallel_linear():
    assert spec_for_param("blocks.attn.q_proj.weight", (36, 2048, 2048),
                          POD) == P(None, None, "model")
    assert spec_for_param("blocks.mlp.up_proj.weight", (48, 4096, 11008),
                          POD) == P(None, None, "model")


def test_row_parallel_linear():
    assert spec_for_param("blocks.attn.o_proj.weight", (36, 2048, 2048),
                          POD) == P(None, "model", None)
    assert spec_for_param("blocks.mlp.down_proj.weight", (48, 11008, 4096),
                          POD) == P(None, "model", None)


def test_vocab_parallel_embedding_and_head():
    assert spec_for_param("embed.weight", (151936, 2048), POD) == \
        P("model", None)
    assert spec_for_param("lm_head.weight", (2048, 151936), POD) == \
        P(None, "model")


def test_column_bias_sharded_row_bias_replicated():
    assert spec_for_param("blocks.attn.q_proj.bias", (36, 2048), POD) == \
        P(None, "model")
    assert spec_for_param("blocks.mlp.down_proj.bias", (36, 4096), POD) == \
        P(None, None)


def test_led_factor_boundary_sharding():
    # column-parallel layer: A replicated, B out-sharded
    assert spec_for_param("blocks.attn.q_proj.A", (36, 2048, 128), POD) == \
        P(None, None, None)
    assert spec_for_param("blocks.attn.q_proj.B", (36, 128, 2048), POD) == \
        P(None, None, "model")
    # row-parallel layer: A in-sharded, B replicated
    assert spec_for_param("blocks.attn.o_proj.A", (36, 2048, 128), POD) == \
        P(None, "model", None)
    assert spec_for_param("blocks.attn.o_proj.B", (36, 128, 2048), POD) == \
        P(None, None, None)


def test_expert_parallel():
    # (L, E, in, out): expert axis on "model"
    assert spec_for_param("blocks.mlp.experts.gate_proj.weight",
                          (61, 384, 7168, 2048), POD) == \
        P(None, "model", None, None)
    # factorized experts keep EP
    assert spec_for_param("blocks.mlp.experts.up_proj.A",
                          (61, 384, 7168, 128), POD) == \
        P(None, "model", None, None)


def test_router_and_norms_replicated():
    assert spec_for_param("blocks.mlp.router.weight", (61, 7168, 384),
                          POD) == P(None, None, None)
    assert spec_for_param("blocks.attn_norm.scale", (36, 2048), POD) == \
        P(None, None)


def test_divisibility_fallback():
    # hymba vocab 32001 is not divisible by 16 → replicate that dim
    assert spec_for_param("lm_head.weight", (1600, 32001), POD) == \
        P(None, None)
    assert spec_for_param("embed.weight", (32001, 1600), POD) == \
        P(None, None)


def test_fsdp_adds_data_axis():
    spec = spec_for_param("blocks.mlp.experts.gate_proj.weight",
                          (61, 384, 7168, 2048), POD, fsdp=True)
    assert spec == P(None, "model", "data", None)
    # small params stay unsharded on data
    spec_small = spec_for_param("blocks.attn_norm.scale", (36, 2048), POD,
                                fsdp=True)
    assert spec_small == P(None, None)


def test_fsdp_multipod_uses_both_dp_axes():
    spec = spec_for_param("blocks.mlp.down_proj.weight",
                          (48, 11008, 4096), MULTI, fsdp=True)
    assert spec == P(None, "model", ("pod", "data"))


def test_batch_spec():
    assert batch_spec(POD) == P("data")
    assert batch_spec(MULTI) == P(("pod", "data"))


def test_mamba_projections():
    assert spec_for_param("blocks.mixer.in_proj.weight", (64, 2560, 10368),
                          POD) == P(None, None, "model")
    assert spec_for_param("blocks.mixer.out_proj.weight", (64, 5120, 2560),
                          POD) == P(None, "model", None)
    assert spec_for_param("blocks.mixer.A_log", (64, 80), POD) == P(None, None)
