"""Regression-lock the assigned architecture specs (they must match the
assignment table exactly) and the shape applicability rules."""

import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config

# (layers, d_model, heads, kv_heads, d_ff, vocab) per the assignment
SPECS = {
    "qwen2.5-3b": ("dense", 36, 2048, 16, 2, 11008, 151936),
    "yi-9b": ("dense", 48, 4096, 32, 4, 11008, 64000),
    "granite-34b": ("dense", 88, 6144, 48, 1, 24576, 49152),
    "glm4-9b": ("dense", 40, 4096, 32, 2, 13696, 151552),
    "mamba2-2.7b": ("ssm", 64, 2560, 0, 0, 0, 50280),
    "whisper-medium": ("encdec", 24, 1024, 16, 16, 4096, 51865),
    "kimi-k2-1t-a32b": ("moe", 61, 7168, 64, 8, 2048, 163840),
    "deepseek-moe-16b": ("moe", 28, 2048, 16, 16, 1408, 102400),
    "chameleon-34b": ("vlm", 48, 8192, 64, 8, 22016, 65536),
    "hymba-1.5b": ("hybrid", 32, 1600, 25, 5, 5504, 32001),
}


@pytest.mark.parametrize("arch", list(SPECS))
def test_assigned_spec_exact(arch):
    fam, L, d, h, kv, ff, v = SPECS[arch]
    cfg = get_config(arch)
    assert cfg.family == fam
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v


def test_arch_registry_complete():
    assert sorted(ARCH_IDS) == sorted(SPECS)


def test_moe_details():
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.n_experts, kimi.top_k, kimi.n_shared) == (384, 8, 1)
    ds = get_config("deepseek-moe-16b")
    assert (ds.n_experts, ds.top_k, ds.n_shared) == (64, 6, 2)


def test_ssm_details():
    mamba = get_config("mamba2-2.7b")
    assert mamba.ssm_state == 128 and mamba.supports_long_context
    hymba = get_config("hymba-1.5b")
    assert hymba.ssm_state == 16 and hymba.window == 1024
    assert hymba.supports_long_context


def test_qwen_has_qkv_bias():
    assert get_config("qwen2.5-3b").qkv_bias
    assert not get_config("yi-9b").qkv_bias


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_applicability():
    """long_500k only for sub-quadratic archs (the documented skip rule)."""
    for arch in ARCH_IDS:
        shapes = applicable_shapes(get_config(arch))
        if arch in ("mamba2-2.7b", "hymba-1.5b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_total_cell_count():
    """8 archs × 3 shapes + 2 archs × 4 shapes = 32 applicable cells."""
    n = sum(len(applicable_shapes(get_config(a))) for a in ARCH_IDS)
    assert n == 32


def test_reduced_configs_are_tiny():
    for arch in ARCH_IDS:
        r = get_config(arch).reduced()
        assert r.n_layers == 2 and r.d_model == 64 and r.vocab == 256
        assert r.dtype == "float32"
