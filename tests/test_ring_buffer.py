"""Property-based ring-buffer invariants (``slot(p) = p % window``).

Random ``(window, prompt_len, chunk_size)`` triples drive the per-slot
ring KV path at the :class:`~repro.nn.attention.Attention` level:

* the lane mapping really is ``slot(p) = p % window`` — after any
  chunking, lane ``p % window`` holds exactly the K projection of
  position ``p`` for the newest ``window`` positions;
* chunked ring prefill + ring decode match the full-sequence oracle
  (causal + sliding-window mask over the whole prompt) at every kept
  position, across wraparound;
* slot recycling never reads a stale lane: a request scanned into a slot
  full of a previous occupant's K/V produces outputs bit-identical to
  the same request on a zeroed cache (the masks, not a reset pass, are
  the isolation boundary);
* decode memory stays O(window) per slot — the cache never grows with
  prompt length.

Runs through the ``tests/_hyp`` shim: property tests skip (not fail)
where hypothesis is not installed.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.nn.attention import Attention, KVCache

DIM, HEADS, KVH, HD = 32, 2, 1, 16


def _attn(window: int) -> Attention:
    return Attention.create(jax.random.PRNGKey(7), DIM, HEADS, KVH,
                            head_dim=HD, window=window, dtype=jnp.float32)


def _ring_cache(batch: int, window: int) -> KVCache:
    return KVCache.zeros(batch, window, KVH, HD, dtype=jnp.float32,
                        per_slot=True)


def _scan_chunks(attn, cache, x, slot, chunk):
    """Feed ``x`` (1, plen, dim) through prefill_chunk in ``chunk``-sized
    spans (last span ragged), returning (outputs (1, plen, dim), cache)."""
    plen = x.shape[1]
    outs = []
    for off in range(0, plen, chunk):
        n = min(chunk, plen - off)
        span = x[:, off:off + chunk]
        if span.shape[1] < chunk:  # right-pad the ragged tail
            span = jnp.pad(span, ((0, 0), (0, chunk - span.shape[1]),
                                  (0, 0)))
        out, cache = attn.prefill_chunk(
            span, cache, slot=jnp.asarray(slot, jnp.int32),
            offset=jnp.asarray(off, jnp.int32),
            n_valid=jnp.asarray(n, jnp.int32))
        outs.append(out[:, :n])
    return jnp.concatenate(outs, axis=1), cache


@given(window=st.integers(2, 10), plen=st.integers(1, 40),
       chunk=st.integers(1, 12), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_ring_lane_mapping_and_oracle_parity(window, plen, chunk, seed):
    """slot(p) = p % window holds after any chunking, outputs match the
    full-attention oracle, and the cache stays O(window)."""
    attn = _attn(window)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, plen, DIM),
                          jnp.float32)
    oracle = attn(x)  # causal + sliding-window full forward
    cache = _ring_cache(2, window)
    out, cache = _scan_chunks(attn, cache, x, slot=1, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
    # O(window) decode memory: lane count never tracks prompt length
    assert cache.k.shape == (2, window, KVH, HD)
    assert int(cache.length[1]) == plen
    # lane p % window holds exactly position p's K for the newest window
    # positions (RoPE applied at absolute position p)
    _, k_full, _ = attn._qkv(x)
    for p in range(max(0, plen - window), plen):
        np.testing.assert_array_equal(
            np.asarray(cache.k[1, p % window]), np.asarray(k_full[0, p]),
            err_msg=f"lane {p % window} does not hold position {p}")


@given(window=st.integers(2, 10), plen=st.integers(1, 24),
       chunk=st.integers(1, 12), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=15, deadline=None)
def test_recycled_ring_slot_never_reads_stale_lanes(window, plen, chunk,
                                                    seed):
    """A slot whose lanes still hold a previous request's K/V must serve a
    new request (offset restarting at 0) bit-identically to a zeroed
    cache — wraparound masking, not a reset pass, isolates occupants."""
    attn = _attn(window)
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    x_old = jax.random.normal(k0, (1, 31, DIM), jnp.float32)
    x_new = jax.random.normal(k1, (1, plen, DIM), jnp.float32)
    dirty = _ring_cache(2, window)
    _, dirty = _scan_chunks(attn, dirty, x_old, slot=1, chunk=5)
    assert not np.allclose(np.asarray(dirty.k[1]), 0)  # genuinely dirty
    # "recycle": same slot, new request from offset 0, no reset
    out_dirty, c_dirty = _scan_chunks(attn, dirty, x_new, slot=1,
                                      chunk=chunk)
    out_clean, c_clean = _scan_chunks(attn, _ring_cache(2, window), x_new,
                                      slot=1, chunk=chunk)
    np.testing.assert_array_equal(np.asarray(out_dirty),
                                  np.asarray(out_clean))
    # ... and the property survives decode steps on the recycled slot
    step = jax.random.normal(jax.random.fold_in(k1, 9), (2, 1, DIM),
                             jnp.float32)
    d_dirty, _ = attn.decode(step, c_dirty)
    d_clean, _ = attn.decode(step, c_clean)
    np.testing.assert_array_equal(np.asarray(d_dirty[1]),
                                  np.asarray(d_clean[1]))


@given(window=st.integers(2, 8), seed=st.integers(0, 2**32 - 1))
@settings(max_examples=10, deadline=None)
def test_ring_decode_matches_oracle_past_wraparound(window, seed):
    """Per-slot ring decode across 3 windows of tokens: every step's
    output matches the full-attention oracle row (the ring holds exactly
    the last ``window`` positions at all times)."""
    attn = _attn(window)
    total = 3 * window + 1
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, total, DIM),
                          jnp.float32)
    oracle = np.asarray(attn(x))
    cache = _ring_cache(1, window)
    prefix = 2  # short prefill, then decode one token at a time
    _, cache = _scan_chunks(attn, cache, x[:, :prefix], slot=0, chunk=2)
    for t in range(prefix, total):
        out, cache = attn.decode(x[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(out)[0, 0], oracle[0, t],
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"decode step t={t}")
    assert cache.k.shape[1] == window  # still O(window)
