"""Decode preemption: a lower-priority running decode is evicted so a
blocked higher-priority arrival can start, then resumed later as a
prefix-hit re-admission — and the resumed stream must be BIT-IDENTICAL
to an unpreempted replay.

The differential matrix runs on both KV layouts: paged (resume re-enters
through the prefix cache, recomputing at most the partial last block +
final token) and dense (resume is a full recompute — still required to
be bit-identical).  Scheduler-level priority/aging/cancel contracts live
in ``tests/test_priority_sched.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousEngine, SloBudgetAdapter, generate

LAYOUTS = [
    dict(kv_layout="paged", block_size=4),
    dict(kv_layout="dense"),
]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


def _baseline(model, cfg, prompt, n, max_len=32):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return np.asarray(out)[0]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


def _preempt_scenario(model, cfg, layout_kw, *, preemption=True, steps=8):
    """Fill the batch with low-priority decodes, let them run ``steps``
    steps, then submit high-priority arrivals that need their slots.
    Returns (engine, [(uid, prompt, n_new)])."""
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, preemption=preemption,
                           **layout_kw)
    low = _prompts([8, 8], cfg.vocab, seed=0)
    high = _prompts([6, 6], cfg.vocab, seed=1)
    jobs = []
    for p in low:
        jobs.append((eng.submit(p, max_new_tokens=12, priority=2), p, 12))
    for _ in range(steps):
        eng.step()
    assert eng.scheduler.n_running == 2
    for p in high:
        jobs.append((eng.submit(p, max_new_tokens=6, priority=0), p, 6))
    return eng, jobs


# ---- the differential matrix ------------------------------------------------


@pytest.mark.parametrize("layout_kw", LAYOUTS,
                         ids=[k["kv_layout"] for k in LAYOUTS])
def test_preempt_resume_bit_identical(setup, layout_kw):
    model, cfg = setup
    eng, jobs = _preempt_scenario(model, cfg, layout_kw)
    comps = {c.uid: c for c in eng.run()}
    ps = eng.preempt_stats()
    assert ps["preemptions"] >= 1, "scenario failed to force a preemption"
    assert ps["resumes"] >= 1
    assert ps["preempt_violations"] == 0
    assert ps["preempted_in_flight"] == 0  # every life merged back
    for uid, prompt, n in jobs:
        c = comps[uid]
        assert c.finish_reason == "length"
        np.testing.assert_array_equal(
            np.array(c.tokens), _baseline(model, cfg, prompt, n),
            err_msg=f"{layout_kw['kv_layout']} uid {uid} diverged")
    # preempted completions are attributed, high-priority ones untouched
    preempted = [c for c in comps.values() if c.preemptions > 0]
    assert preempted and all(c.priority == 2 for c in preempted)
    # no client-visible completion may leak the internal reason
    assert all(c.finish_reason != "preempted" for c in comps.values())


@pytest.mark.parametrize("layout_kw", LAYOUTS,
                         ids=[k["kv_layout"] for k in LAYOUTS])
def test_preemption_releases_all_blocks(setup, layout_kw):
    model, cfg = setup
    eng, _ = _preempt_scenario(model, cfg, layout_kw)
    eng.run()
    if eng.manager is not None:
        assert eng.manager.fully_free
        assert eng.manager.allocator.n_in_use == 0


def test_paged_resume_is_a_prefix_hit(setup):
    """The resumed request's committed tokens re-enter through the prefix
    cache — full blocks are skipped, not recomputed."""
    model, cfg = setup
    eng, _ = _preempt_scenario(model, cfg, dict(kv_layout="paged",
                                                block_size=4))
    eng.reset_stats()
    eng.run()
    assert eng.preempt_stats()["resumes"] >= 1
    assert eng.prefill_stats()["prefix_skipped_tokens"] > 0


@pytest.mark.parametrize("layout_kw", LAYOUTS,
                         ids=[k["kv_layout"] for k in LAYOUTS])
def test_preemption_off_still_serves_identically(setup, layout_kw):
    """``preemption=False`` degrades to pure priority admission: nothing
    is evicted, outputs stay bit-identical, high-priority arrivals simply
    wait for a free slot."""
    model, cfg = setup
    eng, jobs = _preempt_scenario(model, cfg, layout_kw, preemption=False)
    comps = {c.uid: c for c in eng.run()}
    assert eng.preempt_stats()["preemptions"] == 0
    for uid, prompt, n in jobs:
        np.testing.assert_array_equal(
            np.array(comps[uid].tokens), _baseline(model, cfg, prompt, n))
        assert comps[uid].preemptions == 0


def test_cancel_while_awaiting_resume_merges_earlier_tokens(setup):
    """Cancelling a preempted request while it waits in the resume queue
    must return its already-generated tokens under ``"cancelled"`` — the
    client streamed them, the completion cannot pretend they never
    happened."""
    model, cfg = setup
    eng, jobs = _preempt_scenario(model, cfg, dict(kv_layout="paged",
                                                   block_size=4))
    # step until a preemption parks at least one low-priority request
    for _ in range(64):
        eng.step()
        if eng.preempt_stats()["preempted_in_flight"] > 0:
            break
    assert eng.preempt_stats()["preempted_in_flight"] > 0
    low_uids = {uid for uid, _, n in jobs if n == 12}
    parked = [r.uid for r in eng.scheduler.pending if r.uid in low_uids]
    assert parked
    victim = parked[0]
    assert eng.cancel(victim)
    comps = {c.uid: c for c in eng.run()}
    c = comps[victim]
    assert c.finish_reason == "cancelled"
    assert len(c.tokens) > 0, "earlier-life tokens lost on cancel"
    assert c.preemptions >= 1 and c.first_token_at > 0
    prompt = {uid: p for uid, p, _ in jobs}[victim]
    np.testing.assert_array_equal(
        np.array(c.tokens),
        _baseline(model, cfg, prompt, 12)[:len(c.tokens)])
    if eng.manager is not None:
        assert eng.manager.fully_free


def test_repeated_preemption_accumulates(setup):
    """A request preempted more than once still merges into ONE
    completion with the full stream and the right count."""
    model, cfg = setup
    eng = ContinuousEngine(model, cfg, batch=1, max_len=48,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4)
    prompt = _prompts([6], cfg.vocab, seed=3)[0]
    uid = eng.submit(prompt, max_new_tokens=16, priority=3)
    done = []
    interrupts = 0
    for _ in range(400):
        done.extend(eng.step())
        if any(c.uid == uid for c in done):
            break
        # whenever the victim is mid-decode, throw an urgent job at it
        if (interrupts < 2
                and eng.scheduler.find(uid)[0] == "running"
                and eng.scheduler.n_pending == 0):
            eng.submit(_prompts([4], cfg.vocab, seed=10 + interrupts)[0],
                       max_new_tokens=2, priority=0)
            interrupts += 1
    comps = {c.uid: c for c in done}
    assert uid in comps, "victim never finished"
    c = comps[uid]
    assert c.preemptions == 2
    np.testing.assert_array_equal(np.array(c.tokens),
                                  _baseline(model, cfg, prompt, 16,
                                            max_len=48))
    assert eng.manager.fully_free


# ---- SLO budget adapter -----------------------------------------------------


class _FakeEngine:
    def __init__(self, budget=8, buckets=(4, 8)):
        self.prefill_chunk_budget = budget
        self.buckets = buckets
        self.recent_ttfts = []


def test_slo_adapter_grows_on_miss_and_shrinks_on_slack():
    eng = _FakeEngine(budget=8)
    adapter = SloBudgetAdapter(0.1, window=4)
    assert adapter(eng) is None  # no signal yet
    eng.recent_ttfts = [0.5] * 4  # way over target
    assert adapter(eng) == 16
    eng.prefill_chunk_budget = 16
    assert adapter(eng) is None  # hysteresis: no fresh observations
    eng.recent_ttfts += [0.01] * 4  # comfortably under half the target
    assert adapter(eng) == 8
    assert adapter.adaptations == 2


def test_slo_adapter_clamps():
    eng = _FakeEngine(budget=8, buckets=(4, 8))
    adapter = SloBudgetAdapter(0.1, window=1, max_budget=12)
    eng.recent_ttfts = [9.9]
    assert adapter(eng) == 12  # grow clamped to max_budget
    eng.prefill_chunk_budget = 12
    eng.recent_ttfts = eng.recent_ttfts + [0.001]
    assert adapter(eng) == 8  # shrink clamped to max(buckets)
    eng.prefill_chunk_budget = 8
    eng.recent_ttfts = eng.recent_ttfts + [0.001]
    assert adapter(eng) is None  # already at the floor


def test_slo_hook_errors_do_not_break_serving(setup):
    model, cfg = setup

    def bad_hook(engine):
        raise RuntimeError("operator bug")

    eng = ContinuousEngine(model, cfg, batch=1, max_len=16,
                           max_prompt_len=8, prefill_budget_hook=bad_hook)
    uid = eng.submit(_prompts([4], cfg.vocab)[0], max_new_tokens=2)
    comps = eng.run()
    assert [c.uid for c in comps] == [uid]
    assert len(eng.hook_errors) > 0


def test_slo_adapter_drives_live_engine(setup):
    """End-to-end: an impossible SLO grows the live engine's budget."""
    model, cfg = setup
    adapter = SloBudgetAdapter(1e-9, window=1)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=16,
                           max_prompt_len=8, prefill_chunk_budget=8,
                           prefill_budget_hook=adapter)
    start = eng.prefill_chunk_budget
    for p in _prompts([4, 4, 4, 4], cfg.vocab):
        eng.submit(p, max_new_tokens=2)
    eng.run()
    assert adapter.adaptations >= 1
    assert eng.prefill_chunk_budget > start
    assert not eng.hook_errors
