"""Optional-hypothesis shim.

The tier-1 container does not ship ``hypothesis``; property-based tests must
SKIP there, not kill collection.  Test modules import the decorators from
here instead of from hypothesis directly::

    from _hyp import given, settings, st

With hypothesis installed these are the real objects; without it ``@given``
becomes a skip marker and ``st``/``settings`` become inert placeholders, so
the non-property tests in the same module still collect and run.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the bare container
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (strategies are only built at decoration
        time and never run, since the test is skipped)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
