"""Flash prefill-chunk Pallas kernel: parity + serving identity.

Mirrors ``tests/test_paged_attention.py`` for the prefill side of the
kernel matrix (the ``kernels-interpret`` CI job runs both with
``REPRO_PALLAS_INTERPRET=1``):

* kernel vs oracle — :func:`repro.kernels.chunk_attention` must match
  the pure-jnp :func:`repro.kernels.ref.chunk_attention_ref` across
  chunk-boundary, sub-chunk-prompt, mid-block prefix-hit-resume, and
  GQA/MQA cases.  Property-based via the ``tests/_hyp`` shim.
* layer three-way — ``Attention.prefill_chunk`` with
  ``prefill_kernel="pallas"`` matches its own reference gather on the
  valid rows (padding rows carry no contract but must stay finite) and
  writes bit-identical K/V, on BOTH the paged and the dense layout.
* serving identity — greedy tokens through ``ContinuousEngine`` with
  ``prefill_kernel="pallas"`` are bit-identical to the reference path
  on a seeded shared-prefix trace (prefix-cache hits resume mid-block),
  on both layouts.
* structured refusal — ring/ssm/hybrid cache kinds refuse the kernel
  the same way the decode-kernel guard does (``UnsupportedCacheError``
  with a roadmap pointer at the engine, ``NotImplementedError`` at the
  ring layer).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.kernels import (chunk_attention, chunk_attention_dense,
                           chunk_attention_ref)
from repro.models import build_model
from repro.nn.attention import Attention, KVCache, PagedKVCache
from repro.serve import ContinuousEngine, make_trace, replay
from repro.serve.engine import UnsupportedCacheError


# ---- case construction -------------------------------------------------------


def _make_case(seed, *, heads, kvh, hd, bs, n_table, w, offset, n_valid,
               extra_blocks=2, dtype=jnp.float32):
    """One slot mid-prefill: a resident prefix of ``offset`` tokens behind
    a random block table (sentinel tail past the reservation), plus a
    ``w``-wide chunk whose first ``n_valid`` rows are real."""
    rng = np.random.default_rng(seed)
    n_blocks = n_table + extra_blocks
    kq, kk, kv, kc, kw = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(kq, (w, heads, hd), dtype)
    k_pool = jax.random.normal(kk, (n_blocks, bs, kvh, hd), dtype)
    v_pool = jax.random.normal(kv, (n_blocks, bs, kvh, hd), dtype)
    k_chunk = jax.random.normal(kc, (w, kvh, hd), dtype)
    v_chunk = jax.random.normal(kw, (w, kvh, hd), dtype)
    need = -(-(offset + n_valid) // bs) if offset + n_valid else 0
    table = np.full((n_table,), n_blocks, np.int32)
    table[:need] = rng.permutation(n_blocks)[:need]
    return (q, k_pool, v_pool, jnp.asarray(table), k_chunk, v_chunk,
            jnp.int32(offset), jnp.int32(n_valid))


def _assert_parity(case, tol=1e-5):
    q, *_ = case
    n_valid = int(case[-1])
    y = chunk_attention(*case)
    yr = chunk_attention_ref(*case)
    assert y.shape == yr.shape == q.shape
    assert y.dtype == q.dtype
    # the contract covers the valid rows; padding rows are never read by
    # the engine but must not poison anything with NaN/inf
    np.testing.assert_allclose(np.asarray(y, np.float32)[:n_valid],
                               np.asarray(yr, np.float32)[:n_valid],
                               atol=tol, rtol=tol, err_msg="kernel vs ref")
    assert np.isfinite(np.asarray(y)).all()


# ---- kernel vs oracle --------------------------------------------------------


@pytest.mark.parametrize("heads,kvh", [(4, 4), (4, 2), (4, 1), (1, 1)])
def test_parity_head_ratios(heads, kvh):
    """MHA, GQA, and MQA through the same kernel."""
    _assert_parity(_make_case(7, heads=heads, kvh=kvh, hd=16, bs=4,
                              n_table=5, w=8, offset=6, n_valid=8))


def test_parity_chunk_boundary():
    """offset a multiple of block_size AND of the chunk width — the
    admission pipeline's steady state."""
    _assert_parity(_make_case(11, heads=4, kvh=2, hd=8, bs=4, n_table=6,
                              w=4, offset=8, n_valid=4))


def test_parity_first_chunk():
    """offset == 0: no resident prefix, purely in-chunk causal."""
    _assert_parity(_make_case(13, heads=4, kvh=2, hd=8, bs=4, n_table=4,
                              w=8, offset=0, n_valid=8))


def test_parity_sub_chunk_prompt():
    """n_valid < W: a short prompt right-padded into the bucket; the
    padded rows must not perturb the valid ones."""
    _assert_parity(_make_case(17, heads=4, kvh=2, hd=8, bs=4, n_table=4,
                              w=8, offset=0, n_valid=3))


def test_parity_prefix_hit_resume_mid_block():
    """offset NOT a multiple of block_size — exactly where prefix-aware
    admission resumes after a cached-prefix hit (the final shared block
    is recomputed from its last token)."""
    _assert_parity(_make_case(19, heads=4, kvh=2, hd=8, bs=4, n_table=6,
                              w=8, offset=7, n_valid=8))


def test_parity_single_valid_row_and_block_size_one():
    _assert_parity(_make_case(23, heads=2, kvh=1, hd=8, bs=1, n_table=8,
                              w=4, offset=5, n_valid=1))


def test_parity_bf16_pool():
    _assert_parity(_make_case(3, heads=4, kvh=2, hd=16, bs=4, n_table=4,
                              w=8, offset=6, n_valid=8,
                              dtype=jnp.bfloat16), tol=2e-2)


def test_fully_padded_chunk_emits_finite():
    """n_valid == 0 (no real rows at all): every query row is fully
    masked — the guarded division must emit zeros, not NaN."""
    case = _make_case(29, heads=4, kvh=2, hd=8, bs=4, n_table=4, w=4,
                      offset=0, n_valid=0)
    y = np.asarray(chunk_attention(*case))
    assert np.isfinite(y).all() and (y == 0.0).all()


def test_sentinel_hole_in_prefix_is_masked():
    """A sentinel table entry *inside* the resident prefix (a buggy host
    table) is hard-masked by kernel and oracle alike."""
    q, kp, vp, table, kc, vc, off, nv = _make_case(
        31, heads=4, kvh=2, hd=8, bs=4, n_table=4, w=4, offset=12,
        n_valid=4)
    table = table.at[1].set(kp.shape[0])  # hole at positions 4..7
    case = (q, kp, vp, table, kc, vc, off, nv)
    _assert_parity(case)
    # and the hole genuinely changed the answer
    y_holed = chunk_attention(*case)
    y_full = chunk_attention(q, kp, vp, table.at[1].set(0), kc, vc, off, nv)
    assert not np.allclose(np.asarray(y_holed), np.asarray(y_full))


def test_dense_wrapper_matches_identity_table_oracle():
    """chunk_attention_dense pads the lane to a block multiple and serves
    it through an identity table; parity against the oracle on the same
    synthetic pool."""
    rng = jax.random.PRNGKey(5)
    kq, kl, kv2, kc, kw = jax.random.split(rng, 5)
    w, heads, kvh, hd, max_len, off = 6, 4, 2, 8, 21, 9
    q = jax.random.normal(kq, (w, heads, hd))
    k_lane = jax.random.normal(kl, (max_len, kvh, hd))
    v_lane = jax.random.normal(kv2, (max_len, kvh, hd))
    kc_ = jax.random.normal(kc, (w, kvh, hd))
    vc_ = jax.random.normal(kw, (w, kvh, hd))
    y = chunk_attention_dense(q, k_lane, v_lane, kc_, vc_,
                              jnp.int32(off), jnp.int32(w), block_size=8)
    bs = 8
    pad = -max_len % bs
    pool = jnp.pad(k_lane, ((0, pad), (0, 0), (0, 0)))
    poolv = jnp.pad(v_lane, ((0, pad), (0, 0), (0, 0)))
    n_table = (max_len + pad) // bs
    table = jnp.arange(n_table, dtype=jnp.int32)
    yr = chunk_attention_ref(q, pool.reshape(n_table, bs, kvh, hd),
                             poolv.reshape(n_table, bs, kvh, hd), table,
                             kc_, vc_, jnp.int32(off), jnp.int32(w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-5,
                               rtol=1e-5)


def test_kernel_validates_shapes():
    q, kp, vp, table, kc, vc, off, nv = _make_case(
        1, heads=4, kvh=2, hd=8, bs=4, n_table=3, w=4, offset=4, n_valid=4)
    with pytest.raises(ValueError, match="kv_heads"):
        chunk_attention(q[:, :3], kp, vp, table, kc, vc, off, nv)
    with pytest.raises(ValueError, match="mismatch"):
        chunk_attention(q, kp, vp[:, :, :, :4], table, kc, vc, off, nv)
    with pytest.raises(ValueError, match="chunk"):
        chunk_attention(q, kp, vp, table, kc[:2], vc, off, nv)


@given(seed=st.integers(0, 2**32 - 1))
@settings(max_examples=15, deadline=None)
def test_parity_random_cases(seed):
    """Property: random chunk widths, offsets (block-aligned and not),
    ragged n_valid, head ratios, block sizes, sentinel tails."""
    rng = np.random.default_rng(seed)
    heads, kvh = [(1, 1), (2, 1), (4, 2), (4, 4), (6, 3)][
        int(rng.integers(0, 5))]
    bs = int(rng.integers(1, 9))
    n_table = int(rng.integers(1, 7))
    w = int(rng.integers(1, 9))
    cap = n_table * bs
    offset = int(rng.integers(0, max(cap - w, 0) + 1))
    n_valid = int(rng.integers(0, min(w, cap - offset) + 1))
    _assert_parity(_make_case(
        int(rng.integers(0, 2**31)), heads=heads, kvh=kvh,
        hd=int(rng.choice([4, 8, 16])), bs=bs, n_table=n_table, w=w,
        offset=offset, n_valid=n_valid,
        extra_blocks=int(rng.integers(0, 4))))


# ---- layer-level three-way: Attention.prefill_chunk --------------------------

DIM, HEADS, KVH, HD = 32, 4, 2, 8


def _layer():
    return Attention.create(jax.random.PRNGKey(7), DIM, HEADS, KVH,
                            head_dim=HD, dtype=jnp.float32)


def _paged_cache(batch, n_blocks, bs, n_table):
    return PagedKVCache(
        k=jnp.zeros((n_blocks, bs, KVH, HD)),
        v=jnp.zeros((n_blocks, bs, KVH, HD)),
        table=jnp.full((batch, n_table), n_blocks, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32))


def _dst(table_row, off, w, n_valid, bs, n_blocks):
    """Engine-style pool rows for the chunk: real rows through the block
    table, padding rows at the out-of-range sentinel (dropped)."""
    j = np.arange(w)
    p = off + j
    rows = np.asarray(table_row)[p // bs] * bs + p % bs
    return jnp.asarray(np.where(j < n_valid, rows, n_blocks * bs))


def _scan_paged(attn, cache, x, slot, chunk, kernel):
    """Feed x (1, plen, dim) through paged prefill_chunk in chunk-sized
    spans (engine-style: blocks allocated up front here)."""
    plen, bs = x.shape[1], cache.k.shape[1]
    outs = []
    for off in range(0, plen, chunk):
        n = min(chunk, plen - off)
        span = x[:, off:off + chunk]
        if span.shape[1] < chunk:
            span = jnp.pad(span, ((0, 0), (0, chunk - span.shape[1]),
                                  (0, 0)))
        out, cache = attn.prefill_chunk(
            span, cache, slot=jnp.int32(slot), offset=jnp.int32(off),
            n_valid=jnp.int32(n),
            dst=_dst(cache.table[slot], off, chunk, n, bs,
                     cache.k.shape[0]),
            prefill_kernel=kernel)
        outs.append(out[:, :n])
    return jnp.concatenate(outs, axis=1), cache


def test_layer_paged_pallas_matches_reference_multichunk():
    """Three-way at the layer: the pallas path of Attention.prefill_chunk
    equals its own reference gather on every valid row across a chunked
    scan, and the written K/V pool is bit-identical (writes are
    kernel-independent by construction)."""
    attn = _layer()
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 11, DIM))
    bs, n_table = 4, 4
    results = {}
    for kernel in ("reference", "pallas"):
        cache = _paged_cache(2, 9, bs, n_table)
        cache = cache._replace(
            table=cache.table.at[1, :n_table].set(
                jnp.asarray([5, 2, 7, 0], jnp.int32)))
        results[kernel] = _scan_paged(attn, cache, x, slot=1, chunk=4,
                                      kernel=kernel)
    out_r, cache_r = results["reference"]
    out_p, cache_p = results["pallas"]
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(cache_p.k),
                                  np.asarray(cache_r.k))
    np.testing.assert_array_equal(np.asarray(cache_p.length),
                                  np.asarray(cache_r.length))


def test_layer_dense_pallas_matches_reference():
    """Same three-way on the dense per-slot layout (no block table: the
    kernel sees the lane through an identity table)."""
    attn = _layer()
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 8, DIM))
    results = {}
    for kernel in ("reference", "pallas"):
        cache = KVCache.zeros(2, 21, KVH, HD, dtype=jnp.float32,
                              per_slot=True)
        out1, cache = attn.prefill_chunk(
            x[:, :4], cache, slot=jnp.int32(0), offset=jnp.int32(0),
            n_valid=jnp.int32(4), prefill_kernel=kernel)
        out2, cache = attn.prefill_chunk(
            x[:, 4:], cache, slot=jnp.int32(0), offset=jnp.int32(4),
            n_valid=jnp.int32(3), prefill_kernel=kernel)  # ragged tail
        results[kernel] = (jnp.concatenate([out1, out2[:, :3]], 1), cache)
    out_r, cache_r = results["reference"]
    out_p, cache_p = results["pallas"]
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(cache_p.k),
                                  np.asarray(cache_r.k))


def test_layer_validates_kernel_name():
    attn = _layer()
    cache = KVCache.zeros(1, 8, KVH, HD, dtype=jnp.float32, per_slot=True)
    with pytest.raises(ValueError, match="prefill_kernel"):
        attn.prefill_chunk(jnp.zeros((1, 4, DIM)), cache,
                           slot=jnp.int32(0), offset=jnp.int32(0),
                           n_valid=jnp.int32(4), prefill_kernel="cuda")


def test_ring_layer_refuses_pallas():
    """Ring lanes wrap around — no position-addressable prefix, so the
    layer refuses the kernel outright instead of silently falling back."""
    attn = Attention.create(jax.random.PRNGKey(7), DIM, HEADS, KVH,
                            head_dim=HD, window=4, dtype=jnp.float32)
    cache = KVCache.zeros(1, 4, KVH, HD, dtype=jnp.float32, per_slot=True)
    with pytest.raises(NotImplementedError, match="ring"):
        attn.prefill_chunk(jnp.zeros((1, 4, DIM)), cache,
                           slot=jnp.int32(0), offset=jnp.int32(0),
                           n_valid=jnp.int32(4), prefill_kernel="pallas")


# ---- serving identity through ContinuousEngine -------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()  # GQA: 4 heads over 2 KV heads
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


@pytest.mark.parametrize("kv_layout", ["paged", "dense"])
def test_engine_pallas_prefill_bit_identical(setup, kv_layout):
    """Acceptance gate: greedy tokens with prefill_kernel='pallas' are
    bit-identical to the reference path on a seeded shared-prefix trace
    (chunked admission, prefix-cache hits resuming mid-block on the
    paged layout), on BOTH kv layouts."""
    model, cfg = setup
    trace = make_trace(8, seed=13, load=0.7, min_prompt=2, max_prompt=8,
                       min_new=2, max_new=8, vocab=cfg.vocab,
                       shared_prefix=6)
    outs = {}
    for pk in ("reference", "pallas"):
        eng = ContinuousEngine(model, cfg, batch=3, max_len=32,
                               max_prompt_len=16, kv_layout=kv_layout,
                               block_size=4, chunk_size=4,
                               prefill_chunk_budget=4, prefill_kernel=pk)
        outs[pk], _ = replay(eng, trace)
        assert eng.prefill_stats()["prefill_kernel"] == pk
        if kv_layout == "paged":
            assert eng.kv_stats()["prefill_kernel"] == pk
    assert len(outs["pallas"]) == len(trace)
    for cr, cp in zip(outs["reference"], outs["pallas"]):
        assert cr.tokens == cp.tokens, \
            f"pallas prefill diverged for uid={cr.uid} plen={cr.prompt_len}"
        assert (cr.uid, cr.prompt_len, cr.finish_reason) == \
            (cp.uid, cp.prompt_len, cp.finish_reason)


def test_engine_prefill_kernel_validation(setup):
    """Unknown names rejected; ring (hymba-style window) and ssm cache
    kinds refuse with the structured error + roadmap pointer, mirroring
    the decode-kernel guard."""
    model, cfg = setup
    with pytest.raises(ValueError, match="prefill_kernel"):
        ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8,
                         prefill_kernel="cuda")
    ring_cfg = cfg.replace(window=8)
    ring_model = build_model(jax.random.PRNGKey(0), ring_cfg)
    with pytest.raises(UnsupportedCacheError) as ei:
        ContinuousEngine(ring_model, ring_cfg, batch=2, max_len=32,
                         max_prompt_len=8, chunk_size=8,
                         prefill_kernel="pallas")
    assert ei.value.roadmap_item
    mb_cfg = get_config("mamba2-2.7b").reduced()
    mb_model = build_model(jax.random.PRNGKey(0), mb_cfg)
    with pytest.raises(UnsupportedCacheError, match="kv"):
        ContinuousEngine(mb_model, mb_cfg, batch=2, max_len=32,
                         max_prompt_len=8, prefill_kernel="pallas")
