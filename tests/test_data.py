"""Synthetic data pipeline: determinism, structure, learnability targets."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (classification_batch, icl_batch,
                        markov_entropy_floor, markov_lm_batch)


def test_markov_batch_deterministic():
    a = markov_lm_batch(3, batch=4, seq=16, vocab=64, seed=1)
    b = markov_lm_batch(3, batch=4, seq=16, vocab=64, seed=1)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    c = markov_lm_batch(4, batch=4, seq=16, vocab=64, seed=1)
    assert not np.array_equal(np.asarray(a.tokens), np.asarray(c.tokens))


def test_markov_labels_are_shifted_tokens():
    b = markov_lm_batch(0, batch=2, seq=8, vocab=32, seed=0)
    np.testing.assert_array_equal(np.asarray(b.tokens[:, 1:]),
                                  np.asarray(b.labels[:, :-1]))


def test_markov_entropy_floor_sane():
    h = markov_entropy_floor(0, 64)
    assert 0.0 < h < np.log(64)


def test_classification_label_rule():
    b = classification_batch(0, batch=8, seq=32, vocab=100, n_classes=4,
                             seed=2)
    probes = np.array([1, 32 // 3, 16, 30])
    expected = np.asarray(b.tokens)[:, probes].sum(-1) % 4
    np.testing.assert_array_equal(np.asarray(b.label), expected)


def test_icl_answer_embedded_in_stream():
    b = icl_batch(1, batch=16, n_pairs=4, vocab=64, seed=3)
    toks = np.asarray(b.tokens)
    ans = np.asarray(b.answer)
    qpos = np.asarray(b.query_pos)
    labels = np.asarray(b.labels)
    # the label at the query position is the answer
    for i in range(16):
        assert labels[i, qpos[i]] == ans[i]
        # the query key appeared earlier in the stream
        qkey = toks[i, qpos[i]]
        assert qkey in toks[i, :qpos[i]]
        # the paired value follows that earlier occurrence
        j = list(toks[i, :qpos[i]]).index(qkey)
        assert toks[i, j + 1] == ans[i] or qkey in toks[i, :qpos[i]][j + 1:]


def test_icl_keys_values_disjoint_ranges():
    b = icl_batch(0, batch=8, n_pairs=4, vocab=64, seed=4)
    toks = np.asarray(b.tokens)
    keys = toks[:, 0::2][:, :4]
    vals = toks[:, 1::2][:, :4]
    assert keys.max() < 32 and vals.min() >= 32
