import jax
import pytest
from hypothesis import settings

# CPU-only container: keep hypothesis fast and quiet.
settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
