import jax
import pytest

try:
    # CPU-only container: keep hypothesis fast and quiet when present.
    from hypothesis import settings

    settings.register_profile("ci", max_examples=15, deadline=None)
    settings.load_profile("ci")
except ImportError:
    # Tier-1 runs without hypothesis; property tests skip via tests/_hyp.py.
    pass

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
