"""Sharded serving: a ContinuousEngine on a {data, model} mesh must emit
BIT-IDENTICAL tokens to the single-device engine (and to one-shot
``generate``) on seeded traces — across dp-only / tp-only / dp x tp,
paged and dense layouts, chunked prefill with prefix reuse,
mid-prefill cancellation, and speculative decoding.

The differential matrix runs in subprocesses with 8 forced host devices
(the XLA device count is locked at first jax init, so it cannot be set
in this process); the runtime-config surface tests run in-process.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.dist.runtime import (HOST_DEVICES_RECIPE, RuntimeConfig,
                                make_serve_mesh, parse_mesh_spec)

# -- runtime config surface (no devices needed) ------------------------------


def test_parse_mesh_spec():
    assert parse_mesh_spec(None) is None
    assert parse_mesh_spec("") is None
    assert parse_mesh_spec("  ") is None
    assert parse_mesh_spec("2,2") == (2, 2)
    assert parse_mesh_spec(" 4 , 1 ") == (4, 1)
    assert parse_mesh_spec("4") == (4, 1)  # bare dp shorthand


@pytest.mark.parametrize("bad", ["0,2", "2,0", "-1,2", "a,b", "2,2,2", ","])
def test_parse_mesh_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_mesh_spec(bad)


def test_make_serve_mesh_empty_spec_is_single_device():
    assert make_serve_mesh("") is None


def test_make_serve_mesh_too_many_devices_names_the_recipe():
    # this process sees however many devices the environment exposes;
    # 64x64 exceeds any host, and the error must teach the CPU recipe
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_serve_mesh("64,64")


def test_runtime_config_env_seeding(monkeypatch):
    monkeypatch.setenv("REPRO_MESH", "2,4")
    monkeypatch.setenv("REPRO_SEQ_PARALLEL", "1")
    rc = RuntimeConfig()
    assert rc.mesh_spec == "2,4"
    assert rc.seq_parallel is True
    assert rc.fsdp_params is False
    assert set(rc.describe()) == {"mesh_spec", "seq_parallel", "fsdp_params"}
    assert "host_platform_device_count" in HOST_DEVICES_RECIPE


# -- the sharded differential matrix (subprocess, 8 host devices) ------------

_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import ContinuousEngine, bench_trace, make_trace
    from repro.serve.engine import generate
    from repro.dist import make_serve_mesh

    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    trace = make_trace(8, seed=0, load=0.5, min_prompt=4, max_prompt=12,
                       min_new=2, max_new=8, vocab=cfg.vocab,
                       shared_prefix=4)
    DIMS = dict(batch=4, max_len=48, max_prompt_len=16)
"""

MATRIX_SCRIPT = textwrap.dedent(_PRELUDE + """
    from jax.sharding import PartitionSpec as P
    from repro.nn.attention import UnsupportedCacheError

    # single-device references, both layouts
    ref = {}
    for layout in ("paged", "dense"):
        kw = dict(DIMS, kv_layout=layout)
        if layout == "paged":
            kw["block_size"] = 8
        rows, _ = bench_trace(model, cfg, trace, **kw)
        ref[layout] = {r.uid: tuple(r.tokens) for r in rows}

    # ... and one-shot generate agrees with the engine on each request
    for _t, req in trace[:3]:
        toks = jnp.asarray([req.prompt], jnp.int32)
        cache = model.init_cache(1, DIMS["max_len"], cfg)
        out, _ = generate(model, toks, cache, n_steps=req.max_new_tokens)
        want = list(ref["paged"][req.uid])
        got = [int(t) for t in np.asarray(out[0])][: len(want)]
        assert got == want, (req.uid, got, want)

    # mesh engines: dp-only, tp-only, dp x tp — bit-identical on both
    # layouts, with the intended NamedSharding on params / pool / state
    for spec in ("2,1", "1,2", "2,2"):
        mesh = make_serve_mesh(spec)
        dp, tp = mesh.shape["data"], mesh.shape["model"]
        for layout in ("paged", "dense"):
            kw = dict(DIMS, kv_layout=layout, mesh=mesh)
            if layout == "paged":
                kw["block_size"] = 8
            rows, _ = bench_trace(model, cfg, trace, **kw)
            got = {r.uid: tuple(r.tokens) for r in rows}
            assert got == ref[layout], (spec, layout)

        eng = ContinuousEngine(model, cfg, kv_layout="paged", block_size=8,
                               mesh=mesh, **DIMS)
        if tp > 1:
            assert eng.model.blocks.attn.q_proj.weight.sharding.spec \\
                == P(None, None, "model")
            assert eng.cache.k.sharding.spec \\
                == P(None, None, None, "model", None)
        if dp > 1:
            assert eng.cache.table.sharding.spec == P("data", None)
            assert eng.cache.length.sharding.spec == P(None, "data")
            assert eng.state.tok.sharding.spec == P("data")

    # pallas kernels are single-shard: refuse with the structured error
    mesh = make_serve_mesh("1,2")
    for knob in ("decode_kernel", "prefill_kernel"):
        try:
            ContinuousEngine(model, cfg, kv_layout="paged", block_size=8,
                             mesh=mesh, **{knob: "pallas"}, **DIMS)
            raise SystemExit(f"pallas {knob} accepted under tp=2")
        except UnsupportedCacheError as e:
            assert e.roadmap_item and "Pallas" in e.roadmap_item

    # ... but tp=1 meshes (pure data parallelism) may keep the kernels
    ContinuousEngine(model, cfg, kv_layout="paged", block_size=8,
                     mesh=make_serve_mesh("2,1"), decode_kernel="pallas",
                     **DIMS)
    print("SHARDED_MATRIX_OK")
""")

CANCEL_SPEC_SCRIPT = textwrap.dedent(_PRELUDE + """
    from repro.core import auto_fact, spectral_decay

    mesh = make_serve_mesh("2,2")

    # cancellation mid-prefill leaks nothing under a mesh
    eng = ContinuousEngine(model, cfg, batch=2, max_len=64,
                           max_prompt_len=33, kv_layout="paged",
                           block_size=8, chunk_size=8, mesh=mesh)
    uid = eng.submit(list(range(1, 30)), max_new_tokens=4)
    keep = eng.submit([5, 6, 7, 8], max_new_tokens=4)
    eng.step()  # admits both; the long prompt is mid-prefill
    assert uid in [t.req.uid for t in eng._prefills.values()]
    eng.cancel(uid)
    done = eng.step()
    assert any(c.uid == uid and c.finish_reason == "cancelled"
               for c in done)
    out = list(done)
    for _ in range(20):
        out += eng.step()
        if eng.scheduler.idle:
            break
    assert any(c.uid == keep and c.finish_reason != "cancelled"
               for c in out)  # the survivor still completes
    assert eng.scheduler.idle and eng.manager.fully_free

    # speculative decoding: draft + verifier on the same mesh, greedy
    # agreement stays 1.0 vs the unsharded spec engine AND the plain
    # (non-speculative) unsharded engine
    smodel = spectral_decay(build_model(jax.random.PRNGKey(0), cfg), 2.5,
                            exclude=["embed", "lm_head"])
    draft = auto_fact(smodel, 0.25, solver="svd",
                      key=jax.random.PRNGKey(1),
                      exclude=["embed", "lm_head"], gate=False)
    kw = dict(DIMS, kv_layout="paged", block_size=8)
    plain, _ = bench_trace(smodel, cfg, trace, **kw)
    spec, sstats = bench_trace(smodel, cfg, trace, draft_model=draft,
                               spec_k=3, **kw)
    mspec, mstats = bench_trace(smodel, cfg, trace, draft_model=draft,
                                spec_k=3, mesh=mesh, **kw)
    t = lambda rows: {r.uid: tuple(r.tokens) for r in rows}
    assert t(mspec) == t(spec) == t(plain)
    assert mstats["spec_acceptance_rate"] == sstats["spec_acceptance_rate"]
    assert mstats["spec_drafted_tokens"] > 0
    print("SHARDED_CANCEL_SPEC_OK")
""")


def _run(script: str) -> str:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


@pytest.mark.slow
def test_sharded_engine_matches_single_device_and_generate():
    assert "SHARDED_MATRIX_OK" in _run(MATRIX_SCRIPT)


@pytest.mark.slow
def test_sharded_cancellation_and_spec_decode():
    assert "SHARDED_CANCEL_SPEC_OK" in _run(CANCEL_SPEC_SCRIPT)
