"""Low-rank gradient compression (PowerSGD-style) invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gradcomp import (compress_and_reduce, compression_ratio,
                                 init_compressor)


def _grads(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"g{i}": jax.random.normal(k, s) for i, (k, s) in
            enumerate(zip(ks, shapes))}


def test_full_rank_compression_is_nearly_exact():
    g = _grads(jax.random.PRNGKey(0), [(16, 12)])
    st = init_compressor(g, rank=12, key=jax.random.PRNGKey(1))
    out, st = compress_and_reduce(g, st)
    np.testing.assert_allclose(np.asarray(out["g0"]), np.asarray(g["g0"]),
                               atol=1e-3)


def test_error_feedback_accumulates():
    """Error feedback: the RUNNING MEAN of compressed outputs converges to
    the true gradient even at tiny rank (Σ out_t = T·g + e_0 − e_T)."""
    g = _grads(jax.random.PRNGKey(2), [(32, 24)])
    st = init_compressor(g, rank=2, key=jax.random.PRNGKey(3))
    total = jnp.zeros_like(g["g0"])
    errs = []
    for t in range(1, 13):
        out, st = compress_and_reduce(g, st)
        total = total + out["g0"]
        errs.append(float(jnp.linalg.norm(total / t - g["g0"])))
    assert errs[-1] < 0.6 * errs[0]


def test_vectors_pass_through_exactly():
    g = {"mat": jnp.ones((8, 8)), "vec": jnp.arange(5.0),
         "scalar": jnp.array(2.0)}
    st = init_compressor(g, rank=2, key=jax.random.PRNGKey(4))
    out, _ = compress_and_reduce(g, st)
    np.testing.assert_allclose(np.asarray(out["vec"]), np.arange(5.0))
    assert float(out["scalar"]) == 2.0
    assert "mat" not in [None]  # mat went through the low-rank path
    assert out["mat"].shape == (8, 8)


def test_compression_ratio_formula():
    g = {"m": jnp.zeros((100, 50)), "v": jnp.zeros((30,))}
    ratio = compression_ratio(g, rank=4)
    expected = (4 * 150 + 30) / (5000 + 30)
    assert abs(ratio - expected) < 1e-9


def test_stacked_matrices_fold_rows():
    g = {"w": jnp.ones((3, 8, 6))}  # layer-stacked
    st = init_compressor(g, rank=6, key=jax.random.PRNGKey(5))
    assert st.q["{'w'}" if False else list(st.q)[0]].shape == (6, 6)
    out, _ = compress_and_reduce(g, st)
    assert out["w"].shape == (3, 8, 6)


def test_psum_reduction_in_shard_map():
    """Compression reduces across the mapped axis like a mean all-reduce."""
    mesh = jax.make_mesh((1,), ("dp",))
    g = _grads(jax.random.PRNGKey(6), [(16, 8)])
    st = init_compressor(g, rank=8, key=jax.random.PRNGKey(7))

    from jax.sharding import PartitionSpec as P

    def f(g, st):
        out, st2 = compress_and_reduce(g, st, axis_name="dp")
        return out

    from _compat import shard_map

    out = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P())(g, st)
    np.testing.assert_allclose(np.asarray(out["g0"]), np.asarray(g["g0"]),
                               atol=1e-3)
