"""HTTP front door + engine cancellation: differential matrix.

Two layers of coverage:

* **Engine-level cancellation** — ``ContinuousEngine.cancel`` in every
  live state (pending / mid-prefill / mid-decode), with the invariants
  the paged layout must keep: allocator refcounts return to baseline (no
  block leak), surviving requests' tokens stay bit-identical to an
  uncancelled replay, a cancelled provider's registered-but-unwritten
  prefix blocks rewind their dependents instead of deadlocking them, and
  a cancel landing on the request's final step is classified
  ``cancelled``, never ``length``.
* **HTTP-level** — the asyncio server end to end over real sockets:
  SSE tokens bit-identical to the offline baseline, 429 backpressure
  from the bounded admission queue, client-disconnect and deadline
  cancellation propagating into the engine, and the Prometheus
  ``/metrics`` + ``/healthz`` endpoints.
"""

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import BackgroundServer, ContinuousEngine, generate
from repro.launch.loadgen import (fetch, run_closed_loop, run_open_loop,
                                  sse_generate, summarize)

DIMS = dict(batch=4, max_len=64, max_prompt_len=32, block_size=8,
            chunk_size=8, prefill_chunk_budget=8)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


def _engine(model, cfg, **over):
    return ContinuousEngine(model, cfg, **{**DIMS, **over})


def _baseline(model, cfg, prompt, n, max_len=64):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return [int(t) for t in np.asarray(out)[0]]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


def _assert_pool_clean(eng):
    """No live references, no leaked refcounts, tables all sentinel."""
    a = eng.manager.allocator
    assert eng.manager.fully_free
    assert a.n_in_use == 0
    # every refcount zero (parked LRU blocks are refcount 0 by definition)
    assert int(a.refcount.sum()) == 0
    assert (eng.manager.tables == eng.manager.sentinel).all()


# ---- engine-level cancellation matrix ---------------------------------------


def test_cancel_pending_request(setup):
    model, cfg = setup
    eng = _engine(model, cfg, batch=1)
    prompts = _prompts([6, 6, 6], cfg.vocab)
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.step()  # uid0 admitted; uid1/uid2 still pending
    assert eng.scheduler.find(uids[1])[0] == "pending"
    assert eng.cancel(uids[1])
    done = eng.run(max_steps=200)
    reasons = {c.uid: c.finish_reason for c in done}
    assert reasons[uids[1]] == "cancelled"
    assert next(c for c in done if c.uid == uids[1]).tokens == []
    # the cancelled request never occupied a slot; the others finished
    assert reasons[uids[0]] == reasons[uids[2]] == "length"
    _assert_pool_clean(eng)


def test_cancel_mid_prefill_releases_blocks(setup):
    model, cfg = setup
    eng = _engine(model, cfg)
    # 24-token prompt at chunk 8 / budget 8 => 3 steps of prefill
    prompts = _prompts([24, 8, 8], cfg.vocab, seed=1)
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.step()
    assert eng.scheduler.find(uids[0])[0] == "prefilling"
    in_use_before = eng.manager.allocator.n_in_use
    assert in_use_before > 0
    eng.cancel(uids[0])
    done = eng.run(max_steps=200)
    reasons = {c.uid: c.finish_reason for c in done}
    assert reasons[uids[0]] == "cancelled"
    assert next(c for c in done if c.uid == uids[0]).tokens == []
    # survivors bit-identical to the offline baseline
    for uid, p in zip(uids[1:], prompts[1:]):
        comp = next(c for c in done if c.uid == uid)
        assert comp.tokens == _baseline(model, cfg, p, len(comp.tokens))
    _assert_pool_clean(eng)


def test_cancel_mid_decode_survivors_bit_identical(setup):
    model, cfg = setup
    prompts = _prompts([8, 10, 6], cfg.vocab, seed=2)

    ref_eng = _engine(model, cfg)
    ref_uids = [ref_eng.submit(p, max_new_tokens=10) for p in prompts]
    ref_by_uid = {c.uid: c for c in ref_eng.run(max_steps=200)}
    ref = [ref_by_uid[u] for u in ref_uids]  # submission order

    eng = _engine(model, cfg)
    uids = [eng.submit(p, max_new_tokens=10) for p in prompts]
    for _ in range(4):  # past prefill, a few decode steps in
        eng.step()
    assert eng.scheduler.find(uids[1])[0] == "running"
    eng.cancel(uids[1])
    done_by_uid = {c.uid: c for c in eng.run(max_steps=200)}
    done = [done_by_uid[u] for u in uids]

    assert done[1].finish_reason == "cancelled"
    # the cancelled request's tokens are a prefix of its uncancelled run
    n = len(done[1].tokens)
    assert 0 < n < len(ref[1].tokens)
    assert done[1].tokens == ref[1].tokens[:n]
    # survivors are untouched by the neighbour's cancellation
    for i in (0, 2):
        assert done[i].tokens == ref[i].tokens
        assert done[i].finish_reason == ref[i].finish_reason
    _assert_pool_clean(eng)


def test_cancelled_provider_rewinds_prefix_dependent(setup):
    """Cancel a prefill whose registered prefix blocks a dependent
    already hit: the dependent must rewind, recompute the orphaned span
    itself, and still produce baseline-identical tokens — not deadlock
    in blocks_ready."""
    model, cfg = setup
    # 4-token blocks/chunks: the 16-token prefix spans 4 blocks and A
    # publishes only 2 of them before the cancel lands
    eng = _engine(model, cfg, block_size=4, chunk_size=4,
                  prefill_chunk_budget=4)
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    pa = np.concatenate([prefix, rng.integers(0, cfg.vocab, 8)]).astype(
        np.int32)
    pb = np.concatenate([prefix, rng.integers(0, cfg.vocab, 4)]).astype(
        np.int32)
    ua = eng.submit(pa, max_new_tokens=4)
    eng.step()   # A admitted, first chunk in (blocks registered, pending)
    ub = eng.submit(pb, max_new_tokens=4)
    eng.step()   # B admitted: forks A's prefix blocks, waits on publish
    task_b = eng._prefills[eng.scheduler.find(ub)[1]]
    assert task_b.cached == 16 and len(task_b.hit_bids) == 4  # full chain
    assert task_b.chunks == 0  # gated by blocks_ready
    eng.cancel(ua)
    done = {c.uid: c for c in eng.run(max_steps=200)}
    assert done[ua].finish_reason == "cancelled"
    # B was rewound below its original hit boundary...
    assert task_b.cached < 16
    # ...and still completed, bit-identical to the offline baseline
    assert done[ub].finish_reason != "cancelled"
    assert done[ub].tokens == _baseline(model, cfg, pb,
                                        len(done[ub].tokens))
    _assert_pool_clean(eng)


def test_cancel_on_final_step_reports_cancelled_not_length(setup):
    model, cfg = setup
    eng = _engine(model, cfg)
    [p] = _prompts([6], cfg.vocab, seed=3)
    uid = eng.submit(p, max_new_tokens=3)
    eng.step()  # bind + first token + one decode: 2 of 3 tokens in
    assert len(eng.scheduler.slots[eng.scheduler.find(uid)[1]].tokens) == 2
    eng.cancel(uid)
    [comp] = eng.step()  # cancel drains BEFORE the would-be final decode
    assert comp.uid == uid
    assert comp.finish_reason == "cancelled"
    assert len(comp.tokens) == 2  # the final token was never produced
    _assert_pool_clean(eng)


def test_cancel_unknown_and_finished_uid_is_noop(setup):
    model, cfg = setup
    eng = _engine(model, cfg)
    [p] = _prompts([6], cfg.vocab, seed=4)
    uid = eng.submit(p, max_new_tokens=2)
    done = eng.run(max_steps=200)
    assert len(done) == 1
    assert not eng.cancel(uid)     # already finished
    assert not eng.cancel(10**9)   # never existed
    assert eng.step() == []        # draining the stale cancels is a no-op
    _assert_pool_clean(eng)


def test_stream_yields_completion_only_events(setup):
    """A cancelled request emits no token on its final step; stream()
    must still surface its Completion (as token=None) instead of
    dropping it."""
    model, cfg = setup
    eng = _engine(model, cfg)
    prompts = _prompts([6, 6], cfg.vocab, seed=5)
    ua = eng.submit(prompts[0], max_new_tokens=8)
    ub = eng.submit(prompts[1], max_new_tokens=8)
    events, cancelled_once = [], []

    def on_step(e):
        if not cancelled_once and e.scheduler.find(ub)[0] == "running":
            e.cancel(ub)
            cancelled_once.append(True)

    comps = {}
    for uid, tok, comp in eng.stream(on_step=on_step):
        events.append((uid, tok))
        if comp is not None:
            comps[uid] = (tok, comp)
    assert set(comps) == {ua, ub}
    tok_b, comp_b = comps[ub]
    assert comp_b.finish_reason == "cancelled"
    assert tok_b is None  # completion-only event: no token that step
    tok_a, comp_a = comps[ua]
    assert tok_a is not None and comp_a.finish_reason == "length"


# ---- HTTP end-to-end --------------------------------------------------------


@pytest.fixture(scope="module")
def server(setup):
    model, cfg = setup
    eng = ContinuousEngine(model, cfg, **DIMS)
    with BackgroundServer(eng, max_pending=8) as bg:
        yield bg, eng, cfg


def _wait_drained(eng, timeout=20.0):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if eng.scheduler.idle and eng.manager.fully_free:
            return
        time.sleep(0.05)
    raise AssertionError("engine did not drain")


def test_http_sse_tokens_match_offline(setup, server):
    model, cfg = setup
    bg, eng, _ = server[0], server[1], server[2]
    prompts = _prompts([8, 12, 6], cfg.vocab, seed=6)
    payloads = [{"prompt": [int(x) for x in p], "max_new_tokens": 6}
                for p in prompts]
    results = asyncio.run(run_closed_loop(bg.host, bg.port, payloads,
                                          concurrency=3))
    for p, r in zip(prompts, results):
        assert r["status"] == 200
        assert r["finish_reason"] == "length"
        assert r["tokens"] == _baseline(model, cfg, p, len(r["tokens"]))
    summary = summarize(results, 1.0)
    assert summary["served"] == 3 and summary["errors"] == 0
    _wait_drained(eng)


def test_http_healthz_and_metrics(server):
    bg, eng = server[0], server[1]

    async def drive():
        s, body = await fetch(bg.host, bg.port, "/healthz")
        assert s == 200 and b'"status": "ok"' in body
        s, body = await fetch(bg.host, bg.port, "/metrics")
        assert s == 200
        return body.decode()

    text = asyncio.run(drive())
    for name in ("repro_serve_ttft_seconds{quantile=\"0.5\"}",
                 "repro_serve_ttft_seconds{quantile=\"0.95\"}",
                 "repro_serve_latency_seconds",
                 "repro_serve_prefix_hit_rate",
                 "repro_serve_kv_blocks_in_use",
                 "repro_serve_queue_pending",
                 "repro_serve_completions_total"):
        assert name in text, f"{name} missing from /metrics"


def test_http_client_disconnect_cancels(server):
    bg, eng = server[0], server[1]

    async def drive():
        rng = np.random.default_rng(8)
        payload = {"prompt": rng.integers(0, 64, 8).tolist(),
                   "max_new_tokens": 24}
        return await sse_generate(bg.host, bg.port, payload,
                                  cancel_after_tokens=1)

    r = asyncio.run(drive())
    assert r["status"] == 200 and r["cancelled_by_client"]
    assert len(r["tokens"]) == 1
    _wait_drained(eng)  # cancel propagated: no slot, no blocks held
    assert bg.server.metrics.cancelled["disconnect"] >= 1
    assert bg.server.metrics.completions.get("cancelled", 0) >= 1


def test_http_deadline_expiry_reports_cancelled(server):
    bg, eng = server[0], server[1]

    async def drive():
        rng = np.random.default_rng(9)
        payload = {"prompt": rng.integers(0, 64, 8).tolist(),
                   "max_new_tokens": 32, "timeout_s": 0.0}
        return await sse_generate(bg.host, bg.port, payload)

    r = asyncio.run(drive())
    assert r["status"] == 200
    assert r["finish_reason"] == "cancelled"
    assert len(r["tokens"]) < 32  # the budget never ran out; the clock did
    _wait_drained(eng)
    assert bg.server.metrics.cancelled["deadline"] >= 1


def test_http_backpressure_429(setup):
    """batch=1, max_pending=1: with one request running and one queued, a
    third POST is rejected 429 before touching the engine."""
    model, cfg = setup
    eng = ContinuousEngine(model, cfg, **{**DIMS, "batch": 1})
    with BackgroundServer(eng, max_pending=1) as bg:

        async def drive():
            rng = np.random.default_rng(10)

            def payload(max_new):
                return {"prompt": rng.integers(0, cfg.vocab, 8).tolist(),
                        "max_new_tokens": max_new}

            a = asyncio.ensure_future(
                sse_generate(bg.host, bg.port, payload(48)))
            # wait until A occupies the single slot
            while eng.scheduler.n_running + eng.scheduler.n_prefilling < 1:
                await asyncio.sleep(0.01)
            b = asyncio.ensure_future(
                sse_generate(bg.host, bg.port, payload(4)))
            while eng.scheduler.n_pending < 1:  # B parked in the queue
                await asyncio.sleep(0.01)
            c = await sse_generate(bg.host, bg.port, payload(4))
            ra, rb = await a, await b
            return ra, rb, c

        ra, rb, rc = asyncio.run(drive())
        assert ra["status"] == rb["status"] == 200
        assert rc["status"] == 429
        assert "queue full" in rc["error"]
        assert bg.server.metrics.rejected_backpressure >= 1
    _wait_drained(eng)


def test_http_open_loop_with_cancels_leaks_nothing(setup):
    """The CI shape in miniature: open-loop Poisson arrivals with a
    cancel fraction; afterwards the pool is clean and the summary
    accounts for every request."""
    model, cfg = setup
    eng = ContinuousEngine(model, cfg, **DIMS)
    with BackgroundServer(eng, max_pending=16) as bg:
        rng = np.random.default_rng(11)
        payloads = [{"prompt": rng.integers(0, cfg.vocab, int(n)).tolist(),
                     "max_new_tokens": 8}
                    for n in rng.integers(4, 16, 10)]
        t0 = time.monotonic()
        results = asyncio.run(run_open_loop(bg.host, bg.port, payloads,
                                            rate=50.0, cancel_frac=0.4,
                                            seed=3))
        summary = summarize(results, time.monotonic() - t0)
        assert summary["requests"] == 10
        assert summary["errors"] == 0
        assert summary["cancelled_by_client"] >= 1
        assert summary["served"] >= 1
        _wait_drained(eng)
        assert bg.server.metrics.completions.get("cancelled", 0) >= 1
    _assert_pool_clean(eng)


# ---- metrics reservoir split + priority passthrough -------------------------


def test_metrics_split_cancelled_from_served_latency():
    """``Metrics.observe`` used to append cancelled latencies into the
    same reservoir as served ones — a storm of instant cancels dragged
    the served p50/p95 toward zero.  The reservoirs are now split."""
    from repro.serve.http import ServeMetrics
    from repro.serve.scheduler import Completion

    m = ServeMetrics()
    for i in range(4):
        m.observe(Completion(uid=i, prompt_len=4, tokens=[1] * 8,
                             finish_reason="length", priority=0,
                             submitted_at=0.0, first_token_at=1.0,
                             finished_at=10.0))
    for i in range(4, 8):  # instant cancels, never produced a token
        m.observe(Completion(uid=i, prompt_len=4, tokens=[],
                             finish_reason="cancelled",
                             submitted_at=0.0, first_token_at=0.0,
                             finished_at=0.001))
    assert list(m.latency_s) == [10.0] * 4      # served reservoir clean
    assert list(m.cancelled_latency_s) == [0.001] * 4
    assert len(m.ttft_s) == 4                   # tokenless cancels skipped
    assert set(m.ttft_by_priority) == {0}
    assert m.completions == {"length": 4, "cancelled": 4}


def test_http_metrics_expose_priority_and_preemption_series(server):
    bg, eng = server[0], server[1]

    async def drive():
        rng = np.random.default_rng(12)
        payload = {"prompt": rng.integers(0, 64, 6).tolist(),
                   "max_new_tokens": 4, "priority": 0}
        r = await sse_generate(bg.host, bg.port, payload)
        assert r["status"] == 200 and r["finish_reason"] == "length"
        _, body = await fetch(bg.host, bg.port, "/metrics")
        return body.decode()

    text = asyncio.run(drive())
    for name in ('repro_serve_cancelled_latency_seconds{quantile="0.5"}',
                 'repro_serve_ttft_seconds{quantile="0.95",priority="0"}',
                 "repro_serve_preemptions_total",
                 "repro_serve_preempt_resumes_total",
                 "repro_serve_preempt_violations_total"):
        assert name in text, f"{name} missing from /metrics"
    from repro.launch.loadgen import metric_value
    assert metric_value(text, "repro_serve_preempt_violations_total") == 0.0
    _wait_drained(eng)


def test_http_priority_payload_reaches_scheduler(setup):
    """A body ``"priority"`` rides through the route into the engine: a
    class-0 POST overtakes an earlier-queued default-class request.
    (``preemption=False`` so admission order alone proves the plumbing —
    uids are issued in submission order, so the urgent request is the
    LARGEST uid yet must bind before the middle one.)"""
    model, cfg = setup
    eng = _engine(model, cfg, batch=1, preemption=False)
    with BackgroundServer(eng, max_pending=8) as bg:

        async def drive():
            rng = np.random.default_rng(13)

            def payload(prio, max_new=4):
                return {"prompt": rng.integers(0, cfg.vocab, 6).tolist(),
                        "max_new_tokens": max_new, "priority": prio}

            # a long filler holds the single slot while the queue forms
            filler = asyncio.ensure_future(
                sse_generate(bg.host, bg.port, payload(1, max_new=48)))
            while eng.scheduler.n_running + eng.scheduler.n_prefilling < 1:
                await asyncio.sleep(0.01)
            # queue: default-class first, then an urgent class-0
            low = asyncio.ensure_future(
                sse_generate(bg.host, bg.port, payload(1)))
            while eng.scheduler.n_pending < 1:
                await asyncio.sleep(0.01)
            high = asyncio.ensure_future(
                sse_generate(bg.host, bg.port, payload(0)))
            while eng.scheduler.n_pending < 2:  # both queued together
                await asyncio.sleep(0.01)
            return await asyncio.gather(filler, low, high)

        rf, rl, rh = asyncio.run(drive())
        assert all(r["status"] == 200 for r in (rf, rl, rh))
        assert all(r["finish_reason"] == "length" for r in (rf, rl, rh))
        _wait_drained(eng)
        order = list(eng.scheduler.admitted)[-3:]
        assert len(order) == 3
        assert order[1] > order[2], (
            f"urgent request did not jump the default-class queue: {order}")
    _assert_pool_clean(eng)
