"""auto_fact: the paper's API — gating, filtering, conv path, dynamic rank."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro import auto_fact, defactorize, nn
from repro.core import r_max, resolve_rank, should_factorize
from repro.core.auto_fact import FactReport


class ConvWrap(nn.Module):
    c1: nn.Conv1D
    c2: nn.Conv2D


@pytest.fixture
def attn(key):
    return nn.Attention.create(key, 64, 4, 2)


# ---- rank policy -----------------------------------------------------------


@given(m=st.integers(2, 512), n=st.integers(2, 512))
def test_r_max_break_even(m, n):
    r = r_max(m, n)
    # cost model: dense = m*n, factorized = r*(m+n); equal at r_max
    assert abs(r * (m + n) - m * n) < 1e-6


@given(m=st.integers(2, 256), n=st.integers(2, 256),
       ratio=st.floats(0.01, 1.0))
def test_resolve_ratio_bounds(m, n, ratio):
    r = resolve_rank(ratio, m, n)
    assert 1 <= r <= r_max(m, n) + 1


@given(m=st.integers(2, 256), n=st.integers(2, 256), r=st.integers(1, 300))
def test_gate_iff_cheaper(m, n, r):
    assert should_factorize(r, m, n) == (r * (m + n) < m * n)


def test_resolve_rank_rejects_bad():
    with pytest.raises(ValueError):
        resolve_rank(0, 4, 4)
    with pytest.raises(ValueError):
        resolve_rank(1.5, 4, 4)
    with pytest.raises(TypeError):
        resolve_rank(True, 4, 4)


# ---- auto_fact on linears ---------------------------------------------------


def test_replaces_all_linears(attn):
    fact, rep = auto_fact(attn, rank=8, return_report=True)
    assert isinstance(rep, FactReport)
    assert len(rep.entries) == 4 and not rep.skipped
    for proj in (fact.q_proj, fact.k_proj, fact.v_proj, fact.o_proj):
        assert isinstance(proj, nn.LED) and proj.rank == 8


def test_r_max_gate_skips(attn):
    # rank 32 >= r_max(64,64)=32 → q/o skipped; r_max(64,32)=21.3 → k/v skipped
    fact, rep = auto_fact(attn, rank=32, return_report=True)
    assert len(rep.entries) == 0 and len(rep.skipped) == 4
    assert isinstance(fact.q_proj, nn.Linear)


def test_svd_factorization_close_at_high_rank(attn, key):
    fact = auto_fact(attn, rank=20, solver="svd")
    x = jax.random.normal(key, (2, 6, 64))
    # rank 20 of 64x64 random: lossy but structured comparison still sane
    dense, fact_out = attn(x), fact(x)
    assert fact_out.shape == dense.shape
    assert bool(jnp.isfinite(fact_out).all())


def test_param_reduction_matches_formula(attn):
    fact, rep = auto_fact(attn, rank=8, return_report=True)
    # q/o: 64x64 -> 8*(64+64); k/v: 64x32 -> 8*(64+32)
    assert rep.params_before == 2 * 64 * 64 + 2 * 64 * 32
    assert rep.params_after == 2 * 8 * 128 + 2 * 8 * 96


def test_submodule_filter(attn):
    fact, rep = auto_fact(attn, rank=8, submodules=["q_proj", "k_proj"],
                          return_report=True)
    assert {e[0] for e in rep.entries} == {"q_proj", "k_proj"}
    assert isinstance(fact.v_proj, nn.Linear)


def test_exclude_filter(attn):
    fact, rep = auto_fact(attn, rank=8, exclude=["o_proj"],
                          return_report=True)
    assert "o_proj" not in {e[0] for e in rep.entries}
    assert isinstance(fact.o_proj, nn.Linear)


def test_bias_preserved(key):
    lin = nn.Linear.create(key, 16, 8, use_bias=True)

    class W(nn.Module):
        l: nn.Linear

    fact = auto_fact(W(l=lin), rank=2)
    assert fact.l.bias is not None
    np.testing.assert_allclose(np.asarray(fact.l.bias),
                               np.asarray(lin.bias))


def test_defactorize_roundtrip(attn, key):
    fact = auto_fact(attn, rank=8, solver="svd")
    dense = defactorize(fact)
    assert isinstance(dense.q_proj, nn.Linear)
    x = jax.random.normal(key, (1, 4, 64))
    np.testing.assert_allclose(np.asarray(dense(x)), np.asarray(fact(x)),
                               atol=1e-4)


def test_stacked_expert_factorization(key):
    moe = nn.MoE.create(key, 32, 64, n_experts=4, top_k=2)
    fact, rep = auto_fact(moe, rank=8, exclude=["router"],
                          return_report=True)
    assert isinstance(fact.experts.gate_proj, nn.LED)
    assert fact.experts.gate_proj.A.shape == (4, 32, 8)  # per-expert factors
    assert isinstance(fact.router, nn.Linear)  # excluded
    x = jax.random.normal(key, (2, 8, 32))
    out = fact(x)
    assert out.y.shape == (2, 8, 32) and bool(jnp.isfinite(out.y).all())


def test_led_forward_equals_materialized(key):
    led = nn.LED.create(key, 24, 40, 6, use_bias=True)
    x = jax.random.normal(key, (3, 5, 24))
    np.testing.assert_allclose(np.asarray(led(x)),
                               np.asarray(led.materialize()(x)), atol=1e-4)


# ---- conv path ---------------------------------------------------------------


def test_conv_factorization_exact_at_full_rank(key):
    wrap = ConvWrap(c1=nn.Conv1D.create(key, 8, 12, 3),
                    c2=nn.Conv2D.create(key, 4, 6, 3))
    # full effective rank: min(Cin*S, Cout) = 12 and 6 — but the r_max gate
    # requires r < r_max, so pick rank above r_max to check skip instead
    fact, rep = auto_fact(wrap, rank=0.99, solver="svd", return_report=True)
    x1 = jax.random.normal(key, (2, 10, 8))
    x2 = jax.random.normal(key, (2, 6, 6, 4))
    assert isinstance(fact.c1, nn.CED1D) and isinstance(fact.c2, nn.CED2D)
    # materialize(CED) must equal applying the two convs
    np.testing.assert_allclose(np.asarray(fact.c1.materialize()(x1)),
                               np.asarray(fact.c1(x1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fact.c2.materialize()(x2)),
                               np.asarray(fact.c2(x2)), atol=1e-4)


def test_conv_svd_reconstruction_quality(key):
    conv = nn.Conv1D.create(key, 8, 12, 3)

    class W(nn.Module):
        c: nn.Conv1D

    x = jax.random.normal(key, (2, 10, 8))
    errs = []
    for r in (2, 6):
        fact = auto_fact(W(c=conv), rank=r, solver="svd")
        errs.append(float(jnp.abs(fact.c(x) - conv(x)).max()))
    assert errs[1] < errs[0]  # higher rank → better approximation


def test_factorize_conv_flag(key):
    wrap = ConvWrap(c1=nn.Conv1D.create(key, 8, 12, 3),
                    c2=nn.Conv2D.create(key, 4, 6, 3))
    fact = auto_fact(wrap, rank=2, factorize_conv=False)
    assert isinstance(fact.c1, nn.Conv1D) and isinstance(fact.c2, nn.Conv2D)


# ---- whole-model -------------------------------------------------------------


def test_auto_fact_whole_model_runs(key):
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    fact, rep = auto_fact(model, rank=0.5, solver="svd",
                          exclude=["embed", "lm_head"], return_report=True)
    assert rep.entries, "expected some layers factorized"
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    logits, _ = fact(toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


# ---- FactReport accounting ---------------------------------------------------


def _factored_param_delta(model, fact):
    """params(model) - params(fact), counting only factorized targets (all
    other leaves are shared/unchanged, so the tree-wide delta equals the
    before/after delta over factorized layers)."""
    from repro.nn import param_count

    return param_count(model) - param_count(fact)


def test_report_param_counts_match_pytree(key):
    """params_before/params_after must equal the actual pytree param counts
    of the replaced weights (bias leaves are carried over unchanged)."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    fact, rep = auto_fact(model, rank=0.5, solver="svd",
                          exclude=["embed", "lm_head"], return_report=True)
    assert rep.params_before - rep.params_after == \
        _factored_param_delta(model, fact)
    # entries carry per-layer (m, n, r, rel_err); params_* count the whole
    # layer-stacked weights, hence the n_layers factor
    led_after = sum(r * (m + n) for _, kind, m, n, r, _e in rep.entries)
    dense_before = sum(m * n for _, kind, m, n, r, _e in rep.entries)
    assert rep.params_after == cfg.n_layers * led_after
    assert rep.params_before == cfg.n_layers * dense_before
    assert rep.compression == rep.params_before / rep.params_after


def test_report_stacked_counts_include_leading_axes(key):
    """Layer-stacked weights: report counts must cover the whole stack,
    not a single slice."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    fact, rep = auto_fact(model, rank=0.5, solver="svd",
                          exclude=["embed", "lm_head"], return_report=True)
    total_a_b = 0
    for proj in (fact.blocks.attn.q_proj, fact.blocks.attn.k_proj,
                 fact.blocks.attn.v_proj, fact.blocks.attn.o_proj,
                 fact.blocks.mlp.gate_proj, fact.blocks.mlp.up_proj,
                 fact.blocks.mlp.down_proj):
        assert isinstance(proj, nn.LED)
        total_a_b += proj.A.size + proj.B.size  # includes the stack axis
    assert rep.params_after == total_a_b


def test_report_submodule_filter_reflected(attn):
    fact, rep = auto_fact(attn, rank=8, submodules=["q_proj", "k_proj"],
                          return_report=True)
    assert {e[0] for e in rep.entries} == {"q_proj", "k_proj"}
    skipped = {p for p, why in rep.skipped}
    assert skipped == {"v_proj", "o_proj"}
    assert all(why == "filtered" for _, why in rep.skipped)
    # accounting covers ONLY the factorized subset
    assert rep.params_before == 64 * 64 + 64 * 32  # q (64x64) + k (64x32)
    assert rep.params_after == 8 * (64 + 64) + 8 * (64 + 32)


def test_report_exclude_filter_reflected(attn):
    fact, rep = auto_fact(attn, rank=8, exclude=["o_proj"],
                          return_report=True)
    assert {e[0] for e in rep.entries} == {"q_proj", "k_proj", "v_proj"}
    assert [p for p, why in rep.skipped] == ["o_proj"]
    assert rep.params_before == 64 * 64 + 2 * 64 * 32  # o_proj not counted


# ---- compression edge cases & per-layer reconstruction error ----------------


def test_empty_report_compression_is_one():
    """Nothing factorized → 1.0x compression, not a ZeroDivisionError."""
    rep = FactReport()
    assert rep.compression == 1.0
    assert "0 layers factorized" in rep.summary()


def test_all_skipped_report_compression_is_one(attn):
    """Every layer gated off (rank >= r_max everywhere): the report must
    still render and report no compression."""
    _, rep = auto_fact(attn, rank=32, return_report=True)
    assert not rep.entries and len(rep.skipped) == 4
    assert rep.params_after == 0 and rep.compression == 1.0
    assert "4 skipped" in rep.summary()


def test_entries_carry_rel_err(attn):
    """Each entry's 6th field is the relative Frobenius reconstruction
    error; SVD at a given rank is optimal, so it never exceeds the
    random solver's error on the same layer."""
    _, rs = auto_fact(attn, rank=8, solver="svd", return_report=True)
    _, rr = auto_fact(attn, rank=8, solver="random", return_report=True)
    svd = {e[0]: e[5] for e in rs.entries}
    rnd = {e[0]: e[5] for e in rr.entries}
    assert svd.keys() == rnd.keys() == {"q_proj", "k_proj", "v_proj",
                                        "o_proj"}
    for path, err in svd.items():
        assert 0.0 <= err <= 1.5
        assert err <= rnd[path] + 1e-6, path
    assert "rel_err=" in rs.summary()


def test_gate_false_full_rank_is_exact(attn, key):
    """gate=False + rank=1.0: every Linear becomes an exact full-rank
    LED (r = min(m, n), rel_err ~ 0) even though r >= r_max would
    normally skip it — the knob serving uses to isolate routing bugs
    from truncation error."""
    fact, rep = auto_fact(attn, rank=1.0, solver="svd", gate=False,
                          return_report=True)
    assert len(rep.entries) == 4 and not rep.skipped
    for path, kind, m, n, r, err in rep.entries:
        assert r == min(m, n)
        assert err < 1e-5, f"{path}: {err}"
    x = jax.random.normal(key, (2, 6, 64))
    np.testing.assert_allclose(np.asarray(fact(x)), np.asarray(attn(x)),
                               atol=1e-4, rtol=1e-4)
    # full-rank LED costs MORE params than dense — the report says so
    assert rep.compression < 1.0


def test_gate_false_int_rank_clamped(attn):
    """gate=False with an oversized int rank clamps to min(m, n) instead
    of erroring or inflating beyond full rank."""
    fact, rep = auto_fact(attn, rank=4096, solver="svd", gate=False,
                          return_report=True)
    for _, _, m, n, r, _err in rep.entries:
        assert r == min(m, n)


def test_gate_false_rejects_bool_rank(attn):
    with pytest.raises(TypeError):
        auto_fact(attn, rank=True, gate=False)
