"""Pallas LED kernel: shape/dtype sweep vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.ops import led_matmul
from repro.kernels.ref import led_matmul_ref


def _mk(m, k, r, n, dtype, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (m, k), dtype)
    a = (jax.random.normal(k2, (k, r)) / np.sqrt(k)).astype(dtype)
    b = (jax.random.normal(k3, (r, n)) / np.sqrt(r)).astype(dtype)
    return x, a, b


SHAPES = [
    (256, 512, 64, 256),   # block-aligned
    (512, 1024, 128, 512),  # multiple k-blocks
    (128, 256, 8, 384),    # tiny rank
    (100, 300, 17, 130),   # padding on every dim
    (8, 64, 4, 48),        # smaller than any block
    (1, 128, 16, 128),     # single row (decode-like)
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_led_kernel_matches_ref(shape, dtype):
    m, k, r, n = shape
    x, a, b = _mk(m, k, r, n, dtype)
    y = led_matmul(x, a, b)
    yr = led_matmul_ref(x, a, b)
    assert y.shape == yr.shape and y.dtype == yr.dtype
    # bf16 output rounding differs by ≤1 ULP when K is split across blocks
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)


def test_led_kernel_batched_leading_dims():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 64, 128))
    a = jax.random.normal(jax.random.PRNGKey(2), (128, 16)) / 11.3
    b = jax.random.normal(jax.random.PRNGKey(3), (16, 96)) / 4.0
    y = led_matmul(x, a, b)
    assert y.shape == (2, 3, 64, 96)
    np.testing.assert_allclose(np.asarray(y), np.asarray(led_matmul_ref(x, a, b)),
                               atol=1e-4)


def _mk_stacked(stack, m, k, r, n, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (*stack, m, k))
    a = jax.random.normal(k2, (*stack, k, r)) / np.sqrt(k)
    b = jax.random.normal(k3, (*stack, r, n)) / np.sqrt(r)
    return x, a, b


@pytest.mark.parametrize("shape", [(64, 128, 16, 96), (100, 300, 17, 130)])
def test_led_kernel_three_way_parity(shape):
    """kernel == jnp oracle == unfused (x @ a) @ b, all three ways."""
    m, k, r, n = shape
    x, a, b = _mk(m, k, r, n, jnp.float32, seed=3)
    y_k = np.asarray(led_matmul(x, a, b))
    y_r = np.asarray(led_matmul_ref(x, a, b))
    y_u = np.asarray((x @ a) @ b)
    np.testing.assert_allclose(y_k, y_r, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y_k, y_u, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y_r, y_u, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("stack", [(3,), (2, 2)])
def test_led_kernel_stacked_factors(stack):
    """Stacked A/B (layer-scanned or expert-stacked LED weights, the
    shapes ``auto_fact`` emits for scan-over-layers models): the kernel
    vmaps over the shared leading axes of x, a and b."""
    x, a, b = _mk_stacked(stack, 24, 64, 8, 48, seed=11)
    y_k = np.asarray(led_matmul(x, a, b))
    assert y_k.shape == (*stack, 24, 48)
    np.testing.assert_allclose(y_k, np.asarray(led_matmul_ref(x, a, b)),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y_k, np.asarray((x @ a) @ b),
                               atol=1e-4, rtol=1e-4)


def test_led_kernel_stacked_matches_auto_fact_shapes():
    """Drive the kernel with factors produced by ``auto_fact`` itself on
    a layer-stacked Linear — the exact (L, d, r)/(L, r, d) layout the
    serving model's scanned blocks carry."""
    from repro.core import auto_fact
    from repro.nn import Linear

    lin = Linear.create(jax.random.PRNGKey(7), 64, 96, stack_dims=(3,))
    led = auto_fact(lin, 0.5, solver="svd", gate=False)
    x = jax.random.normal(jax.random.PRNGKey(8), (3, 10, 64))
    y_k = np.asarray(led_matmul(x, led.A, led.B))
    np.testing.assert_allclose(y_k, np.asarray((x @ led.A) @ led.B),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(
        y_k, np.asarray(led_matmul_ref(x, led.A, led.B)),
        atol=1e-4, rtol=1e-4)


def test_led_kernel_stacked_mismatched_leads_raise():
    x, a, b = _mk_stacked((3,), 8, 16, 4, 8)
    with pytest.raises(ValueError):
        led_matmul(x, a[:2], b)
    with pytest.raises(ValueError):
        led_matmul(x[:2], a, b)


def test_led_trainable_grads_stacked_factors():
    """Stacked factors fall back to jax.vjp of the reference (the
    hand-derived backward is 2D-only); gradients must still match
    autodiff of the unfused product."""
    from repro.kernels.ops import led_matmul_trainable

    x, a, b = _mk_stacked((3,), 12, 32, 4, 24, seed=13)
    w = jax.random.normal(jax.random.PRNGKey(14), (3, 12, 24))
    loss_tr = lambda x, a, b: jnp.sum(led_matmul_trainable(x, a, b) * w)
    loss_un = lambda x, a, b: jnp.sum(((x @ a) @ b) * w)
    g_tr = jax.grad(loss_tr, argnums=(0, 1, 2))(x, a, b)
    g_un = jax.grad(loss_un, argnums=(0, 1, 2))(x, a, b)
    for gt, gu, name in zip(g_tr, g_un, "xab"):
        assert gt.shape == gu.shape
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gu),
                                   atol=1e-3, rtol=1e-4,
                                   err_msg=f"d{name} mismatch")


@given(m=st.integers(1, 80), k=st.integers(1, 96), r=st.integers(1, 24),
       n=st.integers(1, 80))
@settings(max_examples=10)
def test_led_kernel_arbitrary_shapes(m, k, r, n):
    x, a, b = _mk(m, k, r, n, jnp.float32, seed=m + k + r + n)
    y = led_matmul(x, a, b, block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(led_matmul_ref(x, a, b)),
                               atol=1e-4, rtol=1e-4)


def test_led_kernel_custom_blocks():
    x, a, b = _mk(256, 256, 32, 256, jnp.float32)
    for bm, bn, bk in [(64, 64, 64), (128, 256, 128), (256, 128, 256)]:
        y = led_matmul(x, a, b, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(led_matmul_ref(x, a, b)),
                                   atol=1e-4, rtol=1e-4)


def test_led_layer_uses_kernel(key):
    led_jnp = __import__("repro.nn", fromlist=["LED"]).LED.create(
        key, 64, 96, 8)
    led_pl = led_jnp.replace(fuse="pallas")
    x = jax.random.normal(key, (4, 10, 64))
    np.testing.assert_allclose(np.asarray(led_pl(x)), np.asarray(led_jnp(x)),
                               atol=1e-4, rtol=1e-4)


def test_led_kernel_grad_via_jnp_path(key):
    """The kernel is forward-only today; LED's default path must be
    differentiable (training uses fuse='auto' → jnp)."""
    from repro import nn

    led = nn.LED.create(key, 16, 8, 4)
    x = jax.random.normal(key, (2, 16))
    g = jax.grad(lambda m: jnp.sum(m(x) ** 2))(led)
    assert g.A.shape == led.A.shape and bool(jnp.isfinite(g.A).all())


def test_led_trainable_grads_match_ref_padded_shapes():
    """jax.grad of the custom VJP vs jax.grad of the pure-jnp reference on
    non-divisible shapes: M, K and N all overhang their block grids, so the
    backward must slice the padding back out of every gradient."""
    m, k, r, n = 300, 600, 9, 300  # default blocks 256/512/256 -> all pad
    x, a, b = _mk(m, k, r, n, jnp.float32, seed=42)
    w = jax.random.normal(jax.random.PRNGKey(99), (m, n))  # non-uniform dy

    loss_pl = lambda x, a, b: jnp.sum(led_matmul(x, a, b) * w)
    loss_ref = lambda x, a, b: jnp.sum(led_matmul_ref(x, a, b) * w)
    from repro.kernels.ops import led_matmul_trainable

    loss_tr = lambda x, a, b: jnp.sum(led_matmul_trainable(x, a, b) * w)
    g_tr = jax.grad(loss_tr, argnums=(0, 1, 2))(x, a, b)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, a, b)
    for gt, gr, name in zip(g_tr, g_ref, "xab"):
        assert gt.shape == gr.shape
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gr),
                                   atol=1e-3, rtol=1e-4,
                                   err_msg=f"d{name} mismatch")


def test_led_trainable_grads_match_ref_batched_leading_axes():
    """Batched leading axes: the VJP flattens (2, 3, M) to rows and must
    reshape dx back; dA/dB accumulate over every leading axis."""
    from repro.kernels.ops import led_matmul_trainable

    kx, ka, kb, kw = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(kx, (2, 3, 40, 64))
    a = jax.random.normal(ka, (64, 8)) / 8.0
    b = jax.random.normal(kb, (8, 48)) / 2.8
    w = jax.random.normal(kw, (2, 3, 40, 48))

    loss_tr = lambda x, a, b: jnp.sum(led_matmul_trainable(x, a, b) * w)
    loss_ref = lambda x, a, b: jnp.sum(led_matmul_ref(x, a, b) * w)
    g_tr = jax.grad(loss_tr, argnums=(0, 1, 2))(x, a, b)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, a, b)
    for gt, gr, name in zip(g_tr, g_ref, "xab"):
        assert gt.shape == gr.shape
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gr),
                                   atol=1e-3, rtol=1e-4,
                                   err_msg=f"d{name} mismatch")


def test_led_trainable_gradients_match_jnp(key):
    """The custom-VJP kernel path must produce the same gradients as the
    jnp path (dx itself reuses the fused kernel)."""
    from repro import nn

    led = nn.LED.create(key, 64, 96, 8)
    led_pl = led.replace(fuse="pallas")
    x = jax.random.normal(key, (4, 64))
    loss = lambda m: jnp.sum(m(x) ** 2)
    g_jnp, g_pl = jax.grad(loss)(led), jax.grad(loss)(led_pl)
    np.testing.assert_allclose(np.asarray(g_pl.A), np.asarray(g_jnp.A),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(g_pl.B), np.asarray(g_jnp.B),
                               atol=1e-3, rtol=1e-4)
    gx_jnp = jax.grad(lambda xx: jnp.sum(led(xx) ** 2))(x)
    gx_pl = jax.grad(lambda xx: jnp.sum(led_pl(xx) ** 2))(x)
    np.testing.assert_allclose(np.asarray(gx_pl), np.asarray(gx_jnp),
                               atol=1e-3, rtol=1e-4)
