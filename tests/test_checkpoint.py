"""Checkpoint manager: atomicity, resume, GC, structure guards."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.checkpoint import CheckpointManager


class State(nn.Module):
    w: jax.Array
    b: jax.Array


def make_state(v):
    return State(w=jnp.full((4, 4), float(v)), b=jnp.arange(3.0))


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = make_state(7)
    mgr.save(10, st)
    step, restored = mgr.restore_latest(make_state(0))
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored.w), np.asarray(st.w))
    np.testing.assert_allclose(np.asarray(restored.b), np.asarray(st.b))


def test_latest_points_to_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for s in (5, 20, 15):
        mgr.save(s, make_state(s))
    assert mgr.latest_step() == 15  # LATEST tracks most recent SAVE


def test_keep_last_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(1, 6):
        mgr.save(s, make_state(s))
    assert mgr.all_steps() == [4, 5]


def test_partial_write_is_invisible(tmp_path):
    """A .tmp dir (simulated crash mid-save) must never be restored."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(1))
    os.makedirs(str(tmp_path / "step_00000002.tmp"))
    assert mgr.latest_step() == 1
    # also: a step dir without manifest is ignored
    os.makedirs(str(tmp_path / "step_00000003"))
    assert mgr.latest_step() == 1


def test_treedef_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(1))

    class Other(nn.Module):
        w: jax.Array

    with pytest.raises(ValueError):
        mgr.restore(1, Other(w=jnp.zeros((4, 4))))


def test_none_leaves_roundtrip(tmp_path):
    lin = nn.Linear.create(jax.random.PRNGKey(0), 4, 4, use_bias=False)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, lin)
    _, restored = mgr.restore_latest(
        nn.Linear.create(jax.random.PRNGKey(1), 4, 4, use_bias=False))
    assert restored.bias is None
    np.testing.assert_allclose(np.asarray(restored.weight),
                               np.asarray(lin.weight))


def test_restore_casts_to_template_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, State(w=jnp.ones((4, 4), jnp.float32), b=jnp.zeros(3)))
    _, restored = mgr.restore_latest(
        State(w=jnp.zeros((4, 4), jnp.bfloat16), b=jnp.zeros(3)))
    assert restored.w.dtype == jnp.bfloat16


def test_train_driver_resume(tmp_path):
    """End-to-end: kill training mid-run, relaunch, confirm resume."""
    from repro.launch.train import main

    ckpt = str(tmp_path / "run")
    rc = main(["--arch", "paper-tiny", "--reduced", "--steps", "6",
               "--batch", "4", "--seq", "16", "--ckpt-dir", ckpt,
               "--ckpt-every", "3"])
    assert rc == 0
    mgr = CheckpointManager(ckpt)
    assert mgr.latest_step() == 6
    # a second invocation resumes (instantly: start == steps)
    rc = main(["--arch", "paper-tiny", "--reduced", "--steps", "6",
               "--batch", "4", "--seq", "16", "--ckpt-dir", ckpt])
    assert rc == 0
