"""Trip-count-aware HLO analysis: exact flop counts on known workloads."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_costs import analyze, parse_computations


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    txt = _hlo(lambda a, b: a @ b, a, a)
    assert analyze(txt).flops == 2 * 512 ** 3


def test_batched_dot_flops_exact():
    ab = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    txt = _hlo(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), ab, ab)
    assert analyze(txt).flops == 4 * 2 * 64 ** 3


def test_scan_flops_scaled_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    txt = _hlo(scanned, x, ws)
    assert analyze(txt).flops == 8 * 2 * 128 * 256 * 256


def test_collectives_in_scan_multiplied():
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("mp",))

    def fn(x, ws):
        def body(x, w):
            return jax.lax.psum(jnp.tanh(x @ w), "mp"), None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    from _compat import shard_map

    sm = shard_map(fn, mesh=mesh, in_specs=(P(), P()), out_specs=P())
    c = analyze(_hlo(sm, x, ws))
    assert c.collective_count.get("all-reduce") == 8
    assert c.collective_bytes["all-reduce"] == 8 * 128 * 256 * 4


def test_scan_weight_slicing_not_counted_as_full_reads():
    """The stacked weights are loop-invariant; per-iteration bytes must be
    ~one layer's slice, not the whole stack."""

    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 256, 256), jnp.float32)
    c = analyze(_hlo(scanned, x, ws))
    full_stack = 64 * 256 * 256 * 4
    # 64 iterations x full-stack reads would be 64*full_stack; sliced reads
    # are ~1x full_stack total. Allow generous slack for copies.
    assert c.bytes < 8 * full_stack


def test_parse_computations_finds_entry():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comps, entry = parse_computations(_hlo(lambda a: a @ a, a))
    assert entry is not None and entry in comps
    assert any(op.kind == "dot" for op in comps[entry].ops) or any(
        op.kind == "fusion" for op in comps[entry].ops)


def test_constrain_acts_noop_without_mesh():
    from repro.dist.sharding import constrain_acts

    x = jnp.ones((4, 8, 16))
    assert constrain_acts(x) is x
