"""Training substrate: optimizer math, accumulation equivalence, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.data import markov_entropy_floor, markov_lm_batch
from repro.optim import (AdamW, SGD, clip_by_global_norm, global_norm,
                         linear_warmup_cosine)
from repro.train import TrainState, make_train_step


class Quad(nn.Module):
    w: jax.Array


def test_adamw_reference_step():
    """One AdamW step against a hand-computed update."""
    opt = AdamW(0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                master_fp32=False)
    p = Quad(w=jnp.array([1.0, 2.0]))
    g = Quad(w=jnp.array([0.5, -1.0]))
    st = opt.init(p)
    new_p, st = opt.update(g, st, p)
    # bias-corrected first step: update = lr * g/|g| elementwise (≈ sign)
    expected = np.array([1.0, 2.0]) - 0.1 * np.array([0.5, -1.0]) / (
        np.abs(np.array([0.5, -1.0])) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p.w), expected, atol=1e-5)


def test_adamw_weight_decay_decoupled():
    opt = AdamW(0.1, weight_decay=0.5, master_fp32=False)
    p = Quad(w=jnp.array([2.0]))
    g = Quad(w=jnp.array([0.0]))
    new_p, _ = opt.update(g, opt.init(p), p)
    # zero grad → pure decay: w - lr*wd*w
    np.testing.assert_allclose(np.asarray(new_p.w), [2.0 - 0.1 * 0.5 * 2.0],
                               atol=1e-6)


def test_adamw_master_fp32_preserves_precision():
    opt = AdamW(1e-4, weight_decay=0.0, master_fp32=True)
    p = Quad(w=jnp.ones((4,), jnp.bfloat16))
    g = Quad(w=jnp.full((4,), 1e-3, jnp.bfloat16))
    st = opt.init(p)
    assert st.master.w.dtype == jnp.float32
    for _ in range(3):
        p, st = opt.update(g, st, p)
    # master accumulated updates even though bf16 param may round
    assert float(st.master.w[0]) < 1.0


def test_adamw_handles_none_leaves():
    lin = nn.Linear.create(jax.random.PRNGKey(0), 4, 4, use_bias=False)
    assert lin.bias is None
    opt = AdamW(1e-2, master_fp32=False)
    g = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), lin)
    new_p, _ = opt.update(g, opt.init(lin), lin)
    assert new_p.bias is None


def test_sgd_momentum():
    opt = SGD(0.1, momentum=0.5)
    p = Quad(w=jnp.array([1.0]))
    g = Quad(w=jnp.array([1.0]))
    st = opt.init(p)
    p1, st = opt.update(g, st, p)
    p2, st = opt.update(g, st, p1)
    np.testing.assert_allclose(np.asarray(p1.w), [0.9], atol=1e-6)
    np.testing.assert_allclose(np.asarray(p2.w), [0.9 - 0.1 * 1.5], atol=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.array([3.0, 4.0])}  # norm 5
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    same, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), [3.0, 4.0])


def test_schedule_warmup_cosine():
    sched = linear_warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.array(0))) == 0.0
    assert abs(float(sched(jnp.array(10))) - 1.0) < 1e-6
    assert float(sched(jnp.array(100))) < 1e-6
    assert 0.4 < float(sched(jnp.array(55))) < 0.6


def test_grad_accumulation_equals_full_batch(key):
    """accum=4 on batch 16 == accum=1 on the same batch (same grads)."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("paper-tiny").reduced()
    model = build_model(key, cfg)
    opt = AdamW(1e-2, master_fp32=False)
    toks = jax.random.randint(key, (16, 8), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    s1 = TrainState(model=model, opt=opt.init(model),
                    step=jnp.zeros((), jnp.int32))
    s4 = TrainState(model=model, opt=opt.init(model),
                    step=jnp.zeros((), jnp.int32))
    s1, m1 = jax.jit(make_train_step(opt, accum=1))(s1, batch)
    s4, m4 = jax.jit(make_train_step(opt, accum=4))(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(s1.model)
    l4 = jax.tree_util.tree_leaves(s4.model)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


@pytest.mark.slow
def test_convergence_on_markov_task(key):
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("paper-tiny").replace(n_layers=2, d_model=64, vocab=64,
                                           n_heads=4, n_kv_heads=4,
                                           head_dim=16, d_ff=128)
    model = build_model(key, cfg)
    opt = AdamW(linear_warmup_cosine(3e-3, 10, 80), weight_decay=0.01,
                master_fp32=False)
    state = TrainState(model=model, opt=opt.init(model),
                       step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(opt))
    losses = []
    for i in range(80):
        b = markov_lm_batch(i, batch=16, seq=32, vocab=cfg.vocab, seed=3)
        state, m = step_fn(state, {"tokens": b.tokens, "labels": b.labels})
        losses.append(float(m["loss"]))
    floor = markov_entropy_floor(3, cfg.vocab)
    assert losses[-1] < losses[0] - 0.5, "no learning"
    assert losses[-1] < floor + 1.2, f"final {losses[-1]} vs floor {floor}"
