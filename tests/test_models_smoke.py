"""Per-arch smoke tests: REDUCED config, one forward + one train step on CPU,
asserting output shapes and finiteness (the assignment's smoke requirement).
Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.optim import AdamW
from repro.train import TrainState, make_train_step

B, S = 2, 16


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(key, (B, 24, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(key, cfg)
    batch = _batch(cfg, key)

    if cfg.family == "encdec":
        logits, aux = model(batch["frames"], batch["tokens"])
    else:
        logits, aux = model(batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))

    opt = AdamW(1e-3, master_fp32=False)
    state = TrainState(model=model, opt=opt.init(model),
                       step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(opt))
    state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree_util.tree_leaves(model)[0]
    after = jax.tree_util.tree_leaves(state.model)[0]
    assert not jnp.array_equal(before, after)


@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-moe-16b", "mamba2-2.7b",
                                  "hymba-1.5b", "whisper-medium"])
def test_arch_smoke_serve_paths(arch, key):
    """prefill + a few decode steps run and match the full forward."""
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=16.0)  # no drops => exact match
    model = build_model(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = 0.1 * jax.random.normal(key, (B, 24, cfg.d_model))
        full, _ = model(frames, toks)
        cache = model.init_cache(B, S + 4, cfg, enc_len=24, dtype=jnp.float32)
        lg, cache = model.prefill(frames, toks[:, :S - 2], cache)
    else:
        full, _ = model(toks)
        cache = model.init_cache(B, S + 4, cfg, dtype=jnp.float32)
        lg, cache = model.prefill(toks[:, :S - 2], cache)
    assert float(jnp.abs(lg[:, 0] - full[:, S - 3]).max()) < 1e-3
    for t in range(S - 2, S):
        lg, cache = model.decode(toks[:, t:t + 1], cache)
        assert float(jnp.abs(lg[:, 0] - full[:, t]).max()) < 1e-3


def test_factorized_arch_smoke(key):
    """Greenformer by-design on a reduced arch still trains."""
    from repro.core import auto_fact

    cfg = get_config("qwen2.5-3b").reduced()
    model = build_model(key, cfg)
    fact = auto_fact(model, 0.5, solver="random", key=key,
                     exclude=["embed", "lm_head"])
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    opt = AdamW(1e-3, master_fp32=False)
    state = TrainState(model=fact, opt=opt.init(fact),
                       step=jnp.zeros((), jnp.int32))
    step_fn = jax.jit(make_train_step(opt))
    state, metrics = step_fn(state, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(metrics["loss"]))
