"""jax version compatibility shims shared by the test suite."""

import jax

# jax.shard_map only exists from 0.5; fall back to the experimental home
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F401
