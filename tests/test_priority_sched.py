"""Priority-class scheduling: admission order, starvation bound,
deadlines, and the cancel-path/finish-reason/ttft bugfix contracts.

Pure scheduler-level tests — no jax, no model.  The engine-level
counterpart (preemption + bit-identical resume) lives in
``tests/test_preemption.py``.
"""

import math
import time

import numpy as np
import pytest

from _hyp import given, settings, st
from repro.serve.scheduler import (FINISH_REASONS, Completion, Request,
                                   Scheduler)


def _req(prio=1, **kw):
    kw.setdefault("prompt", np.array([1, 2, 3]))
    kw.setdefault("max_new_tokens", 4)
    return Request(priority=prio, **kw)


def _drain(sched, admissible=None):
    """Admit -> bind -> finish until the queue is empty (1-slot scheduler);
    returns uids in admission order."""
    order = []
    while True:
        nxt = sched.next_admission(admissible)
        if nxt is None:
            break
        slot, req = nxt
        sched.bind(slot, req, first_token=0)
        order.append(req.uid)
        sched.finish(slot, "length")
    return order


# ---- priority classes -------------------------------------------------------


def test_high_priority_admitted_before_earlier_low():
    sched = Scheduler(1)
    low = sched.submit(_req(prio=2))
    high = sched.submit(_req(prio=0))
    assert _drain(sched) == [high, low]


def test_within_class_fifo():
    sched = Scheduler(1, aging_every=10_000)  # aging off for this test
    uids = {0: [], 1: [], 2: []}
    rng = np.random.default_rng(0)
    for prio in rng.integers(0, 3, 30):
        uids[int(prio)].append(sched.submit(_req(prio=int(prio))))
    order = _drain(sched)
    for prio, expect in uids.items():
        got = [u for u in order if u in set(expect)]
        assert got == expect, f"class {prio} not FIFO"
    # and classes themselves came out best-first (aging disabled)
    assert order == uids[0] + uids[1] + uids[2]


def test_admissible_gates_chosen_head_only():
    """A blocked head blocks admission entirely — later requests in the
    same class never jump it."""
    sched = Scheduler(2, aging_every=10_000)
    big = sched.submit(_req(prio=1))
    small = sched.submit(_req(prio=1))
    blocked = {big}
    assert sched.next_admission(lambda r: r.uid not in blocked) is None
    blocked.clear()
    nxt = sched.next_admission(lambda r: True)
    assert nxt is not None and nxt[1].uid == big
    assert small in [r.uid for r in sched.pending]


def test_aging_bounds_starvation_under_adversarial_arrivals():
    """A low-priority request is admitted within ``aging_every``
    admissions even when high-priority traffic never stops arriving."""
    k = 4
    sched = Scheduler(1, aging_every=k)
    starved = sched.submit(_req(prio=5))
    admitted = []
    for i in range(3 * k):
        sched.submit(_req(prio=0))  # adversary: endless urgent stream
        slot, req = sched.next_admission()
        sched.bind(slot, req, first_token=0)
        admitted.append(req.uid)
        sched.finish(slot, "length")
        if starved in admitted:
            break
    assert starved in admitted
    assert admitted.index(starved) <= k - 1


# ---- property tests (skip without hypothesis) -------------------------------


@given(prios=st.lists(st.integers(min_value=0, max_value=3),
                      min_size=1, max_size=40),
       aging=st.integers(min_value=1, max_value=8))
@settings(max_examples=50, deadline=None)
def test_property_within_class_admission_is_submission_order(prios, aging):
    sched = Scheduler(1, aging_every=aging)
    by_class = {}
    for p in prios:
        by_class.setdefault(p, []).append(sched.submit(_req(prio=p)))
    order = _drain(sched)
    assert sorted(order) == sorted(u for us in by_class.values() for u in us)
    for p, expect in by_class.items():
        assert [u for u in order if u in set(expect)] == expect


@given(data=st.data(),
       aging=st.integers(min_value=1, max_value=6))
@settings(max_examples=50, deadline=None)
def test_property_oldest_head_bypassed_at_most_aging_every(data, aging):
    """Count, per admission, whether the oldest class head was bypassed;
    runs of consecutive bypasses never exceed ``aging_every - 1`` — even
    with adversarial arrivals interleaved between admissions."""
    sched = Scheduler(1, aging_every=aging)
    for p in data.draw(st.lists(st.integers(0, 3), min_size=2, max_size=10)):
        sched.submit(_req(prio=p))
    run = 0
    for _ in range(60):
        arrivals = data.draw(st.lists(st.integers(0, 3), max_size=3))
        for p in arrivals:
            sched.submit(_req(prio=p))
        if sched.n_pending == 0:
            break
        oldest = min(r.uid for r in sched.pending)
        slot, req = sched.next_admission()
        sched.bind(slot, req, first_token=0)
        sched.finish(slot, "length")
        run = 0 if req.uid == oldest else run + 1
        assert run <= aging - 1, (
            f"oldest head bypassed {run} times with aging_every={aging}")


# ---- deadlines --------------------------------------------------------------


def test_expire_pending_drops_past_deadline_as_cancelled():
    sched = Scheduler(1)
    live = sched.submit(_req(timeout_s=60.0))
    dead = sched.submit(_req(timeout_s=0.001))
    nodeadline = sched.submit(_req())
    time.sleep(0.005)
    out = sched.expire_pending()
    assert [c.uid for c in out] == [dead]
    assert out[0].finish_reason == "cancelled" and out[0].tokens == []
    assert {r.uid for r in sched.pending} == {live, nodeadline}
    # lazily-dropped queue entry must not resurface at admission
    assert _drain(sched) == [live, nodeadline]


def test_timeout_validation():
    with pytest.raises(ValueError):
        _req(timeout_s=0.0)
    with pytest.raises(ValueError):
        _req(prio=-1)


# ---- bugfix contracts -------------------------------------------------------


def test_finish_reason_raises_on_unclassifiable_eviction():
    """The old code fell through to a silent ``"length"`` for any evicted
    slot — a cancelled request could masquerade as a natural finish."""
    sched = Scheduler(1)
    sched.submit(_req(max_new_tokens=10))
    slot, req = sched.next_admission()
    sched.bind(slot, req, first_token=0)
    with pytest.raises(ValueError, match="no stop condition"):
        sched.finish_reason(slot, cache_pos=5, max_len=32)
    # the explicit-reason path still works, but only for known reasons
    with pytest.raises(ValueError, match="unknown finish_reason"):
        sched.finish(slot, "exploded")
    comp = sched.finish(slot, "cancelled")
    assert comp.finish_reason in FINISH_REASONS


def test_finish_reason_classifies_natural_stops():
    sched = Scheduler(1)
    sched.submit(_req(max_new_tokens=2, stop_ids=(9,)))
    slot, req = sched.next_admission()
    sched.bind(slot, req, first_token=9)
    assert sched.finish_reason(slot, cache_pos=4, max_len=32) == "stop"
    sched.append_token(slot, 5)
    assert sched.finish_reason(slot, cache_pos=5, max_len=32) == "length"
    sched.finish(slot, "length")


def test_ttft_is_nan_when_no_token_landed():
    """``first_token_at == 0.0`` used to produce a huge negative
    "latency" (0.0 minus a monotonic timestamp); now it is NaN, which
    the stats reducers skip explicitly."""
    comp = Completion(uid=0, prompt_len=1, tokens=[],
                      finish_reason="cancelled",
                      submitted_at=time.monotonic(), first_token_at=0.0)
    assert math.isnan(comp.ttft)
    served = Completion(uid=1, prompt_len=1, tokens=[3],
                        finish_reason="length", submitted_at=1.0,
                        first_token_at=1.5)
    assert served.ttft == pytest.approx(0.5)


def test_mass_cancel_is_not_quadratic():
    """20k submit + cancel cycles with a deep queue: O(1) cancels finish
    in well under the bound; the old per-cancel deque scan was O(n) each
    (~minutes at this size)."""
    sched = Scheduler(1)
    uids = [sched.submit(_req()) for _ in range(20_000)]
    t0 = time.monotonic()
    for uid in uids[1:]:  # cancel all but the head
        assert sched.cancel_pending(uid) is not None
    elapsed = time.monotonic() - t0
    assert elapsed < 5.0, f"mass cancel took {elapsed:.1f}s (quadratic?)"
    assert sched.n_pending == 1
    assert _drain(sched) == [uids[0]]  # lazy deletions all skipped


def test_find_and_cancel_pending_are_uid_indexed():
    sched = Scheduler(2)
    uid = sched.submit(_req())
    assert sched.find(uid) == ("pending", None)
    assert sched.find(uid + 999) == (None, None)
    comp = sched.cancel_pending(uid)
    assert comp.uid == uid and comp.finish_reason == "cancelled"
    assert sched.cancel_pending(uid) is None  # idempotent


def test_requeue_preserves_uid_and_submitted_at():
    sched = Scheduler(1)
    uid = sched.submit(_req(prio=2))
    slot, req = sched.next_admission()
    sched.bind(slot, req, first_token=7)
    sched.append_token(slot, 8)
    victim, tokens, first_at = sched.preempt(slot)
    assert victim.uid == uid and tokens == [7, 8] and first_at > 0
    assert sched.slots[slot] is None  # no completion emitted
    import dataclasses
    resume = dataclasses.replace(
        victim, prompt=np.concatenate([victim.prompt,
                                       np.asarray(tokens, np.int32)]),
        max_new_tokens=victim.max_new_tokens - len(tokens))
    sched.requeue(resume)
    slot2, req2 = sched.next_admission()
    assert req2.uid == uid  # same uid across lives
    assert req2.submitted_at == victim.submitted_at  # clock keeps running
    sched.bind(slot2, req2, first_token=9)
    comp = sched.finish(slot2, "length")
    assert comp.uid == uid
