"""Heterogeneous per-slot state: hymba + mamba through ContinuousEngine.

The extended differential serving matrix (the test_paging /
test_chunked_prefill style, pushed to the new state families): seeded
random traces with mixed prompt lengths and staggered Poisson arrivals
are replayed through THREE independent decode paths — one-shot
``generate``, the lock-step ``Engine``, and the chunked-prefill
``ContinuousEngine`` — for the hybrid (hymba: sliding-window ring KV +
SSM state) and pure-SSM (mamba2) families, and the greedy tokens must be
IDENTICAL across all of them.  On top sit the state-machinery edges:
chunk boundaries landing exactly on the sliding-window edge, slot
recycling across requests (stale ring lanes / ssm state must never
leak), and the O(window) / O(1) decode-memory shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ContinuousEngine, Engine, generate, make_trace, replay


@pytest.fixture(scope="module")
def hymba():
    cfg = get_config("hymba-1.5b").reduced()
    return build_model(jax.random.PRNGKey(0), cfg), cfg


@pytest.fixture(scope="module")
def mamba():
    cfg = get_config("mamba2-2.7b").reduced()
    return build_model(jax.random.PRNGKey(0), cfg), cfg


def _baseline(model, cfg, prompt, n, max_len=32):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return np.asarray(out)[0]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


def _assert_three_way(model, cfg, trace, comps, label):
    """Every completion must match generate AND the lock-step Engine."""
    assert len(comps) == len(trace)
    lock = Engine(model, cfg, batch=1, max_len=32, cache_dtype=jnp.float32)
    for (_, req), c in zip(trace, comps):  # trace order == uid order
        n = req.max_new_tokens
        ref_gen = _baseline(model, cfg, req.prompt, n)
        lock.reset()
        ref_lock = np.asarray(
            lock.greedy(jnp.asarray(req.prompt)[None, :], n))[0]
        np.testing.assert_array_equal(ref_gen, ref_lock)
        np.testing.assert_array_equal(
            np.array(c.tokens), ref_gen,
            err_msg=f"{label} diverged for uid={c.uid} "
                    f"plen={req.prompt.size} n={n}")
        assert c.prompt_len == req.prompt.size
        assert len(c.tokens) == n
        assert c.latency >= c.ttft >= 0


# ---- differential: ContinuousEngine == Engine == generate -------------------


@pytest.mark.parametrize("chunk,buckets", [(4, (4, 8)), (8, (8,))])
def test_hymba_differential_trace_three_way(hymba, chunk, buckets):
    """Hybrid family: ring KV + SSM per-slot state through recycled slots.
    ``chunk == 8 == cfg.window`` lands every chunk boundary exactly on
    the sliding-window edge (the wraparound case); ``chunk == 4`` puts
    boundaries mid-window."""
    model, cfg = hymba
    assert cfg.window == 8  # the window-edge parametrization relies on it
    trace = make_trace(10, seed=13, load=0.7, min_prompt=2, max_prompt=16,
                       min_new=2, max_new=8, vocab=cfg.vocab)
    eng = ContinuousEngine(model, cfg, batch=3, max_len=32,
                           max_prompt_len=16, chunk_size=chunk,
                           buckets=buckets, prefill_chunk_budget=chunk)
    comps, _ = replay(eng, trace)
    _assert_three_way(model, cfg, trace, comps, f"hymba chunk={chunk}")
    # decode memory is O(window) per slot, not O(max_len)
    stats = eng.kv_stats()
    assert stats["cache_kind"] == "hybrid"
    assert stats["kv_lane_tokens"] == cfg.window < eng.max_len


def test_mamba_differential_trace_three_way(mamba):
    """Pure-SSM family: conv/ssm per-slot state, chunked scan-in."""
    model, cfg = mamba
    trace = make_trace(10, seed=13, load=0.7, min_prompt=2, max_prompt=16,
                       min_new=2, max_new=8, vocab=cfg.vocab)
    eng = ContinuousEngine(model, cfg, batch=3, max_len=32,
                           max_prompt_len=16, chunk_size=4, buckets=(4, 8),
                           prefill_chunk_budget=4)
    comps, _ = replay(eng, trace)
    _assert_three_way(model, cfg, trace, comps, "mamba")
    stats = eng.kv_stats()
    assert stats["cache_kind"] == "ssm"
    assert "kv_lane_tokens" not in stats  # no position-addressable lanes


def test_swa_transformer_rides_the_ring_path():
    """A sliding-window TransformerLM (cache kind 'ring') serves through
    the same per-slot ring lanes — the kind probe is per-config, not
    per-class."""
    cfg = get_config("paper-tiny").reduced().replace(window=8)
    model = build_model(jax.random.PRNGKey(0), cfg)
    assert model.cache_kind(cfg) == "ring"
    assert model.cache_kind(cfg.replace(window=0)) == "kv"
    trace = make_trace(6, seed=3, load=0.7, min_prompt=2, max_prompt=16,
                       min_new=2, max_new=6, vocab=cfg.vocab)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=16, chunk_size=8, buckets=(4, 8))
    comps, _ = replay(eng, trace)
    _assert_three_way(model, cfg, trace, comps, "swa-transformer")
    assert eng.kv_stats()["kv_lane_tokens"] == cfg.window


# ---- window-edge prompt lengths ---------------------------------------------


@pytest.mark.parametrize("plen", [7, 8, 9, 15, 16])
def test_hymba_prompt_lengths_around_window_edge(hymba, plen):
    """Prompt lengths straddling multiples of the window with chunk ==
    window: the final chunk boundary lands exactly ON the edge (8, 16),
    one short (7, 15), and one past (9) — ring wraparound in every
    phase."""
    model, cfg = hymba
    p = _prompts([plen], cfg.vocab, seed=plen)[0]
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=16, chunk_size=cfg.window,
                           buckets=(cfg.window,))
    eng.submit(p, max_new_tokens=6)
    (comp,) = eng.run()
    np.testing.assert_array_equal(np.array(comp.tokens),
                                  _baseline(model, cfg, p, 6))


# ---- slot recycling: stale state must never leak ----------------------------


@pytest.mark.parametrize("family", ["hymba", "mamba"])
def test_recycled_slot_state_does_not_leak(hymba, mamba, family):
    """Drive enough staggered requests through a 1-slot engine that every
    request after the first reuses a slot whose ring lanes / ssm state
    still hold the previous occupant's bytes — each must match a
    fresh-engine baseline exactly."""
    model, cfg = hymba if family == "hymba" else mamba
    prompts = _prompts([9, 5, 12, 3], cfg.vocab, seed=21)
    eng = ContinuousEngine(model, cfg, batch=1, max_len=32,
                           max_prompt_len=16, chunk_size=4, buckets=(4, 8))
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    comps = eng.run()
    assert len(comps) == len(prompts)
    for p, c in zip(prompts, comps):
        np.testing.assert_array_equal(
            np.array(c.tokens), _baseline(model, cfg, p, 5),
            err_msg=f"{family}: recycled slot leaked state into "
                    f"plen={p.size}")


# ---- mixed decode batch: slots at independent positions ---------------------


def test_hymba_interleaved_admission_mid_decode(hymba):
    """A second request admitted while the first is mid-decode: the
    batched decode step advances both slots at independent positions
    (and the prefilling slot's state is frozen during the overlap)."""
    model, cfg = hymba
    pa, pb = _prompts([11, 6], cfg.vocab, seed=5)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=16, chunk_size=4, buckets=(4,),
                           prefill_chunk_budget=4)
    eng.submit(pa, max_new_tokens=8)
    for _ in range(3):  # pa mid-flight before pb arrives
        eng.step()
    eng.submit(pb, max_new_tokens=8)
    comps = eng.run()
    by_len = {c.prompt_len: c for c in comps}
    np.testing.assert_array_equal(np.array(by_len[11].tokens),
                                  _baseline(model, cfg, pa, 8))
    np.testing.assert_array_equal(np.array(by_len[6].tokens),
                                  _baseline(model, cfg, pb, 8))
