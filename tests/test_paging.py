"""Paged KV cache: differential serving harness + allocator properties.

The load-bearing guarantee is the differential harness: seeded random
traces (mixed prompt lengths, a shared system-prompt prefix, staggered
Poisson arrivals) are replayed through THREE independent decode paths —
one-shot ``generate``, the lock-step ``Engine``, and the paged
``ContinuousEngine`` — and the greedy tokens must be BIT-IDENTICAL across
all of them, with correct per-request completion metadata.  The paging
host layer (refcounted block allocator, hash-chained prefix cache, block
tables) is covered by property-based tests through the ``tests/_hyp``
shim: random alloc/free/fork sequences never leak or double-free blocks,
and a prefix-cache hit can never alias a block some live writer mutates.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, st

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (BlockAllocator, ContinuousEngine, Engine,
                         PagedCacheManager, UnsupportedCacheError,
                         chain_keys, generate, make_trace, replay)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("paper-tiny").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    return model, cfg


def _baseline(model, cfg, prompt, n, max_len=32):
    cache = model.init_cache(1, max_len, cfg, dtype=jnp.float32)
    out, _ = generate(model, jnp.asarray(prompt)[None, :], cache, n_steps=n)
    return np.asarray(out)[0]


def _prompts(lengths, vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, n).astype(np.int32) for n in lengths]


# ---- differential serving harness -------------------------------------------


def test_differential_trace_three_way(setup):
    """generate == lock-step Engine == paged ContinuousEngine, token for
    token, on a seeded trace with mixed lengths, a shared 6-token prefix,
    and staggered arrivals pushed through 3 recycled slots."""
    model, cfg = setup
    trace = make_trace(10, seed=13, load=0.7, min_prompt=2, max_prompt=10,
                       min_new=2, max_new=8, vocab=cfg.vocab,
                       shared_prefix=6)
    eng = ContinuousEngine(model, cfg, batch=3, max_len=32,
                           max_prompt_len=16, kv_layout="paged",
                           block_size=4)
    comps, _ = replay(eng, trace)
    assert len(comps) == len(trace)
    assert [c.uid for c in comps] == sorted(c.uid for c in comps)

    lock = Engine(model, cfg, batch=1, max_len=32, cache_dtype=jnp.float32)
    for (_, req), c in zip(trace, comps):  # trace order == uid order
        n = req.max_new_tokens
        ref_gen = _baseline(model, cfg, req.prompt, n)
        lock.reset()
        ref_lock = np.asarray(
            lock.greedy(jnp.asarray(req.prompt)[None, :], n))[0]
        np.testing.assert_array_equal(ref_gen, ref_lock)
        np.testing.assert_array_equal(
            np.array(c.tokens), ref_gen,
            err_msg=f"paged engine diverged for uid={c.uid} "
                    f"plen={req.prompt.size} n={n}")
        # completion metadata
        assert c.prompt_len == req.prompt.size
        assert c.finish_reason == "length"
        assert len(c.tokens) == n
        assert c.latency >= c.ttft >= 0
    # the shared 6-token prefix spans one full 4-token block; overlapping
    # requests hit it — and with LRU retention the hit survives pool drains
    # between staggered arrivals, so every request after the first hits
    assert eng.manager.prefix_hit_tokens >= 4
    # drained engine holds no live references; what survives is the warm
    # LRU of parked prefix blocks, each still indexed by the prefix cache
    assert eng.manager.fully_free
    assert len(eng.manager.prefix) == len(eng.manager.retained)
    assert (eng.manager.allocator.n_free
            + eng.manager.allocator.n_parked) == eng.n_blocks


def test_paged_matches_dense_layout(setup):
    """Same submissions through kv_layout='dense' and 'paged' produce
    identical tokens and finish metadata (block size chosen so it does not
    divide every prompt length)."""
    model, cfg = setup
    prompts = _prompts([5, 12, 8, 3, 10, 6], cfg.vocab, seed=21)
    budgets = [6, 4, 8, 5, 3, 7]
    outs = {}
    for layout in ("dense", "paged"):
        eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                               max_prompt_len=12, kv_layout=layout,
                               block_size=8)
        for p, n in zip(prompts, budgets):
            eng.submit(p, max_new_tokens=n)
        outs[layout] = eng.run()
        assert eng.kv_stats()["kv_layout"] == layout
    for cd, cp in zip(outs["dense"], outs["paged"]):  # both uid-sorted ==
        assert cd.prompt_len == cp.prompt_len         # submission order
        assert cd.tokens == cp.tokens
        assert cd.finish_reason == cp.finish_reason


def test_prefix_blocks_shared_and_refcounted(setup):
    """Two live requests with the same 8-token prompt share the two full
    prompt blocks (refcount 2) and still match the baseline exactly."""
    model, cfg = setup
    prompt = _prompts([8], cfg.vocab, seed=5)[0]
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4)
    eng.submit(prompt, max_new_tokens=6)
    eng.submit(prompt, max_new_tokens=6)
    eng.step()  # both admitted, one decode step: both still live
    assert eng.manager.prefix_hit_tokens == 8
    shared = [bid for bid in range(eng.n_blocks)
              if eng.manager.allocator.refcount[bid] == 2]
    assert len(shared) == 2  # the two full prompt blocks, nothing else
    ref = _baseline(model, cfg, prompt, 6)
    for c in eng.run():
        np.testing.assert_array_equal(np.array(c.tokens), ref)
    assert eng.manager.fully_free


def test_stop_token_metadata_on_paged_engine(setup):
    """Stop-token eviction (finish_reason + stop id included) survives the
    paged layout."""
    model, cfg = setup
    prompt = _prompts([6], cfg.vocab, seed=3)[0]
    ref = _baseline(model, cfg, prompt, 8)
    stop = int(ref[1]) if ref[1] != ref[0] else int(ref[0])
    first_hit = int(np.argmax(ref == stop))
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                           max_prompt_len=12, kv_layout="paged",
                           block_size=4)
    eng.submit(prompt, max_new_tokens=8, stop_ids=(stop,))
    (comp,) = eng.run()
    assert comp.finish_reason == "stop"
    assert comp.tokens == ref[:first_hit + 1].tolist()
    assert eng.manager.fully_free


def test_cache_full_frozen_slot_does_not_corrupt_neighbors(setup):
    """Regression: a slot evicted with finish_reason='cache_full' freezes at
    length == max_len; its per-step paged decode used to look up one entry
    past its block table, and take_along_axis's out-of-bounds fill
    (INT32_MIN) times block_size wraps around int32 to pool row 0 — so the
    'dropped' scatter landed stale K/V inside a LIVE request's first block,
    silently corrupting its tokens."""
    model, cfg = setup
    rng = np.random.default_rng(7)
    long_lived = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    cache_filler = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    outs = {}
    for layout in ("dense", "paged"):
        eng = ContinuousEngine(model, cfg, batch=2, max_len=16,
                               max_prompt_len=8, kv_layout=layout,
                               block_size=4)
        eng.submit(long_lived, max_new_tokens=12)    # owns pool block 0
        eng.submit(cache_filler, max_new_tokens=16)  # frozen at pos 16
        outs[layout] = {c.prompt_len: c for c in eng.run()}
    assert outs["paged"][6].finish_reason == "cache_full"
    for plen in (4, 6):
        assert outs["paged"][plen].tokens == outs["dense"][plen].tokens, \
            f"frozen cache-full slot corrupted prompt_len={plen}"


# ---- cache-kind capability probe (serve / structured rejection) -------------


def test_hymba_serves_continuously():
    """Regression FLIP: sliding-window (hymba) configs used to be rejected
    with UnsupportedCacheError at construction — they now serve through
    per-slot ring + ssm state, degrading the default paged layout
    gracefully (prefix reuse off, no block reservation), with tokens
    matching the one-shot baseline."""
    cfg = get_config("hymba-1.5b").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    eng = ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8)
    stats = eng.kv_stats()
    assert stats["cache_kind"] == "hybrid"
    assert eng.manager is None  # block reservation / prefix cache inactive
    assert stats["kv_lane_tokens"] == cfg.window  # ring lanes, not max_len
    prompt = _prompts([6], cfg.vocab, seed=1)[0]
    eng.submit(prompt, max_new_tokens=5)
    (comp,) = eng.run()
    np.testing.assert_array_equal(np.array(comp.tokens),
                                  _baseline(model, cfg, prompt, 5))


def test_ssm_serves_continuously_in_both_requested_layouts():
    """Mamba used to raise in both layouts; the engine now serves it via
    per-slot conv/ssm state whichever layout the caller asked for (paged
    knobs degrade gracefully)."""
    cfg = get_config("mamba2-2.7b").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    prompt = _prompts([7], cfg.vocab, seed=2)[0]
    ref = _baseline(model, cfg, prompt, 4)
    for layout in ("paged", "dense"):
        eng = ContinuousEngine(model, cfg, batch=2, max_len=32,
                               max_prompt_len=8, kv_layout=layout)
        assert eng.kv_stats()["cache_kind"] == "ssm"
        eng.submit(prompt, max_new_tokens=4)
        (comp,) = eng.run()
        np.testing.assert_array_equal(np.array(comp.tokens), ref)


def test_whisper_rejected_with_unsupported_cache_error():
    """The mirror-image regression: enc-dec (whisper) still has no
    per-slot state and must be rejected with the structured error naming
    the remaining ROADMAP item (roadmap_item coverage survives the hymba
    flip)."""
    cfg = get_config("whisper-medium").reduced()
    model = build_model(jax.random.PRNGKey(0), cfg)
    with pytest.raises(UnsupportedCacheError) as ei:
        ContinuousEngine(model, cfg, batch=2, max_len=32, max_prompt_len=8)
    assert "Whisper" in ei.value.roadmap_item
    assert "enc-dec" in ei.value.roadmap_item
    assert isinstance(ei.value, ValueError)  # backwards compatible


# ---- allocator / prefix-cache unit tests ------------------------------------


def test_allocator_errors():
    a = BlockAllocator(4, 2)
    bid = a.alloc()
    a.free(bid)
    with pytest.raises(RuntimeError):
        a.free(bid)  # double free
    with pytest.raises(RuntimeError):
        a.fork(bid)  # fork of a free block
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [0, 1, 2, 3]
    with pytest.raises(RuntimeError):
        a.alloc()  # exhausted


def test_chain_keys_commit_to_full_prefix():
    bs = 4
    a = np.arange(10, dtype=np.int32)
    b = np.arange(10, dtype=np.int32)
    c = a.copy()
    c[1] = 99  # differ inside the FIRST block
    d = a.copy()
    d[5] = 99  # differ inside the SECOND block
    ka, kb, kc, kd = (chain_keys(t, bs) for t in (a, b, c, d))
    assert len(ka) == 2  # only full blocks get keys
    assert ka == kb
    assert ka[0] != kc[0] and ka[1] != kc[1]  # first-block change cascades
    assert ka[0] == kd[0] and ka[1] != kd[1]  # second-block change is local
    assert chain_keys(np.arange(3, dtype=np.int32), bs) == []


# ---- property-based: allocator + manager invariants -------------------------


@given(st.integers(0, 2**32 - 1))
def test_allocator_random_ops_never_leak_or_double_free(seed):
    """Random alloc/fork/free interleavings: refcounts always match an
    independent model, in-use + free always covers the pool, and releasing
    every reference returns the pool to fully free."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(n_blocks=8, block_size=4)
    live = {}  # bid -> expected refcount
    for _ in range(150):
        op = int(rng.integers(0, 3))
        if op == 0 and alloc.n_free:
            bid = alloc.alloc()
            assert bid not in live
            live[bid] = 1
        elif op == 1 and live:
            bid = int(rng.choice(sorted(live)))
            alloc.fork(bid)
            live[bid] += 1
        elif op == 2 and live:
            bid = int(rng.choice(sorted(live)))
            rc = alloc.free(bid)
            live[bid] -= 1
            assert rc == live[bid]
            if not live[bid]:
                del live[bid]
        assert alloc.n_in_use == len(live)
        assert alloc.n_free == alloc.n_blocks - len(live)
        for bid, rc in live.items():
            assert alloc.refcount[bid] == rc
    for bid, rc in list(live.items()):
        for _ in range(rc):
            alloc.free(bid)
    assert alloc.n_free == alloc.n_blocks
    assert (alloc.refcount == 0).all()


@given(st.integers(0, 2**32 - 1))
def test_manager_prefix_hits_never_alias_writable_blocks(seed):
    """Random admit/publish/release sequences with colliding prompt stems
    (LRU retention on for half the seeds): the blocks a new admission may
    WRITE (its scatter destinations) are always exclusively owned
    (refcount 1, no other slot maps them), shared prefix blocks are only
    ever read, parked blocks never hold a reference, and draining every
    slot leaves no live references — just the warm LRU, fully indexed by
    the prefix cache."""
    rng = np.random.default_rng(seed)
    bs, batch, max_len = 4, 4, 32
    retain = int(rng.integers(0, 9)) if seed % 2 else 0
    mgr = PagedCacheManager(n_blocks=24, block_size=bs, batch=batch,
                            max_len=max_len, retain_blocks=retain)
    stems = [rng.integers(0, 256, 8).astype(np.int32) for _ in range(2)]
    owned = {}  # slot -> set of mapped block ids
    for _ in range(60):
        free_slots = [s for s in range(batch) if s not in owned]
        do_admit = free_slots and (not owned or rng.random() < 0.6)
        if do_admit:
            slot = int(rng.choice(free_slots))
            stem = stems[int(rng.integers(0, len(stems)))]
            suffix = rng.integers(0, 256, int(rng.integers(0, 5))
                                  ).astype(np.int32)
            prompt = np.concatenate([stem, suffix])
            total = min(len(prompt) + int(rng.integers(1, 6)), max_len)
            if not mgr.can_admit(prompt, total):
                continue
            cached, hits = mgr.admit(slot, prompt, total)
            assert cached % bs == 0 and cached <= len(prompt)
            assert len(hits) * bs == cached
            dst = mgr.scatter_rows(slot, 0, len(prompt), lo=cached,
                                   hi=len(prompt))
            mapped = dst[dst < mgr.sentinel * bs]
            writable = {int(b) for b in mapped // bs}
            assert not writable & set(hits)  # hit blocks are read-only
            for other, blocks in owned.items():
                assert not writable & blocks, \
                    f"slot {slot} would write blocks mapped by slot {other}"
            for bid in writable:
                assert mgr.allocator.refcount[bid] == 1
            # the writer sometimes finishes its prefill (publishing its
            # registered full blocks), sometimes releases mid-pending
            if rng.random() < 0.7:
                mgr.publish(slot, len(prompt))
            owned[slot] = {int(b) for b in mgr.tables[slot]
                           if b != mgr.sentinel}
        elif owned:
            slot = int(rng.choice(sorted(owned)))
            mgr.release(slot)
            del owned[slot]
        in_use = {b for blocks in owned.values() for b in blocks}
        assert mgr.allocator.n_in_use == len(in_use)
        assert len(mgr.retained) <= retain
        for bid in mgr.retained:
            assert mgr.allocator.refcount[bid] == 0
            assert bid not in in_use
    for slot in sorted(owned):
        mgr.release(slot)
    assert mgr.fully_free
    assert len(mgr.prefix) == len(mgr.retained)
    assert mgr.allocator.n_free + mgr.allocator.n_parked == 24
