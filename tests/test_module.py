"""Module system: pytree registration, traversal, surgery."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import nn
from repro.nn.module import (iter_modules, map_modules, named_parameters,
                             param_count, tree_slice)


class Leafy(nn.Module):
    w: jax.Array
    n: int = nn.static_field(default=3)


class Nested(nn.Module):
    lin: nn.Linear
    inner: Leafy
    items: list


def make_nested(key):
    return Nested(
        lin=nn.Linear.create(key, 4, 8, use_bias=True),
        inner=Leafy(w=jnp.ones((2, 2))),
        items=[Leafy(w=jnp.zeros((1,))), nn.Linear.create(key, 3, 3)],
    )


def test_pytree_roundtrip(key):
    m = make_nested(key)
    leaves, treedef = jax.tree_util.tree_flatten(m)
    m2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(m2, Nested)
    assert m2.inner.n == 3
    assert jnp.array_equal(m2.lin.weight, m.lin.weight)


def test_static_fields_are_aux(key):
    m = Leafy(w=jnp.ones((2,)), n=7)
    mapped = jax.tree_util.tree_map(lambda x: x * 2, m)
    assert mapped.n == 7
    assert jnp.array_equal(mapped.w, 2 * jnp.ones((2,)))


def test_jit_through_module(key):
    lin = nn.Linear.create(key, 4, 4)

    @jax.jit
    def f(m, x):
        return m(x)

    x = jnp.ones((2, 4))
    assert jnp.allclose(f(lin, x), lin(x))


def test_iter_modules_paths(key):
    m = make_nested(key)
    paths = [p for p, _ in iter_modules(m)]
    assert "" in paths and "lin" in paths and "inner" in paths
    assert "items.0" in paths and "items.1" in paths


def test_map_modules_replacement(key):
    m = make_nested(key)
    led = nn.LED.create(key, 4, 8, 2)

    def swap(path, node):
        if isinstance(node, nn.Linear) and path == "lin":
            return led
        return node

    m2 = map_modules(m, swap)
    assert isinstance(m2.lin, nn.LED)
    assert isinstance(m2.items[1], nn.Linear)  # untouched
    assert m.lin is not m2.lin and m.inner is m2.inner  # minimal copying


def test_named_parameters_paths(key):
    m = make_nested(key)
    names = dict(named_parameters(m))
    assert "lin.weight" in names and "lin.bias" in names
    assert "items.0.w" in names


def test_param_count(key):
    m = nn.Linear.create(key, 4, 8, use_bias=True)
    assert param_count(m) == 4 * 8 + 8


def test_tree_slice(key):
    stacked = jax.vmap(lambda k: nn.Linear.create(k, 4, 4))(
        jax.random.split(key, 5))
    assert stacked.weight.shape == (5, 4, 4)
    one = tree_slice(stacked, 2)
    assert one.weight.shape == (4, 4)


def test_frozen_immutability(key):
    m = Leafy(w=jnp.ones((2,)))
    with pytest.raises(dataclasses.FrozenInstanceError):
        m.w = jnp.zeros((2,))


def test_replace(key):
    m = Leafy(w=jnp.ones((2,)), n=1)
    m2 = m.replace(n=9)
    assert m2.n == 9 and m.n == 1
